"""Small classifier used for the paper's own HFL experiments (MNIST-scale).

The paper trains "the classification task using the MNIST dataset" with an
unspecified model; we use a 2-hidden-layer MLP, which is the standard choice
in the FL literature the paper builds on (McMahan et al.).  The model is
deliberately tiny so that vmapping it over 64 clients (the paper's setup)
stays cheap.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

Params = Dict[str, Any]


class MLPClassifier:
    def __init__(self, input_dim: int = 784, hidden: int = 128,
                 n_classes: int = 10):
        self.input_dim = input_dim
        self.hidden = hidden
        self.n_classes = n_classes

    def init(self, key) -> Params:
        ks = jax.random.split(key, 3)
        return {
            "w1": layers.scaled_init(ks[0], (self.input_dim, self.hidden),
                                     jnp.float32),
            "b1": jnp.zeros((self.hidden,), jnp.float32),
            "w2": layers.scaled_init(ks[1], (self.hidden, self.hidden),
                                     jnp.float32),
            "b2": jnp.zeros((self.hidden,), jnp.float32),
            "w3": layers.scaled_init(ks[2], (self.hidden, self.n_classes),
                                     jnp.float32),
            "b3": jnp.zeros((self.n_classes,), jnp.float32),
        }

    def apply(self, params: Params, x: jnp.ndarray) -> jnp.ndarray:
        h = jax.nn.relu(x @ params["w1"] + params["b1"])
        h = jax.nn.relu(h @ params["w2"] + params["b2"])
        return h @ params["w3"] + params["b3"]

    def loss(self, params: Params, batch: Tuple[jnp.ndarray, jnp.ndarray]
             ) -> jnp.ndarray:
        x, y = batch
        logits = self.apply(params, x)
        return layers.softmax_cross_entropy(logits, y)

    def accuracy(self, params: Params, x: jnp.ndarray, y: jnp.ndarray
                 ) -> jnp.ndarray:
        logits = self.apply(params, x)
        return jnp.mean((jnp.argmax(logits, -1) == y).astype(jnp.float32))

    def grad_norm(self, params: Params, batch) -> jnp.ndarray:
        g = jax.grad(self.loss)(params, batch)
        leaves = jax.tree.leaves(g)
        return jnp.sqrt(sum(jnp.sum(jnp.square(l)) for l in leaves))
