"""xLSTM blocks — sLSTM (scalar memory, recurrent hidden mixing) and mLSTM
(matrix memory, fully parallelizable) following arXiv:2405.04517.

Both use exponential gating with the log-domain stabiliser state ``m``:

    m_t = max(log f_t + m_{t-1}, log i_t)
    i'  = exp(log i_t - m_t),  f' = exp(log f_t + m_{t-1} - m_t)

mLSTM recurrence (per head):   C_t = f'·C_{t-1} + i'·v_t k_tᵀ
                               n_t = f'·n_{t-1} + i'·k_t
                               h_t = C_t q_t / max(|n_tᵀ q_t|, 1)

sLSTM keeps a scalar cell per unit with block-diagonal (per-head) recurrent
weights on the gate pre-activations, which makes it strictly sequential —
implemented as a ``lax.scan``; the diagonal-recurrence Pallas kernel covers
the RG-LRU-style scans, the sLSTM scan stays XLA (documented in DESIGN.md).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

Params = Dict[str, Any]

_CONV_WIDTH = 4
_PF_MLSTM = 2.0    # mLSTM up-projection factor
_PF_SLSTM = 4.0 / 3.0


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------

def mlstm_block_init(key, cfg, *, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    di = int(_PF_MLSTM * d)
    nh = cfg.n_heads
    dh = di // nh
    ks = jax.random.split(key, 10)
    return {
        "w_up_main": layers.scaled_init(ks[0], (d, di), dtype, fan_in=d),
        "w_up_gate": layers.scaled_init(ks[1], (d, di), dtype, fan_in=d),
        "conv_w": layers.normal_init(ks[2], (_CONV_WIDTH, di), dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": layers.scaled_init(ks[3], (di, nh, dh), dtype, fan_in=di),
        "wk": layers.scaled_init(ks[4], (di, nh, dh), dtype, fan_in=di),
        "wv": layers.scaled_init(ks[5], (di, nh, dh), dtype, fan_in=di),
        "w_igate": layers.normal_init(ks[6], (di, nh), jnp.float32),
        "b_igate": jnp.zeros((nh,), jnp.float32),
        "w_fgate": layers.normal_init(ks[7], (di, nh), jnp.float32),
        "b_fgate": jnp.full((nh,), 3.0, jnp.float32),  # open forget gates
        "norm_scale": jnp.ones((nh, dh), jnp.float32),
        "w_down": layers.scaled_init(ks[8], (di, d), dtype, fan_in=di),
    }


def _causal_conv(w, b, x, state=None):
    pad = jnp.zeros((x.shape[0], _CONV_WIDTH - 1, x.shape[-1]), x.dtype) \
        if state is None else state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w.astype(x.dtype)[i]
              for i in range(_CONV_WIDTH))
    return out + b.astype(x.dtype)


def _mlstm_cell(carry, inp):
    """One timestep of the stabilised mLSTM recurrence.  All fp32."""
    c, n, m = carry                       # (B,H,dk,dv), (B,H,dk), (B,H)
    q, k, v, log_i, log_f = inp           # (B,H,dk) ×3, (B,H) ×2
    m_new = jnp.maximum(log_f + m, log_i)
    i_p = jnp.exp(log_i - m_new)[..., None]
    f_p = jnp.exp(log_f + m - m_new)[..., None]
    n_new = f_p * n + i_p * k
    c_new = f_p[..., None] * c + i_p[..., None] * (k[..., :, None] * v[..., None, :])
    num = jnp.einsum("bhkv,bhk->bhv", c_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n_new, q)), 1.0)
    h = num / den[..., None]
    return (c_new, n_new, m_new), h


def _mlstm_inner(params: Params, x: jnp.ndarray, cfg,
                 state=None) -> Tuple[jnp.ndarray, Tuple]:
    """Shared mLSTM body.  x (B, S, d) -> (y (B, S, d), new_state)."""
    b, s, d = x.shape
    nh = cfg.n_heads
    main = jnp.einsum("bsd,di->bsi", x, params["w_up_main"].astype(x.dtype))
    gate = jax.nn.silu(
        jnp.einsum("bsd,di->bsi", x, params["w_up_gate"].astype(x.dtype)))
    conv_state = None if state is None else state[3]
    cm = jax.nn.silu(_causal_conv(params["conv_w"], params["conv_b"], main,
                                  conv_state))
    di = main.shape[-1]
    dh = di // nh
    q = jnp.einsum("bsi,ihk->bshk", cm, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsi,ihk->bshk", cm, params["wk"].astype(x.dtype)) * dh ** -0.5
    v = jnp.einsum("bsi,ihk->bshk", main, params["wv"].astype(x.dtype))
    log_i = jnp.einsum("bsi,ih->bsh", cm.astype(jnp.float32),
                       params["w_igate"]) + params["b_igate"]
    log_f = jax.nn.log_sigmoid(
        jnp.einsum("bsi,ih->bsh", cm.astype(jnp.float32), params["w_fgate"])
        + params["b_fgate"])

    if state is None:
        c0 = jnp.zeros((b, nh, dh, dh), jnp.float32)
        n0 = jnp.zeros((b, nh, dh), jnp.float32)
        m0 = jnp.zeros((b, nh), jnp.float32)
    else:
        c0, n0, m0 = state[0], state[1], state[2]

    xs = (q.astype(jnp.float32).transpose(1, 0, 2, 3),
          k.astype(jnp.float32).transpose(1, 0, 2, 3),
          v.astype(jnp.float32).transpose(1, 0, 2, 3),
          log_i.transpose(1, 0, 2), log_f.transpose(1, 0, 2))
    (c, n, m), hs = jax.lax.scan(_mlstm_cell, (c0, n0, m0), xs)
    h = hs.transpose(1, 0, 2, 3)                        # (B,S,H,dh)
    h = layers.rmsnorm_apply({"scale": params["norm_scale"].reshape(-1)},
                             h.reshape(b, s, di)).astype(x.dtype)
    y = h * gate
    out = jnp.einsum("bsi,id->bsd", y, params["w_down"].astype(x.dtype))
    new_conv = (main if state is None else
                jnp.concatenate([conv_state.astype(main.dtype), main], axis=1)
                )[:, -(_CONV_WIDTH - 1):]
    return out, (c, n, m, new_conv)


def mlstm_block_apply(params: Params, x: jnp.ndarray, cfg) -> jnp.ndarray:
    y, _ = _mlstm_inner(params, x, cfg)
    return y


def mlstm_init_cache(cfg, batch: int, dtype) -> Tuple:
    d = cfg.d_model
    di = int(_PF_MLSTM * d)
    nh = cfg.n_heads
    dh = di // nh
    return (jnp.zeros((batch, nh, dh, dh), jnp.float32),
            jnp.zeros((batch, nh, dh), jnp.float32),
            jnp.zeros((batch, nh), jnp.float32),
            jnp.zeros((batch, _CONV_WIDTH - 1, di), dtype))


def mlstm_block_decode(params: Params, x: jnp.ndarray, cfg, cache: Tuple
                       ) -> Tuple[jnp.ndarray, Tuple]:
    return _mlstm_inner(params, x, cfg, state=cache)


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_block_init(key, cfg, *, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    dff = int(_PF_SLSTM * d)
    ks = jax.random.split(key, 9)
    return {
        "conv_w": layers.normal_init(ks[0], (_CONV_WIDTH, d), dtype),
        "conv_b": jnp.zeros((d,), dtype),
        # input weights for the four gates (i, f, z, o)
        "w_gates": layers.scaled_init(ks[1], (d, 4 * d), dtype, fan_in=d),
        "b_gates": jnp.concatenate([jnp.zeros((d,)), jnp.full((d,), 3.0),
                                    jnp.zeros((2 * d,))]).astype(jnp.float32),
        # block-diagonal recurrent weights, per head: (4 gates, H, dh, dh)
        "r_gates": layers.scaled_init(ks[2], (4, nh, dh, dh), jnp.float32,
                                      fan_in=dh),
        "norm_scale": jnp.ones((d,), jnp.float32),
        "w_up_gate": layers.scaled_init(ks[3], (d, dff), dtype, fan_in=d),
        "w_up": layers.scaled_init(ks[4], (d, dff), dtype, fan_in=d),
        "w_down": layers.scaled_init(ks[5], (dff, d), dtype, fan_in=dff),
    }


def _slstm_cell(params_r, carry, inp):
    """One sLSTM timestep.  carry: (c, n, h, m) each (B, d) fp32."""
    c, n, h, m = carry
    pre = inp  # (B, 4d) input contribution
    b, d4 = pre.shape
    d = d4 // 4
    nh = params_r.shape[1]
    dh = d // nh
    hh = h.reshape(b, nh, dh)
    rec = jnp.einsum("bhx,ghxy->bghy", hh, params_r).reshape(b, 4 * d)
    zi, zf, zz, zo = jnp.split(pre + rec, 4, axis=-1)
    log_i = zi
    log_f = jax.nn.log_sigmoid(zf)
    m_new = jnp.maximum(log_f + m, log_i)
    i_p = jnp.exp(log_i - m_new)
    f_p = jnp.exp(log_f + m - m_new)
    z = jnp.tanh(zz)
    o = jax.nn.sigmoid(zo)
    c_new = f_p * c + i_p * z
    n_new = f_p * n + i_p
    h_new = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new), h_new


def _slstm_inner(params: Params, x: jnp.ndarray, cfg,
                 state=None) -> Tuple[jnp.ndarray, Tuple]:
    b, s, d = x.shape
    conv_state = None if state is None else state[4]
    cx = jax.nn.silu(_causal_conv(params["conv_w"], params["conv_b"], x,
                                  conv_state))
    pre = (jnp.einsum("bsd,de->bse", cx, params["w_gates"].astype(x.dtype))
           .astype(jnp.float32) + params["b_gates"])
    if state is None:
        zeros = jnp.zeros((b, d), jnp.float32)
        carry = (zeros, zeros, zeros, zeros)
    else:
        carry = (state[0], state[1], state[2], state[3])
    cell = lambda ca, inp: _slstm_cell(params["r_gates"], ca, inp)
    carry, hs = jax.lax.scan(cell, carry, pre.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2)                           # (B,S,d)
    h = layers.rmsnorm_apply({"scale": params["norm_scale"]}, h).astype(x.dtype)
    up = jnp.einsum("bsd,df->bsf", h, params["w_up"].astype(x.dtype))
    gate = jax.nn.gelu(
        jnp.einsum("bsd,df->bsf", h, params["w_up_gate"].astype(x.dtype)))
    out = jnp.einsum("bsf,fd->bsd", up * gate, params["w_down"].astype(x.dtype))
    new_conv = (x if state is None else
                jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
                )[:, -(_CONV_WIDTH - 1):]
    return out, carry + (new_conv,)


def slstm_block_apply(params: Params, x: jnp.ndarray, cfg) -> jnp.ndarray:
    y, _ = _slstm_inner(params, x, cfg)
    return y


def slstm_init_cache(cfg, batch: int, dtype) -> Tuple:
    d = cfg.d_model
    zeros = jnp.zeros((batch, d), jnp.float32)
    return (zeros, zeros, zeros, zeros,
            jnp.zeros((batch, _CONV_WIDTH - 1, d), dtype))


def slstm_block_decode(params: Params, x: jnp.ndarray, cfg, cache: Tuple
                       ) -> Tuple[jnp.ndarray, Tuple]:
    return _slstm_inner(params, x, cfg, state=cache)
