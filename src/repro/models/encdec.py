"""Encoder-decoder transformer backbone (whisper-large-v3).

The mel-spectrogram + conv frontend is a STUB per the assignment: the encoder
consumes precomputed frame embeddings of shape (B, n_frames, d_model) from
``input_specs()``.  Sinusoidal positions (length-agnostic) replace whisper's
learned absolute table so the assigned 32k decode shape lowers cleanly.

Both encoder and decoder stacks are scanned over stacked per-layer params.
Decode caches: per-decoder-layer self-attention KV (cache_len) plus
cross-attention KV precomputed once from the encoder output.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, layers

Params = Dict[str, Any]


def _scan_or_unroll(cfg, body, carry, stack):
    """lax.scan over stacked layer params, or a Python loop when the config
    asks for unrolled HLO (roofline accounting mode — XLA cost analysis
    counts while-loop bodies once)."""
    if cfg.scan_layers:
        out, _ = jax.lax.scan(body, carry, stack)
        return out
    reps = jax.tree.leaves(stack)[0].shape[0]
    for r in range(reps):
        carry, _ = body(carry, jax.tree.map(lambda l, r=r: l[r], stack))
    return carry


def sinusoid_positions(positions: jnp.ndarray, d_model: int) -> jnp.ndarray:
    """(S,) int positions -> (S, d_model) sinusoidal embeddings (fp32)."""
    half = d_model // 2
    freqs = jnp.exp(-jnp.log(10000.0) * jnp.arange(half) / max(half - 1, 1))
    angles = positions[:, None].astype(jnp.float32) * freqs[None, :]
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


class EncDecTransformer:
    def __init__(self, cfg):
        self.cfg = cfg

    # -- init ----------------------------------------------------------------

    def _enc_layer_init(self, key) -> Params:
        cfg, dt = self.cfg, self.cfg.param_dtype
        ks = jax.random.split(key, 2)
        return {
            "norm1": layers.norm_init(cfg.norm, cfg.d_model, dt),
            "attn": attention.attention_init(ks[0], cfg, dtype=dt),
            "norm2": layers.norm_init(cfg.norm, cfg.d_model, dt),
            "mlp": layers.mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                   gated=cfg.gated_mlp, bias=cfg.mlp_bias,
                                   dtype=dt),
        }

    def _dec_layer_init(self, key) -> Params:
        cfg, dt = self.cfg, self.cfg.param_dtype
        ks = jax.random.split(key, 3)
        return {
            "norm1": layers.norm_init(cfg.norm, cfg.d_model, dt),
            "self_attn": attention.attention_init(ks[0], cfg, dtype=dt),
            "norm2": layers.norm_init(cfg.norm, cfg.d_model, dt),
            "cross_attn": attention.cross_attention_init(ks[1], cfg, dtype=dt),
            "norm3": layers.norm_init(cfg.norm, cfg.d_model, dt),
            "mlp": layers.mlp_init(ks[2], cfg.d_model, cfg.d_ff,
                                   gated=cfg.gated_mlp, bias=cfg.mlp_bias,
                                   dtype=dt),
        }

    def init(self, key) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        enc_keys = jax.random.split(ks[0], cfg.encoder_layers)
        dec_keys = jax.random.split(ks[1], cfg.n_layers)
        return {
            "embed": layers.embedding_init(ks[2], cfg.vocab_size, cfg.d_model,
                                           tie=cfg.tie_embeddings,
                                           dtype=cfg.param_dtype),
            "encoder": jax.vmap(lambda k: self._enc_layer_init(k))(enc_keys),
            "enc_norm": layers.norm_init(cfg.norm, cfg.d_model,
                                         cfg.param_dtype),
            "decoder": jax.vmap(lambda k: self._dec_layer_init(k))(dec_keys),
            "final_norm": layers.norm_init(cfg.norm, cfg.d_model,
                                           cfg.param_dtype),
        }

    # -- encoder ---------------------------------------------------------------

    def encode(self, params: Params, frames: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        x = frames.astype(cfg.compute_dtype)
        pos = sinusoid_positions(jnp.arange(x.shape[1]), cfg.d_model)
        x = x + pos[None].astype(x.dtype)

        def body(xc, p):
            h = layers.norm_apply(cfg.norm, p["norm1"], xc)
            xc = xc + attention.bidirectional_attention_apply(
                p["attn"], h, cfg, use_rope=False)
            h = layers.norm_apply(cfg.norm, p["norm2"], xc)
            xc = xc + layers.mlp_apply(p["mlp"], h, activation=cfg.activation)
            return xc, None

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x = _scan_or_unroll(cfg, body, x, params["encoder"])
        return layers.norm_apply(cfg.norm, params["enc_norm"], x)

    # -- decoder (teacher forcing) ----------------------------------------------

    def apply(self, params: Params, tokens: jnp.ndarray, *,
              extra_embeddings: Optional[jnp.ndarray] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """tokens (B, S) decoder inputs, extra_embeddings (B, F, d) frames."""
        cfg = self.cfg
        assert extra_embeddings is not None, "enc-dec model needs frames"
        enc = self.encode(params, extra_embeddings)
        x = layers.embed_apply(params["embed"], tokens, cfg.compute_dtype)
        pos = sinusoid_positions(jnp.arange(x.shape[1]), cfg.d_model)
        x = x + pos[None].astype(x.dtype)
        positions = jnp.arange(x.shape[1])

        def body(xc, p):
            h = layers.norm_apply(cfg.norm, p["norm1"], xc)
            xc = xc + attention.attention_apply(
                p["self_attn"], h, cfg, mask_kind="global",
                positions=positions, use_rope=False)
            h = layers.norm_apply(cfg.norm, p["norm2"], xc)
            xc = xc + attention.cross_attention_apply(p["cross_attn"], h,
                                                      enc, cfg)
            h = layers.norm_apply(cfg.norm, p["norm3"], xc)
            xc = xc + layers.mlp_apply(p["mlp"], h, activation=cfg.activation)
            return xc, None

        if cfg.remat:
            body = jax.checkpoint(
                body, policy=jax.checkpoint_policies.nothing_saveable)
        x = _scan_or_unroll(cfg, body, x, params["decoder"])
        x = layers.norm_apply(cfg.norm, params["final_norm"], x)
        return layers.unembed_apply(params["embed"], x), jnp.zeros((), jnp.float32)

    # -- decode ---------------------------------------------------------------

    def init_cache(self, batch: int, cache_len: int,
                   n_frames: Optional[int] = None) -> Params:
        cfg = self.cfg
        n_frames = n_frames or cfg.stub_frames
        kv, dh, dt = cfg.n_kv_heads, cfg.d_head, cfg.compute_dtype
        layer_cache = {
            "k": jnp.zeros((batch, cache_len, kv, dh), dt),
            "v": jnp.zeros((batch, cache_len, kv, dh), dt),
            "cross_k": jnp.zeros((batch, n_frames, kv, dh), dt),
            "cross_v": jnp.zeros((batch, n_frames, kv, dh), dt),
        }
        return {"decoder": jax.tree.map(
            lambda a: jnp.zeros((cfg.n_layers,) + a.shape, a.dtype),
            layer_cache)}

    def prefill_cross(self, params: Params, cache: Params,
                      frames: jnp.ndarray) -> Params:
        """Populate the cross-attention KV from encoder output."""
        cfg = self.cfg
        enc = self.encode(params, frames)

        def body(_, inp):
            p, c = inp
            k = jnp.einsum("bsd,dhk->bshk", enc,
                           p["cross_attn"]["wk"].astype(enc.dtype))
            v = jnp.einsum("bsd,dhk->bshk", enc,
                           p["cross_attn"]["wv"].astype(enc.dtype))
            if cfg.qkv_bias:
                k = k + p["cross_attn"]["bk"].astype(enc.dtype)
                v = v + p["cross_attn"]["bv"].astype(enc.dtype)
            c = dict(c, cross_k=k.astype(c["cross_k"].dtype),
                     cross_v=v.astype(c["cross_v"].dtype))
            return None, c

        _, dec_cache = jax.lax.scan(body, None,
                                    (params["decoder"], cache["decoder"]))
        return {"decoder": dec_cache}

    def decode_step(self, params: Params, token: jnp.ndarray, cache: Params,
                    index: jnp.ndarray, *, prefix_len: int = 0
                    ) -> Tuple[jnp.ndarray, Params]:
        cfg = self.cfg
        x = layers.embed_apply(params["embed"], token, cfg.compute_dtype)
        pos = sinusoid_positions(jnp.full((1,), index), cfg.d_model)
        x = x + pos[None].astype(x.dtype)

        def body(xc, inp):
            p, c = inp
            h = layers.norm_apply(cfg.norm, p["norm1"], xc)
            y, upd = attention.attention_decode(
                p["self_attn"], h, cfg, {"k": c["k"], "v": c["v"]}, index,
                mask_kind="global", use_rope=False)
            xc = xc + y
            h = layers.norm_apply(cfg.norm, p["norm2"], xc)
            xc = xc + _cross_decode(p["cross_attn"], h, c["cross_k"],
                                    c["cross_v"], cfg)
            h = layers.norm_apply(cfg.norm, p["norm3"], xc)
            xc = xc + layers.mlp_apply(p["mlp"], h, activation=cfg.activation)
            return xc, dict(c, k=upd["k"], v=upd["v"])

        if cfg.scan_layers:
            x, dec_cache = jax.lax.scan(
                body, x, (params["decoder"], cache["decoder"]))
        else:  # unrolled (roofline accounting mode)
            outs = []
            for r in range(cfg.n_layers):
                sl = lambda l, r=r: l[r]
                x, c = body(x, (jax.tree.map(sl, params["decoder"]),
                                jax.tree.map(sl, cache["decoder"])))
                outs.append(c)
            dec_cache = jax.tree.map(lambda *ls: jnp.stack(ls), *outs)
        x = layers.norm_apply(cfg.norm, params["final_norm"], x)
        return layers.unembed_apply(params["embed"], x), {"decoder": dec_cache}


def _cross_decode(p: Params, x: jnp.ndarray, ck: jnp.ndarray, cv: jnp.ndarray,
                  cfg) -> jnp.ndarray:
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
    b, s, h, dh = q.shape
    kvh = ck.shape[2]
    qg = q.reshape(b, s, kvh, h // kvh, dh)
    logits = jnp.einsum("bqhgk,bshk->bhgqs", qg * dh ** -0.5,
                        ck.astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    out = jnp.einsum("bhgqs,bshk->bqhgk", probs,
                     cv.astype(x.dtype)).reshape(b, s, h, dh)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
