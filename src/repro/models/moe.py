"""Mixture-of-experts FFN with grouped capacity-based dispatch/combine.

The dispatch/combine formulation (Mesh-TensorFlow / MaxText style) keeps the
expert dimension explicit so it can be sharded over the ``model`` mesh axis
(expert parallelism, llama4's 128 experts) or kept replicated with ``d_ff``
sharded instead (expert-tensor hybrid, grok-1's 8 experts).

Tokens are routed within fixed-size *groups* (``MOE_GROUP`` tokens).  The
dispatch tensor is then (G, g, E, C) with C ∝ g·top_k/E, so its size and the
dispatch-einsum FLOPs stay *linear* in total tokens (≈1–2 % of the expert
matmul FLOPs) instead of quadratic as with per-sequence capacity.  Expert
compute scales with tokens × top_k × capacity_factor, never with E.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

Params = Dict[str, Any]

MOE_GROUP = 512  # tokens per routing group


def moe_init(key, cfg, *, dtype=jnp.float32) -> Params:
    d, ff, e = cfg.d_model, cfg.moe_d_ff, cfg.moe_experts
    ks = jax.random.split(key, 4)
    return {
        "router": layers.scaled_init(ks[0], (d, e), jnp.float32, fan_in=d),
        "w_gate": layers.scaled_init(ks[1], (e, d, ff), dtype, fan_in=d),
        "w_in": layers.scaled_init(ks[2], (e, d, ff), dtype, fan_in=d),
        "w_out": layers.scaled_init(ks[3], (e, ff, d), dtype, fan_in=ff),
    }


def _capacity(group: int, experts: int, top_k: int, factor: float) -> int:
    cap = int(group * top_k * factor / experts)
    cap = max(cap, 4)
    return cap + (-cap) % 4  # round up to a multiple of 4


def router_probs(params: Params, x: jnp.ndarray, top_k: int
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """x (G, g, d) -> (gate (G,g,k), expert_idx (G,g,k), aux_loss scalar)."""
    logits = jnp.einsum("gsd,de->gse", x.astype(jnp.float32), params["router"])
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = jax.lax.top_k(probs, top_k)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)
    # Switch-style load-balancing auxiliary loss.
    e = logits.shape[-1]
    me = jnp.mean(probs, axis=(0, 1))                            # avg router prob
    ce = jnp.mean(jax.nn.one_hot(idx[..., 0], e), axis=(0, 1))   # top-1 load
    aux = e * jnp.sum(me * ce)
    return gate, idx, aux


def moe_apply(params: Params, x: jnp.ndarray, cfg) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x (B, S, d) -> (y (B, S, d), aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.moe_experts, cfg.moe_top_k
    tokens = b * s
    g = min(MOE_GROUP, tokens)
    n_groups = tokens // g
    assert tokens % g == 0, f"tokens {tokens} not divisible by group {g}"
    cap = _capacity(g, e, k, cfg.moe_capacity_factor)

    xg = x.reshape(n_groups, g, d)
    gate, idx, aux = router_probs(params, xg, k)

    # Position of each (token, choice) within its expert's capacity buffer.
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32)             # (G,g,k,E)
    flat = onehot.reshape(n_groups, g * k, e)
    pos_in_expert = jnp.cumsum(flat, axis=1) - flat
    pos = jnp.sum(pos_in_expert.reshape(n_groups, g, k, e) * onehot, axis=-1)
    keep = pos < cap

    gate = gate * keep.astype(gate.dtype)
    pos_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap, dtype=gate.dtype)
    # combine[G,s,e,c] = sum_k gate * 1[idx==e] * 1[pos==c]
    combine = jnp.einsum("gsk,gske,gskc->gsec",
                         gate, onehot.astype(gate.dtype), pos_oh)
    dispatch = (combine > 0.0).astype(x.dtype)

    xe = jnp.einsum("gsec,gsd->egcd", dispatch, xg)              # (E,G,C,d)
    hg = jnp.einsum("egcd,edf->egcf", xe, params["w_gate"].astype(x.dtype))
    hi = jnp.einsum("egcd,edf->egcf", xe, params["w_in"].astype(x.dtype))
    h = jax.nn.silu(hg) * hi
    ye = jnp.einsum("egcf,efd->egcd", h, params["w_out"].astype(x.dtype))
    y = jnp.einsum("gsec,egcd->gsd", combine.astype(x.dtype), ye)
    return y.reshape(b, s, d), aux.astype(jnp.float32)
