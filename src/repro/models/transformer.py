"""Block-assembly decoder-only transformer covering dense / GQA / MoE /
SSM / hybrid / VLM architectures.

An architecture is a *pattern unit* — a short tuple of (sequence-mixer kind,
ffn kind) pairs — repeated ``n_layers // len(unit)`` times.  The repeated
unit is executed with ``lax.scan`` over stacked per-repetition parameters
(with optional per-unit remat), which keeps the HLO size O(unit) rather than
O(n_layers) and makes 512-device lowering of 80-layer models tractable.

Sequence-mixer kinds: ``attn`` (causal global), ``swa`` (sliding window),
``chunked`` (llama4 chunked-local), ``rec`` (RG-LRU), ``slstm``, ``mlstm``.
FFN kinds: ``dense``, ``moe``, ``none``.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import attention, layers, moe, rglru, xlstm

Params = Dict[str, Any]

ATTENTION_KINDS = ("attn", "swa", "chunked")
MASK_FOR_KIND = {"attn": "global", "swa": "sliding", "chunked": "chunked"}


def compute_stages(n_layers: int, pattern: Tuple[str, ...]
                   ) -> List[Tuple[Tuple[str, ...], int]]:
    """Split ``n_layers`` into (unit, repetitions) stages."""
    u = len(pattern)
    reps, rem = divmod(n_layers, u)
    stages = []
    if reps:
        stages.append((pattern, reps))
    if rem:
        stages.append((pattern[:rem], 1))
    return stages


class Transformer:
    """Pure-function model: ``init`` -> params pytree, ``apply`` -> logits."""

    def __init__(self, cfg):
        self.cfg = cfg
        pat = tuple(zip(cfg.block_pattern, cfg.ffn_pattern))
        self.stages = compute_stages(cfg.n_layers, pat)

    # -- initialisation -----------------------------------------------------

    def _layer_init(self, key, kind: str, ffn_kind: str) -> Params:
        cfg = self.cfg
        dtype = cfg.param_dtype
        ks = jax.random.split(key, 4)
        p: Params = {"norm1": layers.norm_init(cfg.norm, cfg.d_model, dtype)}
        if kind in ATTENTION_KINDS:
            p["attn"] = attention.attention_init(ks[0], cfg, dtype=dtype)
        elif kind == "rec":
            p["rec"] = rglru.rglru_block_init(ks[0], cfg, dtype=dtype)
        elif kind == "slstm":
            p["slstm"] = xlstm.slstm_block_init(ks[0], cfg, dtype=dtype)
        elif kind == "mlstm":
            p["mlstm"] = xlstm.mlstm_block_init(ks[0], cfg, dtype=dtype)
        else:
            raise ValueError(f"unknown sequence mixer {kind!r}")
        if ffn_kind == "dense":
            p["norm2"] = layers.norm_init(cfg.norm, cfg.d_model, dtype)
            p["mlp"] = layers.mlp_init(ks[1], cfg.d_model, cfg.d_ff,
                                       gated=cfg.gated_mlp, bias=cfg.mlp_bias,
                                       dtype=dtype)
        elif ffn_kind == "moe":
            p["norm2"] = layers.norm_init(cfg.norm, cfg.d_model, dtype)
            p["moe"] = moe.moe_init(ks[1], cfg, dtype=dtype)
        elif ffn_kind != "none":
            raise ValueError(f"unknown ffn kind {ffn_kind!r}")
        return p

    def _unit_init(self, key, unit) -> Params:
        ks = jax.random.split(key, len(unit))
        return {str(i): self._layer_init(ks[i], kind, ffn_kind)
                for i, (kind, ffn_kind) in enumerate(unit)}

    def init(self, key) -> Params:
        cfg = self.cfg
        ks = jax.random.split(key, len(self.stages) + 2)
        params: Params = {
            "embed": layers.embedding_init(ks[0], cfg.vocab_size, cfg.d_model,
                                           tie=cfg.tie_embeddings,
                                           dtype=cfg.param_dtype),
            "final_norm": layers.norm_init(cfg.norm, cfg.d_model,
                                           cfg.param_dtype),
        }
        for si, (unit, reps) in enumerate(self.stages):
            unit_keys = jax.random.split(ks[si + 1], reps)
            params[f"stage_{si}"] = jax.vmap(
                functools.partial(self._unit_init, unit=unit))(unit_keys)
        return params

    # -- forward (train / prefill) -------------------------------------------

    def _layer_apply(self, p: Params, x, kind, ffn_kind, *, positions,
                     prefix_len, aux):
        cfg = self.cfg
        h = layers.norm_apply(cfg.norm, p["norm1"], x)
        if kind in ATTENTION_KINDS:
            mask_kind = MASK_FOR_KIND[kind]
            if kind == "attn" and prefix_len > 0:
                mask_kind = "prefix"
            use_rope = cfg.rope_on_global if kind == "attn" else True
            y = attention.attention_apply(p["attn"], h, cfg,
                                          mask_kind=mask_kind,
                                          positions=positions,
                                          use_rope=use_rope,
                                          prefix_len=prefix_len)
        elif kind == "rec":
            y = rglru.rglru_block_apply(p["rec"], h, cfg)
        elif kind == "slstm":
            y = xlstm.slstm_block_apply(p["slstm"], h, cfg)
        else:  # mlstm
            y = xlstm.mlstm_block_apply(p["mlstm"], h, cfg)
        x = x + y
        if ffn_kind == "dense":
            h = layers.norm_apply(cfg.norm, p["norm2"], x)
            x = x + layers.mlp_apply(p["mlp"], h, activation=cfg.activation)
        elif ffn_kind == "moe":
            h = layers.norm_apply(cfg.norm, p["norm2"], x)
            y, aux_inc = moe.moe_apply(p["moe"], h, cfg)
            x = x + y
            aux = aux + aux_inc
        return x, aux

    def _unit_apply(self, p: Params, x, unit, *, positions, prefix_len, aux):
        for i, (kind, ffn_kind) in enumerate(unit):
            x, aux = self._layer_apply(p[str(i)], x, kind, ffn_kind,
                                       positions=positions,
                                       prefix_len=prefix_len, aux=aux)
        return x, aux

    def apply(self, params: Params, tokens: jnp.ndarray, *,
              extra_embeddings: Optional[jnp.ndarray] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """tokens (B, S_text) [+ prefix embeddings (B, P, d)] -> (logits, aux).

        For VLM configs ``extra_embeddings`` holds the stubbed patch
        embeddings; they are prepended and attended bidirectionally
        (prefix-LM).  Logits cover only the text positions.
        """
        cfg = self.cfg
        x = layers.embed_apply(params["embed"], tokens, cfg.compute_dtype)
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        prefix_len = 0
        if extra_embeddings is not None:
            prefix_len = extra_embeddings.shape[1]
            x = jnp.concatenate([extra_embeddings.astype(x.dtype), x], axis=1)
        positions = jnp.arange(x.shape[1])
        aux = jnp.zeros((), jnp.float32)

        for si, (unit, reps) in enumerate(self.stages):
            def body(carry, rep_params, unit=unit):
                xc, auxc = carry
                xc, auxc = self._unit_apply(rep_params, xc, unit,
                                            positions=positions,
                                            prefix_len=prefix_len, aux=auxc)
                return (xc, auxc), None

            if self.cfg.remat:
                body = jax.checkpoint(
                    body, policy=jax.checkpoint_policies.nothing_saveable)
            if self.cfg.scan_layers:
                (x, aux), _ = jax.lax.scan(body, (x, aux),
                                           params[f"stage_{si}"])
            else:  # unrolled (roofline accounting mode)
                for r in range(reps):
                    rp = jax.tree.map(lambda l, r=r: l[r],
                                      params[f"stage_{si}"])
                    (x, aux), _ = body((x, aux), rp)

        x = layers.norm_apply(cfg.norm, params["final_norm"], x)
        if prefix_len:
            x = x[:, prefix_len:]
        logits = layers.unembed_apply(params["embed"], x)
        return logits, aux

    # -- decode ---------------------------------------------------------------

    def _layer_cache(self, kind, ffn_kind, batch, cache_len):
        cfg = self.cfg
        dtype = cfg.compute_dtype
        if kind in ATTENTION_KINDS:
            return attention.init_cache(cfg, batch, cache_len,
                                        MASK_FOR_KIND[kind], dtype)
        if kind == "rec":
            return rglru.init_cache(cfg, batch, dtype)
        if kind == "slstm":
            return xlstm.slstm_init_cache(cfg, batch, dtype)
        return xlstm.mlstm_init_cache(cfg, batch, dtype)

    def init_cache(self, batch: int, cache_len: int) -> Params:
        cache: Params = {}
        for si, (unit, reps) in enumerate(self.stages):
            unit_cache = {str(i): self._layer_cache(kind, ffn_kind, batch,
                                                    cache_len)
                          for i, (kind, ffn_kind) in enumerate(unit)}
            cache[f"stage_{si}"] = jax.tree.map(
                lambda a: jnp.zeros((reps,) + a.shape, a.dtype), unit_cache)
        return cache

    def _layer_decode(self, p, x, kind, ffn_kind, cache, index, prefix_len):
        cfg = self.cfg
        h = layers.norm_apply(cfg.norm, p["norm1"], x)
        if kind in ATTENTION_KINDS:
            mask_kind = MASK_FOR_KIND[kind]
            if kind == "attn" and prefix_len > 0:
                mask_kind = "prefix"
            use_rope = cfg.rope_on_global if kind == "attn" else True
            y, cache = attention.attention_decode(p["attn"], h, cfg, cache,
                                                  index, mask_kind=mask_kind,
                                                  use_rope=use_rope,
                                                  prefix_len=prefix_len)
        elif kind == "rec":
            y, cache = rglru.rglru_block_decode(p["rec"], h, cfg, cache)
        elif kind == "slstm":
            y, cache = xlstm.slstm_block_decode(p["slstm"], h, cfg, cache)
        else:
            y, cache = xlstm.mlstm_block_decode(p["mlstm"], h, cfg, cache)
        x = x + y
        if ffn_kind == "dense":
            h = layers.norm_apply(cfg.norm, p["norm2"], x)
            x = x + layers.mlp_apply(p["mlp"], h, activation=cfg.activation)
        elif ffn_kind == "moe":
            h = layers.norm_apply(cfg.norm, p["norm2"], x)
            y, _ = moe.moe_apply(p["moe"], h, cfg)
            x = x + y
        return x, cache

    def prefill_prefix(self, params: Params, cache: Params,
                       embeddings: jnp.ndarray) -> Params:
        """Populate decode caches from the multimodal prefix (VLM serving).

        Runs the prefix embeddings through the stack with the prefix-LM mask
        (bidirectional within the prefix — prefix hidden states depend only
        on the prefix) and writes each attention layer's K/V into cache
        slots [0, P).  Only attention mixers are supported — the VLM config
        has no recurrent layers.
        """
        cfg = self.cfg
        p_len = embeddings.shape[1]
        positions = jnp.arange(p_len)
        x = embeddings.astype(cfg.compute_dtype)
        new_cache: Params = {}
        for si, (unit, reps) in enumerate(self.stages):
            def body(xc, inp, unit=unit):
                rep_params, rep_cache = inp
                out_cache = {}
                for i, (kind, ffn_kind) in enumerate(unit):
                    assert kind in ATTENTION_KINDS, \
                        "prefix prefill supports attention mixers only"
                    p = rep_params[str(i)]
                    c = rep_cache[str(i)]
                    h = layers.norm_apply(cfg.norm, p["norm1"], xc)
                    use_rope = cfg.rope_on_global if kind == "attn" else True
                    q, k, v = attention._qkv(p["attn"], h, cfg)
                    if use_rope:
                        q = layers.apply_rope(q, positions, cfg.rope_theta)
                        k = layers.apply_rope(k, positions, cfg.rope_theta)
                    # bidirectional among prefix positions (prefix-LM)
                    out = attention._sdpa(q, k, v, positions, positions,
                                          "prefix", prefix_len=p_len)
                    y = jnp.einsum("bshk,hkd->bsd", out,
                                   p["attn"]["wo"].astype(xc.dtype))
                    xc = xc + y
                    ck = jax.lax.dynamic_update_slice(
                        c["k"], k.astype(c["k"].dtype), (0, 0, 0, 0))
                    cv = jax.lax.dynamic_update_slice(
                        c["v"], v.astype(c["v"].dtype), (0, 0, 0, 0))
                    out_cache[str(i)] = {"k": ck, "v": cv}
                    if ffn_kind == "dense":
                        h = layers.norm_apply(cfg.norm, p["norm2"], xc)
                        xc = xc + layers.mlp_apply(p["mlp"], h,
                                                   activation=cfg.activation)
                    elif ffn_kind == "moe":
                        h = layers.norm_apply(cfg.norm, p["norm2"], xc)
                        y, _ = moe.moe_apply(p["moe"], h, cfg)
                        xc = xc + y
                return xc, out_cache

            x, new_cache[f"stage_{si}"] = jax.lax.scan(
                body, x, (params[f"stage_{si}"], cache[f"stage_{si}"]))
        return new_cache

    def decode_step(self, params: Params, token: jnp.ndarray, cache: Params,
                    index: jnp.ndarray, *, prefix_len: int = 0
                    ) -> Tuple[jnp.ndarray, Params]:
        """token (B, 1) + cache + scalar index -> (logits (B, 1, V), cache)."""
        cfg = self.cfg
        x = layers.embed_apply(params["embed"], token, cfg.compute_dtype)
        if cfg.embed_scale:
            x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)
        new_cache: Params = {}
        for si, (unit, reps) in enumerate(self.stages):
            def body(xc, inp, unit=unit):
                rep_params, rep_cache = inp
                out_cache = {}
                for i, (kind, ffn_kind) in enumerate(unit):
                    xc, out_cache[str(i)] = self._layer_decode(
                        rep_params[str(i)], xc, kind, ffn_kind,
                        rep_cache[str(i)], index, prefix_len)
                return xc, out_cache

            if self.cfg.scan_layers:
                x, new_cache[f"stage_{si}"] = jax.lax.scan(
                    body, x, (params[f"stage_{si}"], cache[f"stage_{si}"]))
            else:  # unrolled (roofline accounting mode)
                outs = []
                for r in range(reps):
                    sl = lambda l, r=r: l[r]
                    x, c = body(x, (jax.tree.map(sl, params[f"stage_{si}"]),
                                    jax.tree.map(sl, cache[f"stage_{si}"])))
                    outs.append(c)
                new_cache[f"stage_{si}"] = jax.tree.map(
                    lambda *ls: jnp.stack(ls), *outs)
        x = layers.norm_apply(cfg.norm, params["final_norm"], x)
        logits = layers.unembed_apply(params["embed"], x)
        return logits, new_cache


def loss_fn(model: Transformer, params: Params, batch: Dict[str, jnp.ndarray]
            ) -> jnp.ndarray:
    """Next-token cross entropy + MoE aux loss."""
    cfg = model.cfg
    logits, aux = model.apply(params, batch["tokens"],
                              extra_embeddings=batch.get("embeddings"))
    loss = layers.softmax_cross_entropy(logits, batch["labels"],
                                        batch.get("loss_mask"))
    return loss + cfg.moe_aux_weight * aux
