"""Real-Gated Linear Recurrent Unit block (Griffin / RecurrentGemma).

The recurrent block follows arXiv:2402.19427: a gated branch structure with a
temporal (causal) conv and the RG-LRU diagonal recurrence

    r_t = sigmoid(W_a x_t + b_a)            # recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            # input gate
    a_t = a^(c * r_t)   with a = sigmoid(Λ) # per-channel decay, c = 8
    h_t = a_t * h_{t-1} + sqrt(1 - a_t²) * (i_t * x_t)

The sequential scan is the TPU hot-spot; :mod:`repro.kernels.linear_recurrence`
provides the Pallas kernel and this module uses the jnp oracle formulation
(``jax.lax.associative_scan`` for training, a one-step update for decode).
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

Params = Dict[str, Any]

_C = 8.0  # temperature of the decay exponent (Griffin appendix)
_CONV_WIDTH = 4


def rglru_block_init(key, cfg, *, dtype=jnp.float32) -> Params:
    d = cfg.d_model
    dr = cfg.rnn_width or d
    ks = jax.random.split(key, 8)
    # Λ init so that a = sigmoid(Λ)^(1/c) is distributed in [0.9, 0.999].
    u = jax.random.uniform(ks[0], (dr,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(u ** _C / (1.0 - u ** _C))
    return {
        "w_in": layers.scaled_init(ks[1], (d, dr), dtype, fan_in=d),
        "w_gate_branch": layers.scaled_init(ks[2], (d, dr), dtype, fan_in=d),
        "conv_w": layers.normal_init(ks[3], (_CONV_WIDTH, dr), dtype),
        "conv_b": jnp.zeros((dr,), dtype),
        "w_a": layers.scaled_init(ks[4], (dr, dr), dtype, fan_in=dr),
        "b_a": jnp.zeros((dr,), jnp.float32),
        "w_x": layers.scaled_init(ks[5], (dr, dr), dtype, fan_in=dr),
        "b_x": jnp.zeros((dr,), jnp.float32),
        "lambda": lam,
        "w_out": layers.scaled_init(ks[6], (dr, d), dtype, fan_in=dr),
    }


def _gates(params: Params, x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (log_a, gated_input) both (..., dr), computed in fp32."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["w_a"].astype(jnp.float32) + params["b_a"])
    i = jax.nn.sigmoid(xf @ params["w_x"].astype(jnp.float32) + params["b_x"])
    log_a = -_C * r * jax.nn.softplus(-params["lambda"])  # log sigmoid(Λ)^(c·r)
    a_sq = jnp.exp(2.0 * log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - a_sq, 1e-12)) * (i * xf)
    return log_a, gated


def rglru_scan(log_a: jnp.ndarray, gated: jnp.ndarray,
               h0: jnp.ndarray | None = None) -> jnp.ndarray:
    """Associative scan of h_t = exp(log_a_t)·h_{t-1} + gated_t over axis 1.

    log_a, gated: (B, S, dr) fp32.  Returns (B, S, dr).
    """
    if h0 is not None:
        gated = gated.at[:, 0].add(jnp.exp(log_a[:, 0]) * h0)

    def combine(left, right):
        la, xa = left
        lb, xb = right
        return la + lb, jnp.exp(lb) * xa + xb

    _, h = jax.lax.associative_scan(combine, (log_a, gated), axis=1)
    return h


def _causal_conv(params: Params, x: jnp.ndarray,
                 state: jnp.ndarray | None = None) -> jnp.ndarray:
    """Depthwise causal conv of width 4 along axis 1."""
    w = params["conv_w"].astype(x.dtype)  # (W, dr)
    pad = jnp.zeros((x.shape[0], _CONV_WIDTH - 1, x.shape[-1]), x.dtype) \
        if state is None else state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)
    out = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(_CONV_WIDTH))
    return out + params["conv_b"].astype(x.dtype)


def rglru_block_apply(params: Params, x: jnp.ndarray, cfg) -> jnp.ndarray:
    """Training / prefill forward.  x (B, S, d) -> (B, S, d)."""
    main = jnp.einsum("bsd,dr->bsr", x, params["w_in"].astype(x.dtype))
    gate_branch = jax.nn.gelu(
        jnp.einsum("bsd,dr->bsr", x, params["w_gate_branch"].astype(x.dtype)))
    main = _causal_conv(params, main)
    log_a, gated = _gates(params, main)
    h = rglru_scan(log_a, gated).astype(x.dtype)
    y = h * gate_branch
    return jnp.einsum("bsr,rd->bsd", y, params["w_out"].astype(x.dtype))


def init_cache(cfg, batch: int, dtype) -> Params:
    dr = cfg.rnn_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, dr), jnp.float32),
        "conv": jnp.zeros((batch, _CONV_WIDTH - 1, dr), dtype),
    }


def rglru_block_decode(params: Params, x: jnp.ndarray, cfg, cache: Params
                       ) -> Tuple[jnp.ndarray, Params]:
    """One-token step.  x (B, 1, d)."""
    main = jnp.einsum("bsd,dr->bsr", x, params["w_in"].astype(x.dtype))
    gate_branch = jax.nn.gelu(
        jnp.einsum("bsd,dr->bsr", x, params["w_gate_branch"].astype(x.dtype)))
    conv_in = jnp.concatenate([cache["conv"].astype(x.dtype), main], axis=1)
    w = params["conv_w"].astype(x.dtype)
    conv_out = jnp.einsum("bwr,wr->br", conv_in, w)[:, None, :] \
        + params["conv_b"].astype(x.dtype)
    log_a, gated = _gates(params, conv_out)
    h = jnp.exp(log_a[:, 0]) * cache["h"] + gated[:, 0]
    y = h[:, None, :].astype(x.dtype) * gate_branch
    out = jnp.einsum("bsr,rd->bsd", y, params["w_out"].astype(x.dtype))
    new_cache = {"h": h, "conv": conv_in[:, 1:].astype(cache["conv"].dtype)}
    return out, new_cache
