"""Core neural-net building blocks shared by every architecture.

Everything here is a pure-function pair: ``*_init(key, ...) -> params`` and
``*_apply(params, x, ...) -> y``.  Params are plain nested dicts (pytrees) so
they compose with pjit/shard_map and with the stacked-scan layer layout used
by :mod:`repro.models.transformer`.
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Initialisers
# ---------------------------------------------------------------------------

def normal_init(key, shape, dtype, stddev: float = 0.02):
    return (stddev * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def scaled_init(key, shape, dtype, fan_in: Optional[int] = None):
    """Truncated-normal-ish init scaled by 1/sqrt(fan_in)."""
    fan_in = fan_in if fan_in is not None else shape[-2] if len(shape) >= 2 else shape[-1]
    stddev = 1.0 / math.sqrt(max(fan_in, 1))
    return (stddev * jax.random.normal(key, shape, jnp.float32)).astype(dtype)


# ---------------------------------------------------------------------------
# Normalisation
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(params: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dtype)


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_apply(params: Params, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return y.astype(dtype)


def norm_init(kind: str, d: int, dtype=jnp.float32) -> Params:
    if kind == "rmsnorm":
        return rmsnorm_init(d, dtype)
    if kind == "layernorm":
        return layernorm_init(d, dtype)
    raise ValueError(f"unknown norm kind {kind!r}")


def norm_apply(kind: str, params: Params, x: jnp.ndarray) -> jnp.ndarray:
    if kind == "rmsnorm":
        return rmsnorm_apply(params, x)
    if kind == "layernorm":
        return layernorm_apply(params, x)
    raise ValueError(f"unknown norm kind {kind!r}")


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    """Inverse frequencies, shape (d_head // 2,)."""
    exponent = jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head
    return 1.0 / (theta ** exponent)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotate ``x`` of shape (..., S, H, Dh) by absolute ``positions`` (..., S)."""
    d_head = x.shape[-1]
    freqs = rope_freqs(d_head, theta)  # (Dh/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, Dh/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense / gated MLP
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, *, gated: bool = True,
             bias: bool = False, dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 3)
    p: Params = {
        "w_in": scaled_init(ks[0], (d_model, d_ff), dtype, fan_in=d_model),
        "w_out": scaled_init(ks[1], (d_ff, d_model), dtype, fan_in=d_ff),
    }
    if gated:
        p["w_gate"] = scaled_init(ks[2], (d_model, d_ff), dtype, fan_in=d_model)
    if bias:
        p["b_in"] = jnp.zeros((d_ff,), dtype)
        p["b_out"] = jnp.zeros((d_model,), dtype)
    return p


def mlp_apply(params: Params, x: jnp.ndarray, *, activation: str = "silu") -> jnp.ndarray:
    act = {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[activation]
    dt = x.dtype
    h = jnp.einsum("...d,df->...f", x, params["w_in"].astype(dt))
    if "b_in" in params:
        h = h + params["b_in"].astype(dt)
    if "w_gate" in params:
        g = jnp.einsum("...d,df->...f", x, params["w_gate"].astype(dt))
        h = act(g) * h
    else:
        h = act(h)
    out = jnp.einsum("...f,fd->...d", h, params["w_out"].astype(dt))
    if "b_out" in params:
        out = out + params["b_out"].astype(dt)
    return out


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def embedding_init(key, vocab: int, d_model: int, *, tie: bool,
                   dtype=jnp.float32) -> Params:
    ks = jax.random.split(key, 2)
    p: Params = {"embedding": normal_init(ks[0], (vocab, d_model), dtype)}
    if not tie:
        p["unembedding"] = normal_init(ks[1], (vocab, d_model), dtype)
    return p


def embed_apply(params: Params, tokens: jnp.ndarray, compute_dtype) -> jnp.ndarray:
    return jnp.take(params["embedding"], tokens, axis=0).astype(compute_dtype)


def unembed_apply(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    table = params.get("unembedding", params["embedding"])
    return jnp.einsum("...d,vd->...v", x, table.astype(x.dtype))


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                          mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean token-level cross entropy.  ``logits`` (..., V), ``labels`` (...,).

    The label log-prob is extracted with an iota-mask reduction instead of
    ``take_along_axis``: a gather along a vocab dimension that is sharded
    over the ``model`` mesh axis forces the SPMD partitioner to all-gather
    the full (B, S, V) logits per device (≈40 GB for the 4k-train shapes),
    while the masked reduction stays elementwise over the local shard and
    reduces with a cheap psum (§Perf iteration 1).
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    ll = jnp.sum(jnp.where(vocab_iota == labels[..., None], logits, 0.0),
                 axis=-1)
    nll = logz - ll
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
