"""Model zoo: block-assembly transformer family + enc-dec + the paper's MLP."""
from __future__ import annotations


def build_model(cfg):
    """Return the model object (init/apply/init_cache/decode_step) for a config."""
    from repro.models.encdec import EncDecTransformer
    from repro.models.transformer import Transformer

    if cfg.encoder_layers > 0:
        return EncDecTransformer(cfg)
    return Transformer(cfg)
