"""Attention layer supporting every assigned architecture.

Features: grouped-query attention (any ``n_kv_heads`` dividing ``n_heads``,
including MQA), optional QKV bias (qwen1.5), per-head q/k RMSNorm (qwen3),
RoPE or NoPE (llama4 global layers), and three mask families:

* ``global``   — causal full attention,
* ``sliding``  — causal sliding-window of width ``window`` (recurrentgemma,
                 beyond-paper dense serve variant),
* ``chunked``  — llama4-style chunked local attention (attend within the own
                 chunk only, causally),
* ``prefix``   — prefix-LM mask (paligemma: bidirectional over the multimodal
                 prefix, causal afterwards).

Long sequences use a query-chunked formulation (``lax.scan`` over query
blocks) so the (S, S) score matrix is never materialised — the XLA analogue
of flash attention; the Pallas kernel in :mod:`repro.kernels.flash_attention`
is the TPU hot-spot implementation validated against the same oracle.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

Params = Dict[str, Any]

NEG_INF = -2.0e38

# Query-chunk size used when S exceeds the chunking threshold.
_Q_CHUNK = 1024
_CHUNK_THRESHOLD = 2048


def _constrain(x, spec_dims):
    """Best-effort with_sharding_constraint: no-op without an ambient mesh.

    Used for context parallelism (``cfg.attn_seq_shard``): architectures
    whose head count does not divide the ``model`` mesh axis (yi-34b: 56,
    whisper: 20) shard the attention over the QUERY SEQUENCE instead —
    scores stay local per seq-shard and only the small K/V tensors
    replicate (§Perf iteration 2)."""
    try:
        from jax.sharding import PartitionSpec as P
        return jax.lax.with_sharding_constraint(x, P(*spec_dims))
    except Exception:
        return x


def _seq_shard_qkv(q, k, v):
    U = jax.sharding.PartitionSpec.UNCONSTRAINED
    q = _constrain(q, (U, "model", U, U))       # (B, S/model, H, dh)
    k = _constrain(k, (U, None, U, U))          # full-seq K/V per device
    v = _constrain(v, (U, None, U, U))
    return q, k, v


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def attention_init(key, cfg, *, dtype=jnp.float32) -> Params:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    ks = jax.random.split(key, 6)
    p: Params = {
        "wq": layers.scaled_init(ks[0], (d, h, dh), dtype, fan_in=d),
        "wk": layers.scaled_init(ks[1], (d, kv, dh), dtype, fan_in=d),
        "wv": layers.scaled_init(ks[2], (d, kv, dh), dtype, fan_in=d),
        "wo": layers.scaled_init(ks[3], (h, dh, d), dtype, fan_in=h * dh),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, dh), dtype)
        p["bk"] = jnp.zeros((kv, dh), dtype)
        p["bv"] = jnp.zeros((kv, dh), dtype)
    if cfg.qk_norm:
        p["q_norm"] = layers.rmsnorm_init(dh, dtype)
        p["k_norm"] = layers.rmsnorm_init(dh, dtype)
    return p


# ---------------------------------------------------------------------------
# Masking helpers (computed from positions — never materialised as inputs)
# ---------------------------------------------------------------------------

def mask_logits(logits: jnp.ndarray, q_pos: jnp.ndarray, k_pos: jnp.ndarray,
                kind: str, *, window: int = 0, chunk: int = 0,
                prefix_len: int = 0, k_valid: Optional[jnp.ndarray] = None
                ) -> jnp.ndarray:
    """Apply the mask family ``kind`` to ``logits`` (..., Q, K)."""
    qp = q_pos[:, None]
    kp = k_pos[None, :]
    causal = kp <= qp
    if kind == "global":
        allowed = causal
    elif kind == "sliding":
        allowed = causal & (kp > qp - window)
    elif kind == "chunked":
        allowed = causal & ((kp // chunk) == (qp // chunk))
    elif kind == "prefix":
        allowed = causal | (kp < prefix_len)
    else:
        raise ValueError(f"unknown mask kind {kind!r}")
    if k_valid is not None:
        allowed = allowed & k_valid[None, :]
    return jnp.where(allowed, logits, NEG_INF)


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------

def _qkv(params: Params, x: jnp.ndarray, cfg) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, params["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    if cfg.qk_norm:
        q = layers.rmsnorm_apply(params["q_norm"], q)
        k = layers.rmsnorm_apply(params["k_norm"], k)
    return q, k, v


def _sdpa(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, q_pos, k_pos,
          mask_kind: str, *, window=0, chunk=0, prefix_len=0,
          k_valid=None) -> jnp.ndarray:
    """q (B,Q,H,Dh), k/v (B,K,KV,Dh) -> (B,Q,H,Dh).  GQA via head reshape."""
    b, qlen, h, dh = q.shape
    kv = k.shape[2]
    group = h // kv
    scale = dh ** -0.5
    qg = q.reshape(b, qlen, kv, group, dh)
    logits = jnp.einsum("bqhgk,bshk->bhgqs", qg * scale, k).astype(jnp.float32)
    # mask_logits broadcasts over leading (b, kv, group) dims.
    masked = mask_logits(logits, q_pos, k_pos, mask_kind, window=window,
                         chunk=chunk, prefix_len=prefix_len, k_valid=k_valid)
    probs = jax.nn.softmax(masked, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqs,bshk->bqhgk", probs, v)
    return out.reshape(b, qlen, h, dh)


def _chunked_sdpa(q, k, v, positions, mask_kind, *, window=0, chunk=0,
                  prefix_len=0) -> jnp.ndarray:
    """lax.scan over query chunks — bounds transient memory to (chunk, S)."""
    b, s, h, dh = q.shape
    n_chunks = s // _Q_CHUNK
    qs = q.reshape(b, n_chunks, _Q_CHUNK, h, dh).transpose(1, 0, 2, 3, 4)
    pos = positions.reshape(n_chunks, _Q_CHUNK)

    def body(_, inp):
        qc, pc = inp
        out = _sdpa(qc, k, v, pc, positions, mask_kind, window=window,
                    chunk=chunk, prefix_len=prefix_len)
        return None, out

    _, outs = jax.lax.scan(body, None, (qs, pos))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, dh)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def attention_apply(params: Params, x: jnp.ndarray, cfg, *, mask_kind: str,
                    positions: Optional[jnp.ndarray] = None,
                    use_rope: bool = True, prefix_len: int = 0) -> jnp.ndarray:
    """Full-sequence (training / prefill) attention."""
    b, s, _ = x.shape
    if positions is None:
        positions = jnp.arange(s)
    q, k, v = _qkv(params, x, cfg)
    if use_rope:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    if getattr(cfg, "attn_seq_shard", False):
        q, k, v = _seq_shard_qkv(q, k, v)
    window = cfg.window or 0
    chunk = cfg.attn_chunk or 0
    if s > _CHUNK_THRESHOLD and s % _Q_CHUNK == 0 \
            and not getattr(cfg, "attn_seq_shard", False):
        out = _chunked_sdpa(q, k, v, positions, mask_kind, window=window,
                            chunk=chunk, prefix_len=prefix_len)
    else:
        out = _sdpa(q, k, v, positions, positions, mask_kind, window=window,
                    chunk=chunk, prefix_len=prefix_len)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


def init_cache(cfg, batch: int, cache_len: int, mask_kind: str,
               dtype) -> Params:
    """Allocate a decode KV cache for one attention layer.

    ``sliding``/``chunked`` layers use a ring buffer of the window/chunk size;
    ``global``/``prefix`` layers hold the full ``cache_len``.
    """
    if mask_kind == "sliding":
        size = min(cfg.window, cache_len)
    elif mask_kind == "chunked":
        size = min(cfg.attn_chunk, cache_len)
    else:
        size = cache_len
    kv, dh = cfg.n_kv_heads, cfg.d_head
    cache_dtype = getattr(cfg, "kv_cache_dtype", dtype)
    return {
        "k": jnp.zeros((batch, size, kv, dh), cache_dtype),
        "v": jnp.zeros((batch, size, kv, dh), cache_dtype),
    }


def attention_decode(params: Params, x: jnp.ndarray, cfg, cache: Params,
                     index: jnp.ndarray, *, mask_kind: str,
                     use_rope: bool = True, prefix_len: int = 0
                     ) -> Tuple[jnp.ndarray, Params]:
    """One-token decode step.  ``x`` (B, 1, d); ``index`` scalar position."""
    q, k, v = _qkv(params, x, cfg)
    pos = jnp.full((1,), index, jnp.int32)
    if use_rope:
        q = layers.apply_rope(q, pos, cfg.rope_theta)
        k = layers.apply_rope(k, pos, cfg.rope_theta)
    size = cache["k"].shape[1]
    slot = index % size
    ck = jax.lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                      (0, slot, 0, 0))
    cv = jax.lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                      (0, slot, 0, 0))
    # Validity + effective key positions for ring buffers.  Keys are cached
    # post-RoPE so no re-rotation is needed at read time.
    slots = jnp.arange(size)
    written = jnp.minimum(index + 1, size)
    k_valid = slots < written
    if mask_kind == "chunked":
        # Ring of size `chunk`: the chunk boundary resets the ring logically —
        # only slots belonging to the current chunk are visible.
        chunk = size
        chunk_start = (index // chunk) * chunk
        slot_pos = chunk_start + slots
        k_valid = k_valid & (slot_pos <= index)
        k_pos = slot_pos
    elif mask_kind == "sliding":
        # slot holds absolute position p where p % size == slot and p <= index.
        cand = (index // size) * size + slots
        k_pos = jnp.where(cand <= index, cand, cand - size)
        k_valid = k_valid & (k_pos > index - cfg.window) & (k_pos >= 0)
    else:
        k_pos = slots
    out = _sdpa(q, ck.astype(q.dtype), cv.astype(q.dtype), pos, k_pos,
                "prefix" if mask_kind == "prefix" else "global",
                prefix_len=prefix_len, k_valid=k_valid)
    y = jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
    return y, {"k": ck, "v": cv}


def cross_attention_init(key, cfg, *, dtype=jnp.float32) -> Params:
    return attention_init(key, cfg, dtype=dtype)


def cross_attention_apply(params: Params, x: jnp.ndarray, kv_src: jnp.ndarray,
                          cfg) -> jnp.ndarray:
    """Encoder-decoder cross attention (whisper).  No masking, no RoPE."""
    q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", kv_src.astype(x.dtype), params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", kv_src.astype(x.dtype), params["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + params["bq"].astype(x.dtype)
        k = k + params["bk"].astype(x.dtype)
        v = v + params["bv"].astype(x.dtype)
    b, s, h, dh = q.shape
    kvh = k.shape[2]
    qg = q.reshape(b, s, kvh, h // kvh, dh)
    logits = jnp.einsum("bqhgk,bshk->bhgqs", qg * dh ** -0.5, k).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqs,bshk->bqhgk", probs, v).reshape(b, s, h, dh)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))


def bidirectional_attention_apply(params: Params, x: jnp.ndarray, cfg,
                                  *, use_rope: bool = True) -> jnp.ndarray:
    """Unmasked self attention (whisper encoder / SigLIP-style stubs)."""
    b, s, _ = x.shape
    positions = jnp.arange(s)
    q, k, v = _qkv(params, x, cfg)
    if use_rope:
        q = layers.apply_rope(q, positions, cfg.rope_theta)
        k = layers.apply_rope(k, positions, cfg.rope_theta)
    kvh = k.shape[2]
    h, dh = q.shape[2], q.shape[3]
    qg = q.reshape(b, s, kvh, h // kvh, dh)
    logits = jnp.einsum("bqhgk,bshk->bhgqs", qg * dh ** -0.5, k).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqs,bshk->bqhgk", probs, v).reshape(b, s, h, dh)
    return jnp.einsum("bshk,hkd->bsd", out, params["wo"].astype(x.dtype))
