"""In-scan round telemetry (DESIGN.md §10).

Three pieces, all gated by the static ``EngineSpec.telemetry`` flag so the
disabled path is structurally absent from the engine's programs:

* ``trace``  — the ``RoundTrace`` pytree of per-stage observables riding
  the scan outputs next to ``RoundMetrics`` (Eq. 23a cost decomposition,
  association/scheduler internals, SIC decode depth, staleness histogram);
* ``sink``   — host-side sinks (JSONL, in-memory) fed from inside the
  jitted drivers via ``jax.debug.callback``, plus the pure collect mode;
* ``spans``  — named profiler spans around the paper stages and the
  ``jax.profiler.trace`` capture helper behind ``benchmarks/run.py
  --profile``.

``sink`` imports the engine, so it is NOT re-exported here (the engine
imports ``trace``/``spans``); import it explicitly::

    from repro.telemetry import sink
"""
from repro.telemetry import spans, trace
from repro.telemetry.trace import RoundTrace, STALE_BIN_EDGES, round_trace

__all__ = ["RoundTrace", "STALE_BIN_EDGES", "round_trace", "spans", "trace"]
