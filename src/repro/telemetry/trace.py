"""The per-round trace pytree (DESIGN.md §10.1).

``RoundTrace`` carries the quantities the paper optimises but
``RoundMetrics`` collapses to two scalars — the Eq. 23a time/energy bill
split by term, the deferred-acceptance and PDD convergence counters, the
candidate-frontier health, the NOMA SIC decode depth and a staleness
histogram — as plain jnp leaves, so a ``lax.scan`` stacks it along the
rounds axis and ``vmap``/sharding treat it like any other output pytree.

Everything here is a cheap elementwise epilogue over tensors the round
already computed (``rc_all.client_time_s``, the association one-hot, the
scheduler result): building the trace re-runs no stage.  The decomposition
identity is exact by construction and pinned in tests/test_telemetry.py::

    energy_local_j + energy_uplink_j + energy_cloud_j == total_energy_j
    max over selected edges ≤ time_local_s + time_uplink_s + time_cloud_s
"""
from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax.numpy as jnp

from repro.core import cost
from repro.core.candidates import CandidateSet

# Staleness histogram bucket LOWER edges: bucket b counts clients with
# A_n in [edge_b, edge_{b+1})   (A_n ≥ 1 by Eq. 20; the last bucket is
# open-ended).  Static, so the (8,) histogram leaf has a fixed shape.
STALE_BIN_EDGES = (1, 2, 3, 4, 5, 6, 8, 12)


class RoundTrace(NamedTuple):
    """Per-round, per-stage observables (jnp leaves; scan-stackable).

    Cost decomposition (the Eq. 23a bill split by term, all restricted to
    the billed set — clients on z-selected edges):

    * ``time_local_s``    — τ₂ · max billed t_cmp (Eqs. 4-5 compute term)
    * ``time_uplink_s``   — τ₂ · max billed t_com (Eqs. 7-10 NOMA uplink)
    * ``time_cloud_s``    — the Eq. 15 edge→cloud OFDMA hop
    * ``energy_*_j``      — the matching Σ-shaped energy terms; they sum
      exactly to ``RoundMetrics.total_energy_j``

    Association internals:

    * ``assoc_sweeps``    — deferred-acceptance sweep count (parallel /
      candidate resolver) or queue-pop count (serial resolver)
    * ``edge_load``       — (M,) admitted clients per edge
    * ``frontier_valid_frac`` — valid (in-coverage ∧ available) share of
      the (N, K) frontier slots; the dense path reports the same ratio
      over the (N, M) coverage mask
    * ``frontier_saturation`` — share of matched clients admitted via
      their LAST frontier slot (≫ 0 ⇒ ``candidates_k`` is pruning)

    Scheduler / NOMA / staleness:

    * ``pdd_iters`` / ``pdd_residual`` — Alg. 1 iteration count and final
      penalty feasibility residual (zeros for the "fastest" baseline)
    * ``z_relaxed``       — the PDD's continuous z before rounding
    * ``sic_depth``       — max per-edge occupancy = the longest SIC
      decode chain an edge runs this round (Eq. 7)
    * ``stale_hist``      — (len(STALE_BIN_EDGES),) histogram of post-
      update A_n (Eq. 20)

    Buffered engine (DESIGN.md §11; all-zero on the sync engine):

    * ``buffer_fill``     — updates in the FedBuff buffer at trigger
      evaluation (BEFORE any reset this micro-step)
    * ``trigger_cause``   — 0 = no merge, 1 = fill trigger, 2 = timeout
    * ``tier_active``     — the TiFL tier admitted this micro-step
    * ``tier_occupancy``  — idle-and-available clients of that tier

    Fault layer (DESIGN.md §12; all-zero when ``EngineSpec.faults`` is
    off):

    * ``dead_edges``       — edges down after this round's churn step
    * ``orphaned_clients`` — in-coverage clients whose every in-coverage
      edge is dead (the clients forced to re-associate elsewhere)
    * ``uplink_retries``   — lost uploads re-entering flight with backoff
      (buffered engine only; sync has no buffer to retry from)
    * ``uplink_dropped``   — updates lost for good this round (crashes +
      uploads out of retry attempts)
    * ``quarantined``      — deltas the guard rejected (NaN/Inf)
    """
    round: jnp.ndarray               # () int32
    time_local_s: jnp.ndarray        # () f32
    time_uplink_s: jnp.ndarray       # () f32
    time_cloud_s: jnp.ndarray        # () f32
    energy_local_j: jnp.ndarray      # () f32
    energy_uplink_j: jnp.ndarray     # () f32
    energy_cloud_j: jnp.ndarray      # () f32
    assoc_sweeps: jnp.ndarray        # () int32
    edge_load: jnp.ndarray           # (M,) int32
    frontier_valid_frac: jnp.ndarray # () f32
    frontier_saturation: jnp.ndarray # () f32
    pdd_iters: jnp.ndarray           # () int32
    pdd_residual: jnp.ndarray        # () f32
    z_relaxed: jnp.ndarray           # (M,) f32
    sic_depth: jnp.ndarray           # () int32
    stale_hist: jnp.ndarray          # (8,) int32
    buffer_fill: jnp.ndarray         # () int32
    trigger_cause: jnp.ndarray       # () int32
    tier_active: jnp.ndarray         # () int32
    tier_occupancy: jnp.ndarray      # () int32
    dead_edges: jnp.ndarray          # () int32
    orphaned_clients: jnp.ndarray    # () int32
    uplink_retries: jnp.ndarray      # () int32
    uplink_dropped: jnp.ndarray      # () int32
    quarantined: jnp.ndarray         # () int32


def staleness_histogram(staleness: jnp.ndarray) -> jnp.ndarray:
    """(N,) int staleness -> (len(STALE_BIN_EDGES),) int32 bucket counts."""
    edges = jnp.asarray(STALE_BIN_EDGES, jnp.int32)
    bucket = jnp.sum(staleness[:, None] >= edges[None, :], axis=1) - 1
    bucket = jnp.clip(bucket, 0, len(STALE_BIN_EDGES) - 1)
    return jnp.zeros((len(STALE_BIN_EDGES),), jnp.int32).at[bucket].add(1)


def round_trace(cfg, spec, *, round_idx: jnp.ndarray, rc_all: cost.RoundCost,
                z: jnp.ndarray, assoc: jnp.ndarray, power_w: jnp.ndarray,
                f_hz: jnp.ndarray, counts: jnp.ndarray,
                staleness: jnp.ndarray,
                capacitance: Optional[jnp.ndarray],
                sweeps: jnp.ndarray,
                sched: Optional[Tuple[jnp.ndarray, jnp.ndarray,
                                      jnp.ndarray]],
                cand: Optional[CandidateSet],
                assigned: Optional[jnp.ndarray],
                dist: jnp.ndarray, avail: Optional[jnp.ndarray],
                coverage_radius_m: float,
                buffer: Optional[Tuple[jnp.ndarray, jnp.ndarray,
                                       jnp.ndarray, jnp.ndarray]] = None,
                faults: Optional[Tuple[jnp.ndarray, jnp.ndarray,
                                       jnp.ndarray, jnp.ndarray,
                                       jnp.ndarray]] = None
                ) -> RoundTrace:
    """Build one round's trace from tensors the round already computed.

    ``rc_all`` is the z = 1 cost surface (its per-client terms don't
    depend on z); ``sched`` is ``engine._schedule_traced``'s
    (iterations, residual, z_relaxed) triple (``None`` on the buffered
    engine, which has no edge scheduler — the PDD leaves read 0);
    ``staleness`` is the POST-update A_n so the histogram matches
    ``avg_staleness``; ``buffer`` is the buffered engine's
    (fill, trigger_cause, tier_active, tier_occupancy) quadruple
    (``None`` on sync — those leaves read 0); ``faults`` is the fault
    layer's (dead_edges, orphaned_clients, uplink_retries,
    uplink_dropped, quarantined) quintuple (``None`` with faults off —
    those leaves read 0).
    """
    f32 = jnp.float32
    associated = jnp.sum(assoc, axis=1) > 0
    billed = jnp.sum(assoc * z[None, :], axis=1) > 0            # (N,)

    # -- Eq. 23a decomposition: recover the per-client stage terms from
    #    the cached client_time (= t_cmp + t_com on associated clients)
    t_cmp, e_cmp = cost.local_compute(cfg, f_hz, counts, capacitance)
    t_com = jnp.where(associated, rc_all.client_time_s - t_cmp, 0.0)
    e_com = power_w * t_com
    tau2 = cfg.tau2
    any_edge = jnp.sum(z) > 0
    t_cloud = cfg.edge_model_size_bits / cfg.edge_rate_bps
    e_cloud = cfg.edge_power_w * t_cloud
    bm = billed.astype(f32)

    # -- association / frontier health
    edge_load = jnp.sum(assoc, axis=0).astype(jnp.int32)        # (M,)
    if cand is not None:
        valid_frac = jnp.mean(cand.valid.astype(f32))
        matched = assigned >= 0
        slot = jnp.argmax(
            (cand.idx == jnp.maximum(assigned, 0)[:, None]), axis=1)
        last = matched & (slot == cand.idx.shape[1] - 1)
        frontier_sat = jnp.sum(last.astype(f32)) \
            / jnp.maximum(jnp.sum(matched.astype(f32)), 1.0)
    else:
        cov = dist <= coverage_radius_m
        if avail is not None:
            cov = cov & (avail > 0)[:, None]
        valid_frac = jnp.mean(cov.astype(f32))
        frontier_sat = jnp.asarray(0.0, f32)

    if sched is None:
        i32 = jnp.int32
        sched = (jnp.zeros((), i32), jnp.zeros((), f32),
                 jnp.zeros(z.shape, f32))
    iters, residual, z_relaxed = sched
    if buffer is None:
        zi = jnp.zeros((), jnp.int32)
        buffer = (zi, zi, zi, zi)
    b_fill, b_cause, b_tier, b_occ = buffer
    if faults is None:
        zi = jnp.zeros((), jnp.int32)
        faults = (zi, zi, zi, zi, zi)
    f_dead, f_orph, f_retry, f_drop, f_quar = faults
    return RoundTrace(
        round=round_idx.astype(jnp.int32),
        time_local_s=(tau2 * jnp.max(bm * t_cmp)).astype(f32),
        time_uplink_s=(tau2 * jnp.max(bm * t_com)).astype(f32),
        time_cloud_s=(t_cloud * any_edge).astype(f32),
        energy_local_j=(tau2 * jnp.sum(bm * e_cmp)).astype(f32),
        energy_uplink_j=(tau2 * jnp.sum(bm * e_com)).astype(f32),
        energy_cloud_j=(e_cloud * jnp.sum(z)).astype(f32),
        assoc_sweeps=sweeps.astype(jnp.int32),
        edge_load=edge_load,
        frontier_valid_frac=valid_frac.astype(f32),
        frontier_saturation=frontier_sat.astype(f32),
        pdd_iters=iters.astype(jnp.int32),
        pdd_residual=residual.astype(f32),
        z_relaxed=z_relaxed.astype(f32),
        sic_depth=jnp.max(edge_load).astype(jnp.int32),
        stale_hist=staleness_histogram(staleness),
        buffer_fill=b_fill.astype(jnp.int32),
        trigger_cause=b_cause.astype(jnp.int32),
        tier_active=b_tier.astype(jnp.int32),
        tier_occupancy=b_occ.astype(jnp.int32),
        dead_edges=f_dead.astype(jnp.int32),
        orphaned_clients=f_orph.astype(jnp.int32),
        uplink_retries=f_retry.astype(jnp.int32),
        uplink_dropped=f_drop.astype(jnp.int32),
        quarantined=f_quar.astype(jnp.int32))
