"""Named profiler spans for the paper stages (DESIGN.md §10.3).

``stage(name)`` wraps a round stage in BOTH a
``jax.profiler.TraceAnnotation`` (host-side span — visible while the
stage's python runs, i.e. during tracing and in any eager/stepped
driver) and a ``jax.named_scope`` (propagates into HLO op metadata, so a
device profile captured with ``jax.profiler.trace`` segments the one
fused scan program by paper stage instead of showing a single opaque
``while`` op).  Both are metadata-only: the lowered computation — and
hence every golden trajectory — is unchanged, and there is zero runtime
cost outside a capture.

``profile_scanned`` is the capture helper behind ``benchmarks/run.py
--profile``: warm/compile first so the capture holds steady-state device
work, then run the scanned driver under ``jax.profiler.trace``.
"""
from __future__ import annotations

import contextlib
from typing import Optional

import jax

STAGES = ("associate", "allocate", "schedule", "train", "eval")


@contextlib.contextmanager
def stage(name: str):
    """Span a paper stage: profiler TraceAnnotation + HLO named_scope."""
    with jax.profiler.TraceAnnotation(f"hfl/{name}"), jax.named_scope(name):
        yield


def trace_capture(out_dir: str):
    """The ``jax.profiler.trace`` context, path-normalised: open a capture
    whose trace events include the ``hfl/<stage>`` annotations."""
    return jax.profiler.trace(out_dir)


def profile_scanned(cfg, spec, state, bundle, n_rounds: int, out_dir: str,
                    actor_params: Optional[object] = None) -> str:
    """Capture a stage-annotated device profile of ``run_scanned``.

    Compiles + warms OUTSIDE the capture, then records one steady-state
    driver call (plus a host-side ``hfl/run_scanned`` annotation bracketing
    it).  Returns ``out_dir`` (TensorBoard / XProf readable).
    """
    from repro.core import engine            # local import: no cycle
    run = lambda: engine.run_scanned(cfg, spec, state, bundle, n_rounds,
                                     actor_params)
    jax.block_until_ready(run())             # compile + warm
    with trace_capture(out_dir):
        with jax.profiler.TraceAnnotation("hfl/run_scanned"):
            jax.block_until_ready(run())
    return out_dir
