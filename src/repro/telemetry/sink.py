"""Host-side trace sinks and streaming drivers (DESIGN.md §10.2).

Two consumption modes for a telemetry-enabled engine
(``EngineSpec(telemetry=True)``):

* **collect** — pure: the drivers already stack ``RoundTrace`` along the
  rounds axis as a scan output; ``collect_scanned`` / ``collect_fleet``
  just split it from the metrics.  Works unchanged under vmap and both
  sharding drivers (the trace is an output pytree like any other).
* **stream** — ``stream_scanned`` / ``stream_fleet`` re-wrap the same
  ``round_step`` in a scan whose body feeds each round's trace to a host
  sink through ``jax.debug.callback``, so traces leave the device while
  the program runs, without breaking jit.  The single-simulation driver
  uses an ORDERED callback (JSONL lines arrive in round order); under
  vmap ordering across lanes is undefined, so every record carries its
  ``round`` index and ``load_jsonl`` re-sorts.

Sinks are tiny duck-typed objects with ``emit(trace)``: ``MemorySink``
accumulates host pytrees (the round-trip test target), ``JsonlSink``
appends one JSON object per round to a file.
"""
from __future__ import annotations

import functools
import json
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

from repro.core import engine
from repro.telemetry.trace import RoundTrace


# ---------------------------------------------------------------------------
# Sinks
# ---------------------------------------------------------------------------

class MemorySink:
    """Accumulates per-round traces as host numpy pytrees."""

    def __init__(self) -> None:
        self.records: List[RoundTrace] = []

    def emit(self, trace: RoundTrace) -> None:
        self.records.append(jax.tree.map(np.asarray, trace))

    def stacked(self) -> RoundTrace:
        """Records stacked along a leading rounds axis, sorted by round."""
        order = np.argsort([int(r.round) for r in self.records],
                           kind="stable")
        recs = [self.records[i] for i in order]
        return jax.tree.map(lambda *ls: np.stack(ls), *recs)


class JsonlSink:
    """Appends one JSON object per round: ``{"round": 3, "time_local_s":
    ..., "edge_load": [...], ...}``.  Usable as a context manager; the
    exit path flushes and closes even when the body raised (a crashed
    chaos sweep keeps every line emitted before the failure), and
    ``close`` is idempotent — a second close (context exit after a manual
    close, emit after a failure) is a no-op, never an attribute error."""

    def __init__(self, path: str) -> None:
        self.path = path
        self._fh: Optional[Any] = open(path, "a")

    def emit(self, trace: RoundTrace) -> None:
        if self._fh is None:
            return
        self._fh.write(json.dumps(trace_record(trace)) + "\n")
        self._fh.flush()

    def close(self) -> None:
        fh, self._fh = self._fh, None
        if fh is None:
            return
        try:
            fh.flush()
        finally:
            fh.close()

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def trace_record(trace: RoundTrace) -> Dict[str, Any]:
    """One round's trace as a JSON-serialisable flat dict."""
    out: Dict[str, Any] = {}
    for name, leaf in trace._asdict().items():
        arr = np.asarray(leaf)
        out[name] = arr.item() if arr.ndim == 0 else arr.tolist()
    return out


def load_jsonl(path: str) -> Dict[str, np.ndarray]:
    """Parse a ``JsonlSink`` file back to round-sorted stacked arrays,
    dtype-matched to the ``RoundTrace`` leaves (the round-trip inverse of
    the streaming drivers — pinned in tests/test_telemetry.py)."""
    rows = [json.loads(l) for l in open(path) if l.strip()]
    rows.sort(key=lambda r: r["round"])
    int_fields = {"round", "assoc_sweeps", "edge_load", "pdd_iters",
                  "sic_depth", "stale_hist", "buffer_fill",
                  "trigger_cause", "tier_active", "tier_occupancy",
                  "dead_edges", "orphaned_clients", "uplink_retries",
                  "uplink_dropped", "quarantined"}
    out = {}
    for name in RoundTrace._fields:
        dtype = np.int32 if name in int_fields else np.float32
        # files written before a trace field existed read as zeros, so a
        # newer loader keeps parsing older sweeps' JSONL
        out[name] = np.asarray([r.get(name, 0) for r in rows], dtype)
    return out


# ---------------------------------------------------------------------------
# Pure collect mode
# ---------------------------------------------------------------------------

def collect_scanned(cfg, spec, state, bundle, n_rounds: int,
                    actor_params=None):
    """``run_scanned`` with the (metrics, trace) output split:
    returns (final_state, metrics, trace) — trace ``None`` when the spec
    has telemetry off."""
    final, out = engine.run_scanned(cfg, spec, state, bundle, n_rounds,
                                    actor_params)
    ms, trace = engine.split_output(spec, out)
    return final, ms, trace


def collect_fleet(cfg, spec, states, bundles, n_rounds: int,
                  actor_params=None):
    """``run_fleet`` with the (metrics, trace) output split; trace leaves
    gain the (n_seeds, n_rounds, ...) fleet shape."""
    final, out = engine.run_fleet(cfg, spec, states, bundles, n_rounds,
                                  actor_params)
    ms, trace = engine.split_output(spec, out)
    return final, ms, trace


def emit_stacked(trace, sink, fleet_axes: int = 0) -> None:
    """Feed an already-collected stacked trace to a sink, one round at a
    time (host side) — the bridge that gives the SHARDED drivers JSONL
    output without putting callbacks inside their GSPMD programs.
    ``fleet_axes`` strips leading batch axes (1 for a fleet trace)."""
    host = jax.tree.map(np.asarray, trace)
    leaves, treedef = jax.tree.flatten(host)
    if fleet_axes:
        sims = leaves[0].shape[:fleet_axes]
        for flat_idx in np.ndindex(*sims):
            for r in range(leaves[0].shape[fleet_axes]):
                sink.emit(jax.tree.unflatten(
                    treedef, [l[flat_idx][r] for l in leaves]))
        return
    for r in range(leaves[0].shape[0]):
        sink.emit(jax.tree.unflatten(treedef, [l[r] for l in leaves]))


# ---------------------------------------------------------------------------
# Streaming drivers (jax.debug.callback inside the scan body)
# ---------------------------------------------------------------------------

def _require_telemetry(spec) -> None:
    if not spec.telemetry:
        raise ValueError("streaming drivers need EngineSpec(telemetry=True)"
                         " — with it off the trace is structurally absent")


def _scan_streaming(cfg, spec, n_rounds: int, sink, ordered: bool):
    """A jitted scanned driver whose body emits each round's trace."""

    def step(carry, _):
        state, bundle, actor_params = carry
        state2, (m, tr) = engine.round_step(cfg, spec, state, bundle,
                                            actor_params)
        jax.debug.callback(sink.emit, tr, ordered=ordered)
        return (state2, bundle, actor_params), (m, tr)

    @jax.jit
    def run(state, bundle, actor_params):
        (final, _, _), out = jax.lax.scan(
            step, (state, bundle, actor_params), None, length=n_rounds)
        return final, out

    return run


def stream_scanned(cfg, spec, state, bundle, n_rounds: int, sink,
                   actor_params=None, *, ordered: bool = True):
    """``run_scanned`` + per-round streaming to ``sink``.  Returns
    (final_state, metrics, trace) exactly like ``collect_scanned`` —
    the stream is a tee, not a different result."""
    _require_telemetry(spec)
    # fix the scan-carry structure up front: buffered specs enter with the
    # aggregation buffer attached, faulted specs with the fault state
    # attached, sync specs with both absent (engine.py §11-§12)
    state = engine.ensure_carry(cfg, spec, state)
    run = _scan_streaming(cfg, spec, n_rounds, sink, ordered)
    final, (ms, trace) = run(state, bundle, actor_params)
    jax.block_until_ready(ms)
    return final, ms, trace


def stream_scanned_client_sharded(cfg, spec, state, bundle, n_rounds: int,
                                  sink, actor_params=None, *, mesh=None):
    """The client-sharded scanned driver (DESIGN.md §9.3) with per-round
    streaming: pad → shard → stream.  Returns padded-world results like
    ``engine.run_scanned_client_sharded``."""
    _require_telemetry(spec)
    mesh = engine.client_mesh() if mesh is None else mesh
    # attach the buffer/fault state BEFORE padding so their per-client
    # leaves pad and shard with the rest of the state
    state = engine.ensure_carry(cfg, spec, state)
    cfg, state, bundle = engine.pad_clients(cfg, state, bundle,
                                            int(mesh.devices.size))
    state, bundle = engine.shard_clients(state, bundle, mesh)
    return stream_scanned(cfg, spec, state, bundle, n_rounds, sink,
                          actor_params)


def stream_fleet(cfg, spec, states, bundles, n_rounds: int, sink,
                 actor_params=None, *, mesh=None):
    """``run_fleet`` + streaming: the callback fires once per (lane,
    round) with the unbatched trace (vmap's callback batching rule), so
    records interleave across lanes — ``load_jsonl`` re-sorts by round.
    Pass ``mesh`` to shard the fleet axis first (placement only)."""
    _require_telemetry(spec)

    def step(carry, _):
        state, bundle = carry
        state2, (m, tr) = engine.round_step(cfg, spec, state, bundle,
                                            actor_params)
        jax.debug.callback(sink.emit, tr, ordered=False)
        return (state2, bundle), (m, tr)

    @jax.jit
    def run(states, bundles):
        def one(state, bundle):
            state = engine.ensure_carry(cfg, spec, state)
            (final, _), out = jax.lax.scan(step, (state, bundle), None,
                                           length=n_rounds)
            return final, out

        return jax.vmap(one)(states, bundles)

    if mesh is not None:
        states, bundles = engine.shard_fleet((states, bundles), mesh)
    final, (ms, trace) = run(states, bundles)
    jax.block_until_ready(ms)
    return final, ms, trace
