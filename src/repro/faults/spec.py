"""Fault-injection spec + state (DESIGN.md §12).

The round engine's robustness layer follows the same static-flag
discipline as telemetry (§10) and the buffered engine (§11):

* ``FaultSpec`` — a frozen (hashable) dataclass hanging off
  ``EngineSpec.faults``.  ``None`` (the default) keeps every fault path
  STRUCTURALLY absent: no fault state rides the carry, no fault op is
  traced, and every committed golden stays bit-exact un-re-recorded.
* ``FaultState`` — the pytree that rides in ``RoundState.faults`` when
  faults are on: the live-edge mask the churn process evolves, the
  per-client retry ledger the buffered engine's backoff consumes, and
  cumulative counters for the degradation events (retries, drops,
  quarantines, crashes) so a run's fault history survives in the final
  carry even without telemetry.

The spec's numbers are TRACE-TIME constants (like ``timeout_s``): two
fault parameterisations are two compiles.  That is deliberate — fault
probabilities select program structure (e.g. ``edge_p_kill=0`` skips the
churn ops entirely is NOT done; the whole FaultSpec is one switch), and a
chaos sweep runs a handful of fault cells, not thousands.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Static fault-injection + graceful-degradation knobs.

    Injection processes (all per-round / per-micro-step Bernoulli draws
    from a PRNG stream folded off the round key, so the no-fault stream
    is untouched):

    * **edge churn** — each live edge dies with ``edge_p_kill``, each dead
      edge respawns with ``edge_p_respawn`` (a two-state Markov chain over
      ``FaultState.edge_up``).  A step that would leave fewer than
      ``min_edges_up`` live edges is vetoed (the previous mask is kept):
      a federation with zero reachable edges is a dead experiment, not a
      degraded one.
    * **uplink loss** — a finished upload is lost with a channel-tied
      probability: ``uplink_p_loss`` at the best observed channel rising
      by ``uplink_loss_slope`` toward the worst (a monotone SINR proxy —
      the weaker the client's best live-edge gain, the likelier the
      drop).
    * **client crash** — an admitted client crashes mid-round with
      ``client_p_crash``: its compute is billed (the energy was spent)
      but its delta never reaches aggregation.
    * **poisoning** — with ``p_poison`` a produced delta is corrupted
      (scaled by ``poison_scale``, or NaN-filled when ``poison_nan``):
      the stress input the quarantine guard must absorb.

    Graceful degradation:

    * **retry/backoff** (buffered engine) — a lost upload re-enters
      flight with finish time ``clock + backoff_base_s ·
      backoff_factor^attempt`` for up to ``max_attempts`` attempts, then
      is dropped and counted.
    * **quarantine** — every delta reaching aggregation is L2-clipped to
      ``quarantine_clip`` and NaN/Inf-rejected (``faults.guard``).
    * **min participation** — the buffered merge applies only when the
      buffer holds ≥ ``min_participation`` updates; a churn-starved
      buffer keeps accumulating across timeout resets instead of
      applying near-empty merges (at the default 1 this is bit-identical
      to the guard-less trigger).
    """
    # edge-server churn (Markov kill/respawn over FaultState.edge_up)
    edge_p_kill: float = 0.0
    edge_p_respawn: float = 0.25
    min_edges_up: int = 1
    # SINR-tied Bernoulli uplink loss
    uplink_p_loss: float = 0.0
    uplink_loss_slope: float = 0.0
    # mid-round client crash (compute billed, delta lost)
    client_p_crash: float = 0.0
    # delta poisoning (stress input for the quarantine guard)
    p_poison: float = 0.0
    poison_scale: float = 1e6
    poison_nan: bool = False
    # retry/backoff (buffered engine uplink re-entry)
    max_attempts: int = 3
    backoff_base_s: float = 2.0
    backoff_factor: float = 2.0
    # graceful degradation
    quarantine_clip: float = 100.0
    min_participation: int = 1


class FaultState(NamedTuple):
    """Fault-layer carry (rides in ``RoundState.faults``; ``None`` — zero
    leaves, zero program bytes — when ``EngineSpec.faults`` is ``None``).

    ``edge_up`` is float (1.0/0.0) so it multiplies masks directly;
    ``attempts`` is the CURRENT upload's retry count (reset on each new
    admission); the ``n_*`` counters are cumulative over the run."""
    edge_up: jnp.ndarray        # (M,) f32 live-edge mask
    attempts: jnp.ndarray       # (N,) int32 retries of the in-flight upload
    n_retries: jnp.ndarray      # () int32 cumulative uplink retries
    n_dropped: jnp.ndarray      # () int32 uploads dropped after max_attempts
    n_quarantined: jnp.ndarray  # () int32 deltas rejected by the guard
    n_crashed: jnp.ndarray      # () int32 mid-round client crashes


def init_faults(cfg) -> FaultState:
    """All edges up, no retries, zeroed counters."""
    i32 = jnp.int32
    z = jnp.zeros((), i32)
    return FaultState(
        edge_up=jnp.ones((cfg.n_edges,), jnp.float32),
        attempts=jnp.zeros((cfg.n_clients,), i32),
        n_retries=z, n_dropped=z, n_quarantined=z, n_crashed=z)
