"""Update quarantine: the last gate before aggregation (DESIGN.md §12.3).

Contract: every client delta that reaches ``buffer_accumulate`` or the
sync cloud epilogue first passes ``quarantine``.  Two defenses:

* **norm clip** — a finite delta whose global L2 norm exceeds
  ``quarantine_clip`` is rescaled onto the clip sphere (the update's
  direction survives, its magnitude cannot dominate the merge);
* **NaN/Inf reject** — a delta with ANY non-finite element is zeroed
  outright and its client masked out of the merge.

Zeroing (not just down-weighting) is load-bearing: the aggregators
compute ``Σ wᵢ·dᵢ`` via einsum/broadcast products, and ``NaN · 0 = NaN``
— a poisoned delta left in the buffer would contaminate the sum even
with zero weight.  The guard therefore returns BOTH a cleaned delta tree
and the surviving-client mask, and callers must use the cleaned tree.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def delta_norms(deltas) -> jnp.ndarray:
    """(N,) global L2 norm of each client's delta across all leaves."""
    sq = sum(jnp.sum(jnp.reshape(leaf, (leaf.shape[0], -1)) ** 2, axis=1)
             for leaf in jax.tree.leaves(deltas))
    return jnp.sqrt(sq)


def delta_finite(deltas) -> jnp.ndarray:
    """(N,) bool — True iff every element of the client's delta is finite."""
    fin = None
    for leaf in jax.tree.leaves(deltas):
        f = jnp.all(jnp.isfinite(jnp.reshape(leaf, (leaf.shape[0], -1))),
                    axis=1)
        fin = f if fin is None else (fin & f)
    return fin


def quarantine(deltas, produced: jnp.ndarray, clip: float
               ) -> Tuple:
    """Clip finite deltas to ``clip`` and zero non-finite ones.

    Returns ``(deltas', ok, n_rejected)`` where ``ok`` is the (N,) bool
    mask of ``produced`` clients whose delta survived (rejected clients
    must also be dropped from the merge weights) and ``n_rejected`` is
    the () int32 count of produced-but-rejected deltas this call."""
    finite = delta_finite(deltas)
    norms = delta_norms(deltas)
    # non-finite norms would poison the scale; rejected rows are zeroed
    # below anyway, so any placeholder works
    safe_norm = jnp.where(finite, norms, 1.0)
    scale = jnp.minimum(1.0, clip / jnp.maximum(safe_norm, 1e-30))
    keep = (finite & produced).astype(jnp.float32) * scale

    def clean(leaf):
        k = keep.reshape((-1,) + (1,) * (leaf.ndim - 1))
        # zero-out first so 0 · NaN never occurs: where() selects, it
        # does not multiply
        z = jnp.where(jnp.isfinite(leaf), leaf, 0.0)
        return z * k

    ok = produced & finite
    n_rejected = jnp.sum(produced & ~finite, dtype=jnp.int32)
    return jax.tree.map(clean, deltas), ok, n_rejected
