"""Run-level fault tolerance: the chunked checkpoint-resume driver
(DESIGN.md §12.4).

``run_scanned_resumable`` splits one ``run_scanned`` experiment into
segments of ``segment_rounds`` scanned rounds, snapshotting the FULL scan
carry (``RoundState`` including the 13-leaf ``BufferState`` and the
``FaultState``, typed PRNG key included) plus the metrics/trace
accumulated so far through ``checkpoint/store.py`` after every segment.
A later call with the same ``directory`` resumes from the newest
snapshot and produces a trajectory BIT-IDENTICAL to the uninterrupted
run (pinned in tests/test_faults.py):

* the scan body is the same compiled program whether it runs 20 rounds
  in one scan or 4 × 5 — ``lax.scan`` threads the identical carry either
  way;
* the checkpoint round-trips every leaf exactly (npz preserves float
  bits; the typed PRNG key travels as its raw ``key_data`` words);
* per-segment outputs are concatenated on the host, untouched.

The checkpoint step number IS the number of completed rounds, so
``latest_step`` doubles as the resume cursor.  ``max_segments`` bounds
how many segments ONE call executes — the unit tests use it to simulate
a host crash mid-run (checkpoint written, driver gone).
"""
from __future__ import annotations

from typing import Any, NamedTuple, Optional

import jax
import numpy as np

from repro.checkpoint import store
from repro.core import engine
from repro.telemetry.trace import RoundTrace


class ResumableRun(NamedTuple):
    """One ``run_scanned_resumable`` call's outcome.  ``completed_rounds``
    < ``n_rounds`` means the call stopped at ``max_segments`` (or was
    asked for zero work) — call again with the same directory to
    continue from the last snapshot."""
    state: Any               # scan carry after the last completed segment
    metrics: Any             # RoundMetrics, host arrays, (completed, ...)
    trace: Any               # RoundTrace ditto, or None (telemetry off)
    completed_rounds: int
    n_rounds: int

    @property
    def done(self) -> bool:
        return self.completed_rounds >= self.n_rounds


def _template(nt_cls) -> Any:
    """A structure-only pytree for ``load_checkpoint`` (which restores by
    key path into the TEMPLATE'S structure — leaf values/shapes are never
    read, so zero-size placeholders are enough)."""
    return nt_cls(*([np.zeros((0,), np.float32)] * len(nt_cls._fields)))


def _out_template(spec: engine.EngineSpec) -> Any:
    mt = _template(engine.RoundMetrics)
    return (mt, _template(RoundTrace)) if spec.telemetry else mt


def _concat(acc, new):
    if acc is None:
        return jax.tree.map(np.asarray, new)
    return jax.tree.map(
        lambda a, b: np.concatenate([np.asarray(a), np.asarray(b)], axis=0),
        acc, new)


def run_scanned_resumable(cfg, spec: engine.EngineSpec, state, bundle,
                          n_rounds: int, *, directory: str,
                          segment_rounds: int = 8, actor_params=None,
                          max_segments: Optional[int] = None
                          ) -> ResumableRun:
    """``run_scanned`` in checkpointed segments; resume-safe.

    If ``directory`` holds a snapshot, ``state`` is only used for its
    pytree STRUCTURE (it must be the same experiment's init state) and
    the run continues from the snapshot's round count."""
    state = engine.ensure_carry(cfg, spec, state)
    seg_len = max(1, int(segment_rounds))
    done, out_accum = 0, None

    last = store.latest_step(directory)
    if last is not None:
        template = {"carry": state, "out": _out_template(spec)}
        tree, done, _ = store.load_checkpoint(directory, template, last)
        state, out_accum = tree["carry"], tree["out"]

    segments = 0
    while done < n_rounds and (max_segments is None
                               or segments < max_segments):
        seg = min(seg_len, n_rounds - done)
        state, out = engine.run_scanned(cfg, spec, state, bundle, seg,
                                        actor_params)
        out = jax.block_until_ready(out)
        out_accum = _concat(out_accum, out)
        done += seg
        segments += 1
        store.save_checkpoint(directory, done,
                              {"carry": state, "out": out_accum},
                              extra={"n_rounds": int(n_rounds),
                                     "segment_rounds": seg_len})

    if out_accum is None:
        ms, tr = None, None
    elif spec.telemetry:
        ms, tr = out_accum
    else:
        ms, tr = out_accum, None
    return ResumableRun(state=state, metrics=ms, trace=tr,
                        completed_rounds=done, n_rounds=int(n_rounds))
