"""Pure, jittable fault-injection processes (DESIGN.md §12.1).

Every function here is a pure map over (spec constants, a PRNG key, state
arrays) — scan/vmap/shard-safe, no host calls — and every one is traced
ONLY when ``EngineSpec.faults`` is set, so the no-fault program carries
zero bytes of this module.

PRNG discipline: the engine derives ONE fault key per round by folding a
fixed tag into the round's fading key (``fault_key``).  ``fold_in`` gives
an independent stream without consuming a split from the round layout
(``engine.round_keys``), so the fade/assoc/alloc/train streams — and with
them every golden trajectory — are untouched by the fault layer's draws.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.faults.spec import FaultSpec

# the fold_in tag for the per-round fault stream (an arbitrary constant;
# what matters is that it is fixed, so runs are reproducible)
_FAULT_STREAM = 0xFA117

# distance pushed far past any coverage radius: a dead edge is simply
# unreachable, so the unchanged association pipeline routes around it
DEAD_EDGE_DIST = 1e9


def fault_key(k_fade) -> jnp.ndarray:
    """The round's fault-stream key (independent of the round layout)."""
    return jax.random.fold_in(k_fade, _FAULT_STREAM)


def advance_edges(fspec: FaultSpec, key, edge_up: jnp.ndarray
                  ) -> jnp.ndarray:
    """One Markov churn step over the live-edge mask.

    Live edges die with ``edge_p_kill``; dead edges respawn with
    ``edge_p_respawn``.  A step that would leave fewer than
    ``min_edges_up`` live edges is vetoed wholesale (the previous mask is
    kept): orphaned clients re-associating through a smaller frontier is
    the degradation under test, a zero-edge federation is not."""
    u = jax.random.uniform(key, edge_up.shape)
    up = edge_up > 0
    nxt = jnp.where(up, u >= fspec.edge_p_kill, u < fspec.edge_p_respawn)
    ok = jnp.sum(nxt) >= min(int(fspec.min_edges_up), edge_up.shape[0])
    return jnp.where(ok, nxt, up).astype(jnp.float32)


def masked_dist(dist: jnp.ndarray, edge_up: jnp.ndarray) -> jnp.ndarray:
    """The association view of the distance field: dead edges are pushed
    out of every coverage disk, so the dense coverage mask — and the
    candidate frontier's validity — excludes them with zero new logic."""
    return jnp.where(edge_up[None, :] > 0, dist, DEAD_EDGE_DIST)


def uplink_loss_prob(fspec: FaultSpec, gains: jnp.ndarray,
                     edge_up: jnp.ndarray) -> jnp.ndarray:
    """(N,) per-client upload-loss probability, tied to channel quality.

    The proxy: a client's best live-edge gain, normalised by the cohort
    max — the client with the best channel loses with ``uplink_p_loss``,
    the worst with ``uplink_p_loss + uplink_loss_slope`` (clipped to
    0.95 so no client is deterministically unreachable)."""
    live = jnp.where(edge_up[None, :] > 0, gains, 0.0)
    best = jnp.max(live, axis=1)                               # (N,)
    q = best / jnp.maximum(jnp.max(best), 1e-30)               # (0, 1]
    p = fspec.uplink_p_loss + fspec.uplink_loss_slope * (1.0 - q)
    return jnp.clip(p, 0.0, 0.95)


def draw_losses(fspec: FaultSpec, key, gains: jnp.ndarray,
                edge_up: jnp.ndarray, active: jnp.ndarray) -> jnp.ndarray:
    """(N,) bool — which of the ``active`` uploads are lost this step."""
    u = jax.random.uniform(key, active.shape)
    return active & (u < uplink_loss_prob(fspec, gains, edge_up))


def draw_crashes(fspec: FaultSpec, key, admitted: jnp.ndarray
                 ) -> jnp.ndarray:
    """(N,) bool — which admitted clients crash mid-round (compute is
    billed upstream; the caller discards their deltas)."""
    u = jax.random.uniform(key, admitted.shape)
    return admitted & (u < fspec.client_p_crash)


def poison_deltas(fspec: FaultSpec, key, deltas, produced: jnp.ndarray
                  ) -> Tuple:
    """Corrupt a Bernoulli subset of the ``produced`` deltas.

    Returns ``(deltas', poisoned)``.  Corruption is a huge scale factor
    (``poison_scale``) or a NaN fill (``poison_nan``) — both must be
    caught by ``faults.guard`` before any aggregation touches them."""
    u = jax.random.uniform(key, produced.shape)
    poisoned = produced & (u < fspec.p_poison)

    def corrupt(leaf):
        m = poisoned.reshape((-1,) + (1,) * (leaf.ndim - 1))
        bad = leaf + jnp.nan if fspec.poison_nan else leaf * fspec.poison_scale
        return jnp.where(m, bad, leaf)

    return jax.tree.map(corrupt, deltas), poisoned


def backoff_s(fspec: FaultSpec, attempts: jnp.ndarray) -> jnp.ndarray:
    """Exponential backoff delay for retry number ``attempts`` (0-based):
    ``backoff_base_s · backoff_factor^attempts``."""
    return fspec.backoff_base_s * jnp.power(
        jnp.float32(fspec.backoff_factor), attempts.astype(jnp.float32))


def orphan_count(dist: jnp.ndarray, edge_up: jnp.ndarray,
                 coverage_radius_m: float, avail) -> jnp.ndarray:
    """() int32 — available clients with ≥ 1 in-coverage edge but ZERO
    live in-coverage edges: the clients edge churn cut off this round,
    who must re-associate through the surviving frontier."""
    cov = dist <= coverage_radius_m                            # (N, M)
    live = cov & (edge_up[None, :] > 0)
    orphaned = jnp.any(cov, axis=1) & ~jnp.any(live, axis=1)
    if avail is not None:
        orphaned = orphaned & (avail > 0)
    return jnp.sum(orphaned, dtype=jnp.int32)
