"""Fault injection & graceful degradation for the round engine
(DESIGN.md §12).

* ``spec``   — ``FaultSpec`` (static knobs, hangs off ``EngineSpec.faults``)
  and the ``FaultState`` carry pytree;
* ``inject`` — the pure per-round fault processes (edge churn, SINR-tied
  uplink loss, crashes, delta poisoning, backoff schedule);
* ``guard``  — the update quarantine (norm clip + NaN/Inf reject) run
  before any delta reaches aggregation;
* ``resume`` — the chunked checkpoint-resume driver
  (``run_scanned_resumable``); imported lazily because it depends on
  ``repro.core.engine``, which itself imports this package's leaf
  modules — eager import here would be a cycle.
"""
from repro.faults.spec import FaultSpec, FaultState, init_faults  # noqa: F401

__all__ = ["FaultSpec", "FaultState", "init_faults",
           "run_scanned_resumable", "ResumableRun"]


def __getattr__(name):
    if name in ("run_scanned_resumable", "ResumableRun", "resume"):
        from repro.faults import resume
        return resume if name == "resume" else getattr(resume, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
