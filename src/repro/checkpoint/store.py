"""Pytree checkpointing: one .npz of flattened leaves + a JSON manifest.

The manifest records the flattened key paths, dtypes, shapes and the step,
so a checkpoint round-trips bit-exactly and survives pytree reordering (load
restores by key path, not by position).  Atomic rename guards against a
crash mid-write — a production trainer resumes only from complete files.
"""
from __future__ import annotations

import json
import os
import re
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

Params = Any

_STEP_RE = re.compile(r"^step_(\d+)\.npz$")


_BITCAST = {"bfloat16": np.uint16, "float8_e4m3fn": np.uint8,
            "float8_e5m2": np.uint8}       # ml_dtypes npz-safe encodings


def _flatten(tree: Params) -> Dict[str, Tuple[np.ndarray, str]]:
    """key -> (npz-safe array, ORIGINAL dtype name)."""
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if (isinstance(leaf, jax.Array)
                and jax.dtypes.issubdtype(leaf.dtype, jax.dtypes.prng_key)):
            # a typed PRNG key (e.g. the scan carry's round key) travels
            # as its raw key-data words; the dtype name records the
            # impl so load re-wraps it bit-exactly
            impl = str(jax.random.key_impl(leaf))
            out[key] = (np.asarray(jax.random.key_data(leaf)),
                        f"prng:{impl}")
            continue
        arr = np.asarray(leaf)
        orig = arr.dtype.name
        if orig in _BITCAST:               # npz cannot hold ml_dtypes
            arr = arr.view(_BITCAST[orig])
        out[key] = (arr, orig)
    return out


def _restore_dtype(arr: np.ndarray, dtype_name: str):
    if dtype_name.startswith("prng:"):
        import jax.numpy as jnp
        return jax.random.wrap_key_data(jnp.asarray(arr),
                                        impl=dtype_name[len("prng:"):])
    if dtype_name in _BITCAST:
        import ml_dtypes
        return arr.view(getattr(ml_dtypes, dtype_name))
    return arr


def save_checkpoint(directory: str, step: int, tree: Params,
                    extra: Optional[Dict[str, Any]] = None) -> str:
    os.makedirs(directory, exist_ok=True)
    flat = _flatten(tree)
    manifest = {
        "step": int(step),
        "keys": {k: {"dtype": dt, "shape": list(v.shape)}
                 for k, (v, dt) in flat.items()},
        "extra": extra or {},
    }
    path = os.path.join(directory, f"step_{step}.npz")
    fd, tmp = tempfile.mkstemp(dir=directory, suffix=".npz.tmp")
    os.close(fd)
    with open(tmp, "wb") as fh:     # file handle: savez must not append .npz
        np.savez(fh, **{k: v for k, (v, _) in flat.items()})
    os.replace(tmp, path)
    mpath = os.path.join(directory, f"step_{step}.json")
    with open(mpath + ".tmp", "w") as f:
        json.dump(manifest, f)
    os.replace(mpath + ".tmp", mpath)
    return path


def load_checkpoint(directory: str, template: Params,
                    step: Optional[int] = None
                    ) -> Tuple[Params, int, Dict[str, Any]]:
    """Restore into the structure of ``template`` (a pytree or eval_shape)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {directory}")
    with open(os.path.join(directory, f"step_{step}.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(directory, f"step_{step}.npz"))

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        if key not in data:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        want = manifest["keys"][key]
        # shape-check the RAW stored array: a typed PRNG key re-wraps to
        # the key shape (its trailing key-data axis folds into the dtype)
        assert list(data[key].shape) == want["shape"], key
        leaves.append(_restore_dtype(data[key], want["dtype"]))
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    return tree, int(manifest["step"]), manifest.get("extra", {})


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(m.group(1)) for f in os.listdir(directory)
             if (m := _STEP_RE.match(f))]
    return max(steps) if steps else None
