"""Logical-axis sharding rules (DESIGN.md §5).

Every parameter leaf is matched by NAME (the leaf key in the params pytree)
to a rule giving, in *negative axis positions* so the stacked-scan leading
``reps`` axis needs no special casing:

* a TENSOR dimension chain — tried in order, the first whose size divides the
  ``model`` mesh axis wins (tensor parallelism), and
* an optional FSDP dimension chain — sharded over ``data`` (fully-sharded
  data parallelism, which is what lets the 110B/314B/400B configs fit
  params+Adam moments in 16 GB/chip).

The ``pod`` axis of the multi-pod mesh is pure data parallelism: params are
replicated across pods, the batch (and gradient all-reduce) spans it.

Indivisible dimensions fall through the chain and end replicated — e.g. MQA
KV heads (kv=1) stay replicated while Q heads shard, exactly the GQA rule in
DESIGN.md §5.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Params = Any

# (tensor-dim chain, fsdp-dim chain) per leaf name; dims are negative axes
# of the CANONICAL (unstacked) leaf. `None` chain = never shard that way.
_RULES: Dict[str, Tuple[Tuple[int, ...], Tuple[int, ...]]] = {
    # embedding / unembedding (V, d): vocab over model.  NO FSDP dim: a
    # data-sharded d makes the unembed einsum contract over a sharded dim
    # while the batch is also data-sharded — XLA then materialises FULL
    # (B, S, V) logits per device (measured 3×53 GB on llama4, §Perf it. 4).
    "embedding": ((-2, -1), ()),
    "unembedding": ((-2, -1), ()),
    # attention (also mlstm q/k/v): (d, H, dh) / (d, KV, dh).
    # Head dims shard ONLY when divisible; the fallback is REPLICATION, not
    # head-dim (dh) sharding — a dh-sharded K/V makes every attention-score
    # einsum contract over a sharded dim, all-reducing the full (Q, S)
    # score matrix (measured 3×15 GB per layer on yi-34b, §Perf it. 2).
    "wq": ((-2,), (-3,)),
    "wk": ((-2,), (-3,)),
    "wv": ((-2,), (-3,)),
    "wo": ((-3,), (-1,)),
    # MLP family: up-projections (d, ff) and down-projections (ff, d).
    # 3-D variants (MoE: (E, d, ff) / (E, ff, d)) hit the expert dim first.
    "w_in": ((-4, -1), (-2,)),        # -4 never matches 2-D/3-D: see _MOE
    "w_gate": ((-4, -1), (-2,)),
    "w_out": ((-4, -2), (-1,)),
    "w_up": ((-1,), (-2,)),
    "w_up_main": ((-1,), (-2,)),
    "w_up_gate": ((-1,), (-2,)),
    "w_gate_branch": ((-1,), (-2,)),
    "w_gates": ((-1,), (-2,)),
    "w_down": ((-2,), (-1,)),
    # RG-LRU square maps (dr, dr)
    "w_a": ((-1,), (-2,)),
    "w_x": ((-1,), (-2,)),
    # depthwise conv (W, ch)
    "conv_w": ((-1,), ()),
    # sLSTM recurrent gates (4, nh, dh, dh)
    "r_gates": ((-3, -1), ()),
    # per-head gates (di, nh)
    "w_igate": ((), (-2,)),
    "w_fgate": ((), (-2,)),
}

# MoE 3-D leaves share names with dense MLP 2-D leaves; give the expert dim
# priority when the leaf is 3-D.
_MOE_RULES: Dict[str, Tuple[Tuple[int, ...], Tuple[int, ...]]] = {
    "w_in": ((-3, -1), (-2,)),
    "w_gate": ((-3, -1), (-2,)),
    "w_out": ((-3, -2), (-1,)),
}


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Which mesh axes play which logical role."""
    batch: Tuple[str, ...]           # ("pod", "data") or ("data",)
    fsdp: Tuple[str, ...]            # ("data",)
    tensor: Tuple[str, ...]          # ("model",)


def mesh_axes(mesh: Mesh) -> MeshAxes:
    names = mesh.axis_names
    if "pod" in names:
        return MeshAxes(("pod", "data"), ("data",), ("model",))
    return MeshAxes(("data",), ("data",), ("model",))


def _axis_size(mesh: Mesh, axes: Sequence[str]) -> int:
    size = 1
    for a in axes:
        size *= mesh.shape[a]
    return size


def spec_for_param(name: str, shape: Tuple[int, ...], mesh: Mesh,
                   *, fsdp: bool = True) -> P:
    """PartitionSpec for one param leaf by rule name + shape."""
    ax = mesh_axes(mesh)
    ndim = len(shape)
    rules = _RULES.get(name)
    if name in _MOE_RULES and ndim >= 3:
        rules = _MOE_RULES[name]
    spec: list = [None] * ndim
    if rules is None:
        return P(*spec)
    tensor_chain, fsdp_chain = rules
    t_size = _axis_size(mesh, ax.tensor)
    f_size = _axis_size(mesh, ax.fsdp)
    t_dim = None
    for d in tensor_chain:
        if -d <= ndim and shape[d] % t_size == 0:
            t_dim = d % ndim
            spec[t_dim] = ax.tensor if len(ax.tensor) > 1 else ax.tensor[0]
            break
    if t_dim is None and name in ("wq", "wk", "wv") and ndim >= 3:
        # heads indivisible -> weights replicate over `model`; FSDP must
        # then avoid the contraction dim d (else every projection all-
        # reduces its full activation, §Perf it. 4) — shard dh instead.
        fsdp_chain = (-1, -3)
    if fsdp:
        for d in fsdp_chain:
            dd = d % ndim if -d <= ndim else None
            if dd is not None and dd != t_dim and shape[d] % f_size == 0:
                spec[dd] = ax.fsdp if len(ax.fsdp) > 1 else ax.fsdp[0]
                break
    return P(*spec)


def _leaf_name(path) -> str:
    last = path[-1]
    return str(getattr(last, "key", getattr(last, "idx", last)))


def tree_specs(shapes: Params, mesh: Mesh, *, fsdp: bool = True) -> Params:
    """PartitionSpec pytree for a params pytree (of arrays or SDS)."""
    def rule(path, leaf):
        return spec_for_param(_leaf_name(path), tuple(leaf.shape), mesh,
                              fsdp=fsdp)
    return jax.tree_util.tree_map_with_path(rule, shapes)


def param_shardings(shapes: Params, mesh: Mesh, *, fsdp: bool = True
                    ) -> Params:
    """NamedSharding pytree for a params pytree."""
    specs = tree_specs(shapes, mesh, fsdp=fsdp)
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


# ---------------------------------------------------------------------------
# Activations / inputs
# ---------------------------------------------------------------------------

def batch_axes(mesh: Mesh, global_batch: int) -> Optional[Tuple[str, ...]]:
    """Longest prefix of the batch mesh axes that divides ``global_batch``.

    long_500k (batch=1) ends fully replicated — DESIGN.md §5.
    """
    ax = mesh_axes(mesh)
    chosen: Tuple[str, ...] = ()
    size = 1
    # prefer consuming the pod axis first so DP spans pods
    for a in ax.batch:
        if global_batch % (size * mesh.shape[a]) == 0:
            chosen = chosen + (a,)
            size *= mesh.shape[a]
        else:
            break
    return chosen if chosen else None


def cache_spec(shape: Tuple[int, ...], mesh: Mesh, batch: Tuple[str, ...] | None
               ) -> P:
    """KV/recurrent-state cache leaf: axis 1 is batch (axis 0 is the stacked
    layer/rep axis).

    For attention K/V (ndim ≥ 4) the SEQUENCE dim (-3) shards over ``model``
    — flash-decoding style: attention scores are then per-shard partials and
    only the (tiny) softmax statistics and output reduce across chips.
    Sharding the head dim instead makes XLA all-gather the whole cache every
    layer (measured 1.07 GB/layer on qwen3 decode_32k, §Perf iteration 3).
    Recurrent states (ndim 3) shard their channel dim."""
    ax = mesh_axes(mesh)
    t_size = _axis_size(mesh, ax.tensor)
    ndim = len(shape)
    spec: list = [None] * ndim
    if ndim >= 2:
        b_dim = 1
        if batch and shape[b_dim] % _axis_size(mesh, batch) == 0:
            spec[b_dim] = batch if len(batch) > 1 else batch[0]
        chain = (-3, -1, -2) if ndim >= 4 else (-1,)
        for d in chain:
            dd = d % ndim
            if dd > b_dim and spec[dd] is None and shape[d] % t_size == 0:
                spec[dd] = ax.tensor[0]
                break
    return P(*spec)


def input_shardings(specs: Dict[str, Any], mesh: Mesh, global_batch: int
                    ) -> Dict[str, Any]:
    """NamedSharding for each entry of ``input_specs`` (train or decode)."""
    b_ax = batch_axes(mesh, global_batch)
    b_spec = (b_ax if b_ax and len(b_ax) > 1 else
              (b_ax[0] if b_ax else None))

    out: Dict[str, Any] = {}
    for k, v in specs.items():
        if k == "cache":
            out[k] = jax.tree.map(
                lambda l: NamedSharding(mesh, cache_spec(tuple(l.shape), mesh,
                                                         b_ax)), v)
        elif k == "index":
            out[k] = NamedSharding(mesh, P())
        else:
            ndim = len(v.shape)
            out[k] = NamedSharding(mesh, P(*([b_spec] + [None] * (ndim - 1))))
    return out
