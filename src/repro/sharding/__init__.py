"""Logical-axis sharding rules -> NamedSharding pytrees."""
from repro.sharding.rules import (param_shardings, input_shardings,
                                  batch_axes, spec_for_param, cache_spec,
                                  tree_specs)

__all__ = ["param_shardings", "input_shardings", "batch_axes",
           "spec_for_param", "cache_spec", "tree_specs"]
