"""LM token batching for the big-architecture training path.

Host-side iterator producing (tokens, labels) next-token batches from a
synthetic Zipf stream; shapes match ``input_specs`` so the same ``train_step``
serves the dry-run and real (small-scale) training examples.
"""
from __future__ import annotations

from typing import Dict, Iterator

import numpy as np

from repro.data import synthetic


def token_batches(rng: np.random.Generator, *, vocab: int, batch: int,
                  seq_len: int, n_batches: int) -> Iterator[Dict[str, np.ndarray]]:
    stream = synthetic.make_tokens(
        rng, n_tokens=batch * (seq_len + 1) * n_batches + 1, vocab=vocab)
    per = batch * (seq_len + 1)
    for i in range(n_batches):
        chunk = stream[i * per:(i + 1) * per + 1]
        toks = chunk[:-1].reshape(batch, seq_len + 1)
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}
