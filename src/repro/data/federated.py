"""Federated partitioning: IID and Dirichlet label-skew non-IID (paper §V,
Zhao et al. [39]) with heterogeneous per-client data quantities D_n.

The partition is materialised as fixed-capacity padded arrays so the whole
client population vmaps/shards as one stacked tensor:
  x (N, cap, dim), y (N, cap), counts (N,).
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import numpy as np

from repro.data import synthetic


@dataclasses.dataclass
class FederatedData:
    x: np.ndarray          # (N, cap, dim) float32, zero-padded
    y: np.ndarray          # (N, cap) int32
    counts: np.ndarray     # (N,) int64 — D_n
    test_x: np.ndarray     # (T, dim)
    test_y: np.ndarray     # (T,)

    @property
    def n_clients(self) -> int:
        return self.x.shape[0]


def _quantities(rng: np.random.Generator, n_clients: int, lo: int, hi: int
                ) -> np.ndarray:
    # every client owns at least one sample (a zero-data client would make
    # the engine's masked batch indexing and Eq. 11 weights degenerate)
    return np.maximum(rng.integers(lo, hi + 1, n_clients), 1)


def make_federated(rng: np.random.Generator, *, n_clients: int,
                   dim: int = 784, n_classes: int = 10, iid: bool = True,
                   min_samples: int = 200, max_samples: int = 1200,
                   dirichlet_alpha: float = 0.5, test_samples: int = 2000,
                   noise: float = 1.2) -> FederatedData:
    counts = _quantities(rng, n_clients, min_samples, max_samples)
    cap = int(max_samples)
    total = int(counts.sum())
    # one shared pool so all clients draw from the same distribution family
    pool_x, pool_y = synthetic.make_classification(
        rng, n_samples=total + test_samples, dim=dim, n_classes=n_classes,
        noise=noise)
    test_x, test_y = pool_x[:test_samples], pool_y[:test_samples]
    pool_x, pool_y = pool_x[test_samples:], pool_y[test_samples:]

    x = np.zeros((n_clients, cap, dim), np.float32)
    y = np.zeros((n_clients, cap), np.int32)

    if iid:
        perm = rng.permutation(total)
        offset = 0
        for c in range(n_clients):
            take = perm[offset:offset + counts[c]]
            offset += counts[c]
            x[c, :counts[c]] = pool_x[take]
            y[c, :counts[c]] = pool_y[take]
    else:
        # Dirichlet label-skew: each client draws a class mixture ~ Dir(α)
        by_class = [np.where(pool_y == k)[0] for k in range(n_classes)]
        for k in range(n_classes):
            rng.shuffle(by_class[k])
        sizes = np.asarray([len(b) for b in by_class], np.int64)
        if sizes.sum() == 0:
            raise ValueError("empty sample pool for the Dirichlet partition")
        class_ptr = np.zeros(n_classes, np.int64)
        for c in range(n_clients):
            mix = rng.dirichlet(np.full(n_classes, dirichlet_alpha))
            quota = mix * counts[c]
            per_class = np.floor(quota).astype(np.int64)
            # flooring under-fills the drawn quantity D_n by up to
            # n_classes-1 samples; classes absent from the pool can't
            # contribute at all.  Top the deficit back up over non-empty
            # classes by largest fractional remainder, so every client gets
            # EXACTLY its drawn counts[c] (≥ 1 by _quantities).
            per_class[sizes == 0] = 0
            eligible = np.flatnonzero(sizes > 0)
            order = eligible[np.argsort(-(quota[eligible] % 1.0),
                                        kind="stable")]
            deficit = int(counts[c] - per_class.sum())
            if deficit > 0:
                add = np.bincount(np.arange(deficit) % len(order),
                                  minlength=len(order))
                per_class[order] += add
            taken = []
            for k in range(n_classes):
                need = int(per_class[k])
                if need == 0:
                    continue
                avail = by_class[k]
                start = class_ptr[k]
                idx = [avail[(start + i) % len(avail)] for i in range(need)]
                class_ptr[k] = (start + need) % len(avail)
                taken.extend(idx)
            taken = np.asarray(taken, np.int64)
            rng.shuffle(taken)
            x[c, :len(taken)] = pool_x[taken]
            y[c, :len(taken)] = pool_y[taken]
            assert len(taken) == counts[c]

    return FederatedData(x, y, counts.astype(np.int64), test_x, test_y)
