"""Data substrate: synthetic datasets, federated partitioning, token streams."""
