"""Synthetic datasets (the container is offline — no downloads).

``make_classification`` builds an MNIST-like 10-class problem: each class is
a random template in R^dim plus noise, linearly separable enough that the
paper's MLP shows the convergence curves of Figs. 8-11, hard enough that
accuracy is informative.  ``make_tokens`` builds Zipf-distributed LM token
streams for the big-model training path.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np


def make_classification(rng: np.random.Generator, *, n_samples: int,
                        dim: int = 784, n_classes: int = 10,
                        noise: float = 1.2, template_scale: float = 1.0
                        ) -> Tuple[np.ndarray, np.ndarray]:
    """Returns (x (n, dim) float32 in ~[0,1], y (n,) int32)."""
    templates = rng.normal(0.0, template_scale, (n_classes, dim))
    y = rng.integers(0, n_classes, n_samples)
    x = templates[y] + rng.normal(0.0, noise, (n_samples, dim))
    # squash into a pixel-like range
    x = 1.0 / (1.0 + np.exp(-x))
    return x.astype(np.float32), y.astype(np.int32)


def make_tokens(rng: np.random.Generator, *, n_tokens: int, vocab: int,
                zipf_a: float = 1.2) -> np.ndarray:
    """Zipf-distributed token stream (n_tokens,) int32 in [0, vocab)."""
    ranks = rng.zipf(zipf_a, n_tokens).astype(np.int64)
    return (ranks % vocab).astype(np.int32)
