"""Batched decode server simulation for any assigned architecture.

Prefill a batch of prompts (reduced config), then autoregressively decode
with the same ``serve_step`` the decode-shape dry-runs lower at full scale.

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --tokens 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.launch.steps import make_serve_step


def prefill_into_cache(model, cfg, params, tokens, cache):
    """Feed prompt tokens one step at a time (functional reference prefill)."""
    serve = jax.jit(lambda p, t, c, i: model.decode_step(
        p, t, c, i, prefix_len=cfg.prefix_tokens))
    logits = None
    for i in range(tokens.shape[1]):
        logits, cache = serve(params, tokens[:, i:i + 1], cache,
                              jnp.asarray(i, jnp.int32))
    return logits, cache


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--cache-len", type=int, default=64)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    serve_step, model = make_serve_step(cfg)
    serve_step = jax.jit(serve_step, donate_argnums=(2,))

    key = jax.random.key(args.seed)
    params = model.init(key)
    if cfg.encoder_layers:
        cache = model.init_cache(args.batch, args.cache_len, cfg.stub_frames)
        key, k = jax.random.split(key)
        frames = jax.random.normal(
            k, (args.batch, cfg.stub_frames, cfg.d_model), cfg.compute_dtype)
        cache = model.prefill_cross(params, cache, frames)
    else:
        cache = model.init_cache(args.batch, args.cache_len)

    key, k = jax.random.split(key)
    prompt = jax.random.randint(k, (args.batch, args.prompt_len), 0,
                                cfg.vocab_size, jnp.int32)
    _, cache = prefill_into_cache(model, cfg, params, prompt, cache)

    tok = prompt[:, -1:]
    out = []
    t0 = time.time()
    for i in range(args.tokens):
        idx = jnp.asarray(args.prompt_len + i, jnp.int32)
        tok, cache = serve_step(params, tok, cache, idx)
        out.append(np.asarray(tok[:, 0]))
    dt = time.time() - t0
    gen = np.stack(out, axis=1)
    print(f"arch={cfg.name} batch={args.batch} generated {gen.shape[1]} "
          f"tokens/seq in {dt:.2f}s ({args.tokens*args.batch/dt:.1f} tok/s)")
    print("sample:", gen[0][:16])
    assert np.isfinite(gen).all()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
