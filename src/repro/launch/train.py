"""Small-scale runnable trainer for any assigned architecture.

Runs the REDUCED variant of ``--arch`` on the host devices (CPU here, TPU in
production) with synthetic Zipf tokens, checkpointing every ``--ckpt-every``
steps.  The same ``train_step`` is what the multi-pod dry-run lowers at full
scale — this proves the step function actually trains, not just compiles.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --steps 20
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.configs import get_config
from repro.data.tokens import token_batches
from repro.launch.steps import make_train_step


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--full-config", action="store_true",
                    help="use the full (assigned) config, not reduced")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full_config:
        cfg = cfg.reduced()
    step_fn, model, opt = make_train_step(cfg, lr=args.lr)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    key = jax.random.key(args.seed)
    params = model.init(key)
    opt_state = opt.init(params)
    step = jnp.zeros((), jnp.int32)
    n_params = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M "
          f"devices={len(jax.devices())}")

    rng = np.random.default_rng(args.seed)
    batches = token_batches(rng, vocab=cfg.vocab_size, batch=args.batch,
                            seq_len=args.seq, n_batches=args.steps)
    for i, batch in enumerate(batches):
        b = {k: jnp.asarray(v) for k, v in batch.items()}
        if cfg.prefix_tokens or cfg.stub_frames:
            n = cfg.prefix_tokens or cfg.stub_frames
            key, k = jax.random.split(key)
            b["embeddings"] = jax.random.normal(
                k, (args.batch, n, cfg.d_model), cfg.compute_dtype)
        t0 = time.time()
        params, opt_state, step, metrics = step_fn(params, opt_state, step, b)
        loss = float(metrics["loss"])
        print(f"step {i:4d} loss {loss:.4f} ({time.time()-t0:.2f}s)")
        assert np.isfinite(loss), "loss diverged"
        if args.ckpt_dir and (i + 1) % args.ckpt_every == 0:
            path = checkpoint.save_checkpoint(args.ckpt_dir, i + 1, params)
            print(f"  checkpoint -> {path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
