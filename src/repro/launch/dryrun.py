import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Multi-pod dry-run: prove every (architecture × input shape × mesh)
# combination lowers AND compiles under the production sharding config.
#
# The two lines above run before ANY other import (jax locks the device
# count on first init).  The dry-run lowers against ShapeDtypeStructs only —
# no device memory is ever allocated.
#
# Usage:
#  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k
#  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out]

import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, INPUT_SHAPES, get_config, input_specs
from repro.configs.base import shape_applicable
from repro.launch import roofline as rl
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_prefill_step, make_serve_step, \
    make_train_step
from repro.sharding import input_shardings, param_shardings


def lower_pair(arch: str, shape_name: str, *, multi_pod: bool = False,
               compile_: bool = True, cfg_override=None,
               unroll: bool = False, donate: bool = True) -> Dict[str, Any]:
    """Lower + compile one (arch × shape × mesh); return the record dict.

    ``unroll=True`` lowers with ``scan_layers=False`` so XLA's cost analysis
    counts every layer (while-loop bodies are otherwise visited once) — the
    roofline accounting mode.  The scanned variant stays the memory/compile
    proof.
    """
    cfg = cfg_override if cfg_override is not None else get_config(arch)
    if unroll:
        cfg = cfg.replace(scan_layers=False)
    shape = INPUT_SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    chips = 512 if multi_pod else 256
    t0 = time.time()

    specs = input_specs(cfg, shape)
    in_sh = input_shardings(specs, mesh, shape.global_batch)

    with mesh:
        if shape.kind == "train":
            step_fn, model, _ = make_train_step(cfg)
            p_shapes = jax.eval_shape(model.init, jax.random.key(0))
            p_sh = param_shardings(p_shapes, mesh)
            o_shapes = {"m": p_shapes, "v": p_shapes}
            o_sh = {"m": p_sh, "v": p_sh}
            s_sds = jax.ShapeDtypeStruct((), jnp.int32)
            fn = jax.jit(
                step_fn,
                in_shardings=(p_sh, o_sh, None, in_sh),
                out_shardings=(p_sh, o_sh, None, None),
                donate_argnums=(0, 1) if donate else ())
            lowered = fn.lower(p_shapes, o_shapes, s_sds, specs)
        elif shape.kind == "prefill":
            step_fn, model = make_prefill_step(cfg)
            p_shapes = jax.eval_shape(model.init, jax.random.key(0))
            p_sh = param_shardings(p_shapes, mesh)
            fn = jax.jit(step_fn, in_shardings=(p_sh, in_sh))
            lowered = fn.lower(p_shapes, specs)
        else:  # decode
            step_fn, model = make_serve_step(cfg)
            p_shapes = jax.eval_shape(model.init, jax.random.key(0))
            p_sh = param_shardings(p_shapes, mesh)
            fn = jax.jit(
                step_fn,
                in_shardings=(p_sh, in_sh["token"], in_sh["cache"],
                              in_sh["index"]),
                out_shardings=(in_sh["token"], in_sh["cache"]),
                donate_argnums=(2,) if donate else ())
            lowered = fn.lower(p_shapes, specs["token"], specs["cache"],
                               specs["index"])

        t_lower = time.time() - t0
        rec: Dict[str, Any] = {
            "arch": arch, "shape": shape_name, "mesh": mesh_name,
            "status": "lowered", "lower_s": round(t_lower, 1),
        }
        if not compile_:
            return rec
        compiled = lowered.compile()
        rec["status"] = "compiled"
        rec["compile_s"] = round(time.time() - t0 - t_lower, 1)

        mem = compiled.memory_analysis()
        rec["memory"] = {
            "argument_gb": mem.argument_size_in_bytes / 1e9,
            "output_gb": mem.output_size_in_bytes / 1e9,
            "temp_gb": mem.temp_size_in_bytes / 1e9,
            "generated_code_gb": mem.generated_code_size_in_bytes / 1e9,
        }
        roof = rl.analyze(
            compiled, arch=arch, shape=shape_name, mesh_name=mesh_name,
            chips=chips, model_flops=rl.model_flops_estimate(cfg, shape))
        rec["roofline"] = {
            "flops_per_device": roof.flops_per_device,
            "bytes_per_device": roof.bytes_per_device,
            "coll_bytes_per_device": roof.coll_bytes_per_device,
            "coll_breakdown": {k: v for k, v in roof.coll_breakdown.items()
                               if v},
            "compute_s": roof.compute_s,
            "memory_s": roof.memory_s,
            "collective_s": roof.collective_s,
            "dominant": roof.dominant,
            "model_flops": roof.model_flops,
            "useful_ratio": roof.useful_ratio,
        }
        return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--no-compile", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="roofline accounting mode (scan_layers=False)")
    ap.add_argument("--json", default=None, help="append records to file")
    args = ap.parse_args(argv)

    pairs = []
    if args.all:
        for a in ASSIGNED:
            for s in INPUT_SHAPES:
                pairs.append((a, s))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        pairs.append((args.arch, args.shape))

    records = []
    failed = 0
    for arch, shape in pairs:
        try:
            rec = lower_pair(arch, shape, multi_pod=args.multi_pod,
                             compile_=not args.no_compile,
                             unroll=args.unroll)
        except Exception as e:  # a dry-run failure is a bug in the system
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "status": "FAILED",
                   "error": repr(e)[:500]}
            failed += 1
        records.append(rec)
        print(json.dumps(rec), flush=True)
        if args.json:
            with open(args.json, "a") as f:
                f.write(json.dumps(rec) + "\n")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
