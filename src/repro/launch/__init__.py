"""Launcher: production mesh, train/serve steps, multi-pod dry-run, roofline."""
