"""Roofline-term extraction from a compiled dry-run artifact.

Three terms per (arch × shape × mesh), in seconds (§Roofline):

  compute    = HLO_FLOPs   / (chips × peak_FLOP/s)
  memory     = HLO_bytes   / (chips × HBM_bw)
  collective = coll_bytes  / (chips × link_bw)

``compiled.cost_analysis()`` reports flops/bytes of the PER-DEVICE partitioned
program, so totals are ``value × chips`` and the per-chip division cancels:
compute = cost['flops'] / peak, memory = cost['bytes accessed'] / bw.

Collective bytes are NOT in cost_analysis — we parse the post-SPMD HLO and
sum operand bytes of all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute ops (per-device shapes, so again no chips division).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Optional, Tuple

# TPU v5e hardware constants (assignment spec)
PEAK_FLOPS = 197e12          # bf16 FLOP/s per chip
HBM_BW = 819e9               # bytes/s per chip
ICI_BW = 50e9                # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# result shapes:  %x = f32[256,128]{1,0} all-gather(%param), ...
#                 %y = (f32[8], f32[8]) all-reduce(...)   (tuple form)
_LINE_RE = re.compile(
    r"=\s*(?P<shapes>\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(")
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
# replica_groups={{0,1},{2,3}}  or  replica_groups=[32,8]<=[256]...
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([t for t in m.group(1).split(",") if t.strip()])
    return 1


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device ICI traffic per collective kind, from post-SPMD HLO.

    Ring cost model on the RESULT shape R with group size g
    (operand shapes are not printed in compiled HLO):
      all-gather        R·(g−1)/g      (result = gathered full tensor)
      all-reduce        2·R·(g−1)/g    (reduce-scatter + all-gather phases)
      reduce-scatter    R·(g−1)        (operand = R·g; send all but own shard)
      all-to-all        R·(g−1)/g
      collective-permute R
    """
    out: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m or m.group("suffix") == "-done":
            continue
        kind = m.group("op")
        nbytes = _shape_bytes(m.group("shapes"))
        g = _group_size(line)
        if g <= 1 and kind != "collective-permute":
            continue
        if kind == "all-gather":
            traffic = nbytes * (g - 1) / g
        elif kind == "all-reduce":
            traffic = 2.0 * nbytes * (g - 1) / g
        elif kind == "reduce-scatter":
            traffic = nbytes * (g - 1)
        elif kind == "all-to-all":
            traffic = nbytes * (g - 1) / g
        else:
            traffic = nbytes
        out[kind] += int(traffic)
    return out


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_device: float
    bytes_per_device: float
    coll_bytes_per_device: float
    coll_breakdown: Dict[str, int]
    peak_mem_bytes: float            # per-device from memory_analysis
    model_flops: float               # 6·N·D analytic
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def useful_ratio(self) -> float:
        total = self.flops_per_device * self.chips
        return self.model_flops / total if total else 0.0


def analyze(compiled, *, arch: str, shape: str, mesh_name: str, chips: int,
            model_flops: float, hlo_text: Optional[str] = None) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):          # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    byts = float(cost.get("bytes accessed", 0.0))
    text = hlo_text if hlo_text is not None else compiled.as_text()
    coll = collective_bytes(text)
    coll_total = float(sum(coll.values()))
    try:
        mem = compiled.memory_analysis()
        peak = float(getattr(mem, "peak_memory_in_bytes", 0) or
                     (mem.argument_size_in_bytes
                      + mem.output_size_in_bytes
                      + mem.temp_size_in_bytes))
    except Exception:
        peak = 0.0
    return Roofline(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_device=flops, bytes_per_device=byts,
        coll_bytes_per_device=coll_total, coll_breakdown=coll,
        peak_mem_bytes=peak, model_flops=model_flops,
        compute_s=flops / PEAK_FLOPS,
        memory_s=byts / HBM_BW,
        collective_s=coll_total / ICI_BW,
    )


def model_flops_estimate(cfg, shape) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); decode D = batch·1."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch


def format_table(rows: List[Roofline]) -> str:
    hdr = (f"{'arch':<26}{'shape':<13}{'mesh':<9}{'compute_s':>11}"
           f"{'memory_s':>11}{'coll_s':>11}{'dominant':>11}{'useful':>8}")
    lines = [hdr, "-" * len(hdr)]
    for r in rows:
        lines.append(
            f"{r.arch:<26}{r.shape:<13}{r.mesh:<9}{r.compute_s:>11.4g}"
            f"{r.memory_s:>11.4g}{r.collective_s:>11.4g}{r.dominant:>11}"
            f"{r.useful_ratio:>8.3f}")
    return "\n".join(lines)
