import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

# Roofline accounting by two-point layer extrapolation.
#
# Full-model unrolled HLO is exact but slow to compile on the CPU stand-in
# (one core); a transformer stack is layer-homogeneous, so per-device
# flops / bytes / collective-bytes are affine in the number of pattern
# units:  total(U) = fixed + U * per_unit.  We compile the unrolled model
# at U=1 and U=2 pattern units, take the delta (= exactly one unit), and
# extrapolate to the full depth:
#
#   total(U_full) = p1 + (U_full - 1) * (p2 - p1)
#
# Validated against the exact full unroll for qwen3-8b × train_4k
# (EXPERIMENTS.md §Roofline, error < 2 %).  Memory analysis still comes
# from the scanned full-depth dry-run (results/dryrun_scanned_1pod.jsonl).
#
#   PYTHONPATH=src python -m repro.launch.roofline_extrapolate \
#       [--json results/dryrun_roofline.jsonl] [--arch A --shape S]
import argparse
import json
import sys
import time
import traceback
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.configs import ASSIGNED, INPUT_SHAPES, get_config, input_specs
from repro.configs.base import shape_applicable
from repro.launch import roofline as rl
from repro.launch.dryrun import lower_pair
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_prefill_step, make_serve_step, \
    make_train_step
from repro.sharding import input_shardings, param_shardings

CHIPS = 256


def _measure(cfg, shape_name: str) -> Dict[str, float]:
    """Per-device flops/bytes/coll-bytes of one unrolled compile."""
    rec = lower_pair(cfg.name, shape_name, cfg_override=cfg, unroll=True)
    assert rec["status"] == "compiled", rec
    rf = rec["roofline"]
    return {"flops": rf["flops_per_device"],
            "bytes": rf["bytes_per_device"],
            "coll": rf["coll_bytes_per_device"],
            "coll_breakdown": rf["coll_breakdown"]}


def extrapolate(arch: str, shape_name: str) -> Dict[str, Any]:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "status": "skipped",
                "reason": why}
    t0 = time.time()
    unit = len(cfg.block_pattern)
    u_full = cfg.n_layers // unit
    enc1 = min(cfg.encoder_layers, 1) if cfg.encoder_layers else 0
    c1 = cfg.replace(n_layers=unit, encoder_layers=enc1)
    c2 = cfg.replace(n_layers=2 * unit,
                     encoder_layers=2 * enc1 if enc1 else 0)
    p1 = _measure(c1, shape_name)
    p2 = _measure(c2, shape_name)

    def lin(k):
        return p1[k] + (u_full - 1) * (p2[k] - p1[k])

    flops, byts, coll = lin("flops"), lin("bytes"), lin("coll")
    breakdown = {k: int(p1["coll_breakdown"].get(k, 0)
                        + (u_full - 1) * (p2["coll_breakdown"].get(k, 0)
                                          - p1["coll_breakdown"].get(k, 0)))
                 for k in set(p1["coll_breakdown"]) | set(p2["coll_breakdown"])}
    mf = rl.model_flops_estimate(cfg, shape)
    rec = {
        "arch": arch, "shape": shape_name, "mesh": "16x16",
        "status": "compiled", "method": "unroll-extrapolated",
        "compile_s": round(time.time() - t0, 1),
        "roofline": {
            "flops_per_device": flops, "bytes_per_device": byts,
            "coll_bytes_per_device": coll,
            "coll_breakdown": {k: v for k, v in breakdown.items() if v > 0},
            "compute_s": flops / rl.PEAK_FLOPS,
            "memory_s": byts / rl.HBM_BW,
            "collective_s": coll / rl.ICI_BW,
            "dominant": max(
                [("compute", flops / rl.PEAK_FLOPS),
                 ("memory", byts / rl.HBM_BW),
                 ("collective", coll / rl.ICI_BW)], key=lambda t: t[1])[0],
            "model_flops": mf,
            "useful_ratio": mf / (flops * CHIPS) if flops else 0.0,
        },
    }
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)

    pairs = ([(args.arch, args.shape)] if args.arch else
             [(a, s) for a in ASSIGNED for s in INPUT_SHAPES])
    failed = 0
    for arch, shape in pairs:
        try:
            rec = extrapolate(arch, shape)
        except Exception as e:
            traceback.print_exc()
            rec = {"arch": arch, "shape": shape, "status": "FAILED",
                   "error": repr(e)[:400]}
            failed += 1
        print(json.dumps(rec), flush=True)
        if args.json:
            with open(args.json, "a") as f:
                f.write(json.dumps(rec) + "\n")
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
