"""Production mesh factory (TPU v5e target).

A FUNCTION, not a module constant, so importing this module never touches
jax device state (the dry-run sets XLA_FLAGS before any jax init; smoke
tests and benches see the single real CPU device).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh

# jax<0.5 has neither AxisType nor make_mesh's axis_types kwarg; Auto is
# its only (implicit) behaviour there, so omitting the kwarg is identical.
try:
    from jax.sharding import AxisType
except ImportError:                                   # pragma: no cover
    AxisType = None


def _make_mesh(shape, axes) -> Mesh:
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(model: int = 1) -> Mesh:
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    assert n % model == 0, (n, model)
    return _make_mesh((n // model, model), ("data", "model"))
