"""Jittable train/prefill/serve steps shared by the dry-run, the roofline
harness, the examples and the tests.

``make_train_step`` closes over (model, optimizer); its signature is
  (params, opt_state, step, batch) -> (params, opt_state, step, metrics)
``make_serve_step`` is the decode step the ``decode_32k``/``long_500k``
shapes lower: ONE new token against a KV cache of ``seq_len``.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import build_model
from repro.models.transformer import loss_fn as _tf_loss
from repro.optim import adamw, clip_by_global_norm

Params = Any


def model_loss(model, params: Params, batch: Dict[str, jnp.ndarray]
               ) -> jnp.ndarray:
    cfg = model.cfg
    logits, aux = model.apply(params, batch["tokens"],
                              extra_embeddings=batch.get("embeddings"))
    from repro.models import layers
    loss = layers.softmax_cross_entropy(logits, batch["labels"],
                                        batch.get("loss_mask"))
    if cfg.moe_experts:
        loss = loss + cfg.moe_aux_weight * aux
    return loss


def make_train_step(cfg, *, lr: float = 3e-4, grad_clip: float = 1.0
                    ) -> Callable:
    model = build_model(cfg)
    opt = adamw(lr, opt_dtype=cfg.opt_dtype_str)

    def train_step(params, opt_state, step, batch):
        microbatches = cfg.grad_accum

        def compute(p, b):
            return model_loss(model, p, b)

        if microbatches > 1:
            b0 = batch["tokens"].shape[0]
            mb = b0 // microbatches

            def split(x):
                return x.reshape((microbatches, mb) + x.shape[1:])

            mbatch = {k: split(v) for k, v in batch.items()}

            def body(carry, mbat):
                loss_acc, grad_acc = carry
                loss, grads = jax.value_and_grad(compute)(params, mbat)
                return (loss_acc + loss,
                        jax.tree.map(jnp.add, grad_acc, grads)), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(
                body, (jnp.zeros(()), zeros), mbatch)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        else:
            loss, grads = jax.value_and_grad(compute)(params, batch)

        grads = clip_by_global_norm(grads, grad_clip)
        params, opt_state = opt.update(grads, opt_state, params, step)
        return params, opt_state, step + 1, {"loss": loss}

    return train_step, model, opt


def make_prefill_step(cfg) -> Tuple[Callable, Any]:
    model = build_model(cfg)

    def prefill_step(params, batch):
        logits, _ = model.apply(params, batch["tokens"],
                                extra_embeddings=batch.get("embeddings"))
        # return only the last-position logits (what a server samples from)
        return logits[:, -1, :]

    return prefill_step, model


def make_serve_step(cfg) -> Tuple[Callable, Any]:
    model = build_model(cfg)
    prefix = cfg.prefix_tokens

    def serve_step(params, token, cache, index):
        logits, cache = model.decode_step(params, token, cache, index,
                                          prefix_len=prefix)
        next_token = jnp.argmax(logits[:, -1, :], axis=-1, keepdims=True)
        return next_token.astype(jnp.int32), cache

    return serve_step, model
