"""Dynamic-scenario subsystem (DESIGN.md §6).

The paper motivates the DDPG allocator with *time-varying environments*,
but in the PR-1 engine only the channel fading evolves: topology, coverage
and client capability are frozen at ``init_simulation``.  This package
makes the rest of the world move, as a **pure per-round transition** that
lives inside the jitted ``round_step``:

    advance(cfg, kind, key, ScenarioState) -> ScenarioState'

* ``ScenarioState`` — the per-client world state that evolves between
  global rounds: positions (→ client-edge distances), a two-state Markov
  availability mask, and the device class (per-client ``f_max``/``p_max``
  caps and effective-capacitance κ).  A pytree, so it rides in the
  ``RoundState`` carry and scans/vmaps with the rest of the engine.
* ``ScenarioSpec`` — host-side init configuration only.  Its numbers are
  baked into ScenarioState *arrays* at init time, so two scenarios with
  different speeds / drop rates / device mixes share ONE compiled program:
  the engine's static switch is just the transition *kind* string.

Built-in kinds (all parameterised through the state, so any mixture
batches into a single ``run_fleet`` compile):

* ``static``          — identity; bit-for-bit the PR-1 engine.
* ``random_waypoint`` — clients walk toward uniformly re-drawn waypoints
  at per-client speeds; coverage and the nearest edge change every round.
* ``markov_dropout``  — two-state availability chain: an available client
  drops with prob ``p_drop``, a dropped one returns with ``p_return``
  (stationary availability p_return / (p_drop + p_return)).
* ``hetero_devices``  — per-client CPU/power classes drawn at init and
  flowing into the Eq. 23a cost model (κ, f_max, p_max).
* ``dynamic``         — all of the above; the kind every dynamic preset
  normalises to, so a sweep over scenarios is data, not code.

Purity contract: a transition may use only ``cfg`` floats, its PRNG key
and the state arrays — no numpy, no python control flow on traced values,
no host callbacks (the lowering test asserts it).  Custom transitions
register with ``register_transition``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

_MOBILE = "random_waypoint"
_DROPOUT = "markov_dropout"
_HETERO = "hetero_devices"
_PARTS = (_MOBILE, _DROPOUT, _HETERO)
_FLASH = "flash_crowd"
_REGIONAL = "regional_outage"
_DIURNAL = "diurnal"


@dataclasses.dataclass(frozen=True)
class ScenarioSpec:
    """Host-side scenario initialisation config (NOT a jit static arg —
    every number here becomes a ScenarioState array)."""
    kind: str = "static"            # "static" | "+"-joined parts | "dynamic"
    # random_waypoint mobility
    speed_min_mps: float = 1.0
    speed_max_mps: float = 15.0
    round_duration_s: float = 10.0  # wall-clock per global round (motion step)
    # markov_dropout availability
    p_drop: float = 0.15            # P(available -> dropped) per round
    p_return: float = 0.5           # P(dropped -> available) per round
    # hetero_devices classes
    n_device_classes: int = 4
    kappa_spread: float = 1.0       # κ ∈ cfg.capacitance · [1, 1+spread]
    # regional_outage: radius of the outage disk as a fraction of the area
    # side (the numbers land in reused ScenarioState slots — see the
    # transition's docstring)
    outage_radius_frac: float = 0.35
    # diurnal load curve: availability oscillates with this period
    # (rounds) down to `diurnal_floor` at the trough
    diurnal_period_rounds: float = 24.0
    diurnal_floor: float = 0.2

    @property
    def parts(self) -> tuple:
        """The BUILT-IN parts this kind activates (a custom registered
        transition has none — its init is the identity parameterisation
        and its own transition evolves whatever leaves it wants)."""
        if self.kind == "static":
            return ()
        if self.kind == "dynamic":
            return _PARTS
        parts = tuple(self.kind.split("+"))
        unknown = set(parts) - set(_PARTS)
        if not unknown:
            return parts
        if self.kind in TRANSITIONS:          # registered custom transition
            return ()
        raise ValueError(f"unknown scenario part(s) {sorted(unknown)}; "
                         f"choose from {_PARTS} or register_transition()")

    @property
    def is_dynamic(self) -> bool:
        return self.kind != "static"

    def engine_kind(self) -> str:
        """The engine's trace-time switch.  Every built-in dynamic mixture
        lowers to the SAME program ("dynamic"): which parts are active is
        encoded in the state arrays, so scenario sweeps share one compile.
        A custom registered kind selects its own transition (and its own
        compile)."""
        if self.kind == "static":
            return "static"
        return "dynamic" if (self.parts or self.kind == "dynamic") \
            else self.kind

    @property
    def stationary_availability(self) -> float:
        return self.p_return / max(self.p_drop + self.p_return, 1e-12)


class ScenarioState(NamedTuple):
    """Per-client world state carried across rounds (leaves (N, ...) /
    (M, 2) / (N, M); a leading fleet axis appears under ``stack_fleet``)."""
    pos: jnp.ndarray        # (N, 2) client positions [m]
    waypoint: jnp.ndarray   # (N, 2) current waypoint target [m]
    speed: jnp.ndarray      # (N,) metres moved per ROUND (speed·duration)
    avail: jnp.ndarray      # (N,) float32 availability mask (1.0 / 0.0)
    p_drop: jnp.ndarray     # (N,) P(up -> down); 0 disables dropout
    p_return: jnp.ndarray   # (N,) P(down -> up); 1 disables dropout
    f_max_hz: jnp.ndarray   # (N,) per-device CPU-frequency cap
    p_max_w: jnp.ndarray    # (N,) per-device transmit-power cap
    kappa: jnp.ndarray      # (N,) per-device effective capacitance κ
    edges: jnp.ndarray      # (M, 2) edge-server positions (constant)
    dist: jnp.ndarray       # (N, M) current client-edge distances


def _distances(pos: jnp.ndarray, edges: jnp.ndarray) -> jnp.ndarray:
    return jnp.linalg.norm(pos[:, None, :] - edges[None, :, :], axis=-1)


def init_scenario(cfg, sspec: ScenarioSpec, rng: np.random.Generator,
                  topo: Dict[str, np.ndarray]) -> ScenarioState:
    """Materialise the spec into state arrays (host side, numpy RNG).

    Inactive parts are initialised to their identity parameterisation
    (speed 0, p_drop 0 / p_return 1, homogeneous devices), so the shared
    ``advance_dynamic`` transition is a no-op along that axis.
    """
    n = cfg.n_clients
    parts = sspec.parts
    f32 = np.float32
    pos = np.asarray(topo["clients"], f32)
    edges = np.asarray(topo["edges"], f32)
    dist = np.asarray(topo["dist"], f32)

    if _MOBILE in parts:
        speed = rng.uniform(sspec.speed_min_mps, sspec.speed_max_mps,
                            n).astype(f32) * f32(sspec.round_duration_s)
        waypoint = rng.uniform(0.0, cfg.area_side_m, (n, 2)).astype(f32)
    else:
        speed = np.zeros((n,), f32)
        waypoint = pos.copy()

    if _DROPOUT in parts or sspec.kind in (_FLASH, _REGIONAL, _DIURNAL):
        # flash_crowd / regional_outage / diurnal reuse the dropout
        # parameter slots: p_drop is the decay / outage-event / phase-step
        # probability, p_return the burst / recovery probability (or the
        # diurnal floor) — see each transition's docstring
        p_drop = np.full((n,), sspec.p_drop, f32)
        p_return = np.full((n,), sspec.p_return, f32)
    else:
        p_drop = np.zeros((n,), f32)
        p_return = np.ones((n,), f32)

    if sspec.kind == _REGIONAL:
        # the speed slot (unused: no mobility) carries the outage radius
        speed = np.full((n,), sspec.outage_radius_frac * cfg.area_side_m,
                        f32)
    elif sspec.kind == _DIURNAL:
        # p_drop slot: per-round phase increment; p_return slot: the
        # availability floor; speed slot: the running phase accumulator
        p_drop = np.full((n,), 2.0 * np.pi
                         / max(sspec.diurnal_period_rounds, 1e-6), f32)
        p_return = np.full((n,), sspec.diurnal_floor, f32)
        speed = np.zeros((n,), f32)

    if _HETERO in parts:
        cls = rng.integers(0, sspec.n_device_classes, n)
        frac = (cls + 1.0) / sspec.n_device_classes          # (0, 1]
        f_max = (cfg.f_min_hz
                 + frac * (cfg.f_max_hz - cfg.f_min_hz)).astype(f32)
        p_max = (cfg.p_min_w
                 + frac * (cfg.p_max_w - cfg.p_min_w)).astype(f32)
        # weaker silicon burns more J per cycle at a given f
        kappa = (cfg.capacitance
                 * (1.0 + sspec.kappa_spread * (1.0 - frac))).astype(f32)
    else:
        f_max = np.full((n,), cfg.f_max_hz, f32)
        p_max = np.full((n,), cfg.p_max_w, f32)
        kappa = np.full((n,), cfg.capacitance, f32)

    return ScenarioState(
        pos=jnp.asarray(pos), waypoint=jnp.asarray(waypoint),
        speed=jnp.asarray(speed), avail=jnp.ones((n,), jnp.float32),
        p_drop=jnp.asarray(p_drop), p_return=jnp.asarray(p_return),
        f_max_hz=jnp.asarray(f_max), p_max_w=jnp.asarray(p_max),
        kappa=jnp.asarray(kappa), edges=jnp.asarray(edges),
        dist=jnp.asarray(dist))


# ---------------------------------------------------------------------------
# Pure transitions
# ---------------------------------------------------------------------------

def static_transition(cfg, key, s: ScenarioState) -> ScenarioState:
    """Identity — the PR-1 frozen world."""
    del cfg, key
    return s


def advance_dynamic(cfg, key, s: ScenarioState) -> ScenarioState:
    """One round of world evolution: waypoint motion + availability chain.

    Device classes are fixed per simulation (drawn at init); inactive axes
    are identities by parameterisation (see ``init_scenario``), so this one
    program serves every built-in scenario mixture.
    """
    k_wp, k_drop = jax.random.split(key)

    # -- random-waypoint motion (speed is metres per round) ------------------
    delta = s.waypoint - s.pos                                   # (N, 2)
    d = jnp.linalg.norm(delta, axis=-1)                          # (N,)
    arrived = d <= jnp.maximum(s.speed, 1e-6)
    step = (s.speed / jnp.maximum(d, 1e-9))[:, None] * delta
    pos = jnp.where(arrived[:, None], s.waypoint, s.pos + step)
    fresh_wp = jax.random.uniform(k_wp, s.pos.shape, minval=0.0,
                                  maxval=cfg.area_side_m)
    waypoint = jnp.where(arrived[:, None], fresh_wp, s.waypoint)
    dist = _distances(pos, s.edges)

    # -- two-state Markov availability --------------------------------------
    u = jax.random.uniform(k_drop, s.avail.shape)
    up = s.avail > 0
    avail = jnp.where(up, u >= s.p_drop, u < s.p_return)
    return s._replace(pos=pos, waypoint=waypoint, dist=dist,
                      avail=avail.astype(jnp.float32))


def flash_crowd_transition(cfg, key, s: ScenarioState) -> ScenarioState:
    """Burst arrivals: availability flips in WAVES instead of mixing.

    Between bursts the population only decays — each available client
    drops with its ``p_drop`` and dropped clients stay down, so
    availability drains toward zero.  With probability ``mean(p_return)``
    per round a flash crowd arrives and EVERY dropped client returns at
    once (the clients that just dropped this round stay down, so a burst
    round still churns).  The result is the sawtooth arrival pattern the
    semi-async buffered engine (DESIGN.md §11) is built to absorb: long
    quiet stretches followed by a wall of simultaneous admissions —
    exactly where a fill-or-timeout trigger beats a per-round barrier.

    Parameter reuse keeps this a pure data-parameterised transition:
    ``p_drop`` is the decay chain, ``p_return`` the burst probability
    (``init_scenario`` fills both for kind="flash_crowd").
    """
    del cfg
    k_burst, k_drop = jax.random.split(key)
    burst = jax.random.uniform(k_burst, ()) < jnp.mean(s.p_return)
    u = jax.random.uniform(k_drop, s.avail.shape)
    up = s.avail > 0
    stay_up = up & (u >= s.p_drop)
    avail = jnp.where(burst, stay_up | ~up, stay_up)
    return s._replace(avail=avail.astype(jnp.float32))


def regional_outage_transition(cfg, key, s: ScenarioState) -> ScenarioState:
    """Correlated regional outages: whole NEIGHBOURHOODS go dark at once.

    With probability ``mean(p_drop)`` per round an outage event strikes a
    uniformly-drawn centre, and every client within the outage radius
    drops TOGETHER — the spatially-correlated failure mode (backhaul cut,
    local power loss) that independent per-client dropout chains cannot
    produce, and the stress input for the fault layer's edge-churn +
    re-association machinery (DESIGN.md §12).  Between events, downed
    clients recover independently with ``mean(p_return)`` per round.

    Parameter reuse (the ``flash_crowd`` precedent): ``p_drop`` is the
    event probability, ``p_return`` the recovery probability, and the
    (motionless) ``speed`` slot carries the outage radius in metres
    (``init_scenario`` fills all three for kind="regional_outage").
    """
    k_evt, k_ctr, k_rec = jax.random.split(key, 3)
    event = jax.random.uniform(k_evt, ()) < jnp.mean(s.p_drop)
    centre = jax.random.uniform(k_ctr, (2,), minval=0.0,
                                maxval=cfg.area_side_m)
    hit = jnp.linalg.norm(s.pos - centre[None, :], axis=-1) \
        <= jnp.mean(s.speed)
    up = s.avail > 0
    recovered = ~up & (jax.random.uniform(k_rec, s.avail.shape)
                       < jnp.mean(s.p_return))
    avail = (up | recovered) & ~(event & hit)
    return s._replace(avail=avail.astype(jnp.float32))


def diurnal_transition(cfg, key, s: ScenarioState) -> ScenarioState:
    """Diurnal load curve: fleet availability breathes sinusoidally.

    The target availability level is ``floor + (1-floor) · (1+sin φ)/2``
    with the phase φ advancing by a fixed increment per round (one full
    cycle every ``diurnal_period_rounds``); each client is then
    independently available with that probability — the day/night
    participation rhythm of real cross-device federations, which the
    buffered engine's fill-or-timeout trigger must ride out without
    starving (DESIGN.md §12).

    Parameter reuse: ``p_drop`` carries the per-round phase increment,
    ``p_return`` the availability floor, and the (motionless) ``speed``
    slot accumulates the running phase.
    """
    del cfg
    phase = s.speed + s.p_drop                 # (N,) — uniform by init
    floor = s.p_return
    level = floor + (1.0 - floor) * 0.5 * (1.0 + jnp.sin(phase))
    avail = jax.random.uniform(key, s.avail.shape) < level
    return s._replace(avail=avail.astype(jnp.float32), speed=phase)


Transition = Callable[..., ScenarioState]

TRANSITIONS: Dict[str, Transition] = {"static": static_transition,
                                      "dynamic": advance_dynamic,
                                      _FLASH: flash_crowd_transition,
                                      _REGIONAL: regional_outage_transition,
                                      _DIURNAL: diurnal_transition}
# the named parts (and every "+"-mixture of them, any order) run the same
# data-parameterised program; registering them lets
# EngineSpec(scenario="random_waypoint") work directly, at the price of one
# compile per distinct kind string.
import itertools as _it

for _r in range(1, len(_PARTS) + 1):
    for _combo in _it.permutations(_PARTS, _r):
        TRANSITIONS["+".join(_combo)] = advance_dynamic


def register_transition(kind: str, fn: Transition) -> None:
    """Register a custom pure transition ``fn(cfg, key, state) -> state``.
    It must obey the purity contract (jit/scan/vmap-safe, no host calls)."""
    TRANSITIONS[kind] = fn


def advance(cfg, kind: str, key, s: ScenarioState) -> ScenarioState:
    if kind not in TRANSITIONS:
        raise ValueError(f"unknown scenario transition {kind!r}; "
                         f"registered: {sorted(TRANSITIONS)}")
    return TRANSITIONS[kind](cfg, key, s)


# ---------------------------------------------------------------------------
# Presets (the sweep vocabulary)
# ---------------------------------------------------------------------------

PRESETS: Dict[str, ScenarioSpec] = {
    "static": ScenarioSpec(),
    "random_waypoint": ScenarioSpec(kind="random_waypoint"),
    "markov_dropout": ScenarioSpec(kind="markov_dropout"),
    "hetero_devices": ScenarioSpec(kind="hetero_devices"),
    # flaky pedestrians: slow motion, sticky outages
    "mobile_flaky": ScenarioSpec(kind="random_waypoint+markov_dropout",
                                 speed_max_mps=3.0, p_drop=0.3, p_return=0.3),
    # everything at once — vehicular speeds on a heterogeneous fleet
    "full_dynamic": ScenarioSpec(kind="dynamic", speed_max_mps=25.0),
    # burst arrivals: availability decays (p_drop), then a flash crowd
    # returns every dropped client at once with prob p_return per round
    "flash_crowd": ScenarioSpec(kind="flash_crowd", p_drop=0.25,
                                p_return=0.15),
    # spatially-correlated outages: with prob p_drop per round a disk of
    # clients goes dark together; survivors recover with p_return
    "regional_outage": ScenarioSpec(kind="regional_outage", p_drop=0.2,
                                    p_return=0.4),
    # day/night participation rhythm: availability breathes sinusoidally
    # between the floor and 1 over diurnal_period_rounds
    "diurnal": ScenarioSpec(kind="diurnal"),
}


def preset(name_or_spec) -> ScenarioSpec:
    """Resolve a preset name / kind string / ScenarioSpec to a spec."""
    if isinstance(name_or_spec, ScenarioSpec):
        return name_or_spec
    if name_or_spec is None:
        return ScenarioSpec()
    if name_or_spec in PRESETS:
        return PRESETS[name_or_spec]
    return ScenarioSpec(kind=str(name_or_spec))   # validates via .parts
