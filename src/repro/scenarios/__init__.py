from repro.scenarios.base import (PRESETS, TRANSITIONS, ScenarioSpec,
                                  ScenarioState, advance, advance_dynamic,
                                  flash_crowd_transition, init_scenario,
                                  preset, register_transition,
                                  static_transition)

__all__ = [
    "PRESETS", "TRANSITIONS", "ScenarioSpec", "ScenarioState", "advance",
    "advance_dynamic", "flash_crowd_transition", "init_scenario", "preset",
    "register_transition", "static_transition",
]
