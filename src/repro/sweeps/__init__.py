from repro.sweeps.grid import (SweepCell, SweepGrid, expand_grid, run_sweep,
                               summarize)

__all__ = ["SweepCell", "SweepGrid", "expand_grid", "run_sweep", "summarize"]
