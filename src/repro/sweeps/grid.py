"""Declarative scenario × policy × allocator sweep runner (DESIGN.md §6.3).

A ``SweepGrid`` names the axes of an experiment grid — scenarios (preset
names or ``ScenarioSpec``s), association policies, allocators, schedulers,
NOMA on/off, seeds — and ``run_sweep`` executes the full cross product with
the MINIMUM number of XLA compiles:

* axes that are trace-time code paths (policy / allocator / scheduler /
  NOMA / scenario *kind*) partition the grid into static-spec groups;
* everything else (scenario parameterisation, seeds) is DATA: every cell
  of a group is stacked along the fleet axis (``stack_fleet``) and the
  whole group runs as one vmapped ``run_fleet`` call — one compile, no
  matter how many scenarios × seeds ride in it.

Because every built-in dynamic scenario normalises to the single "dynamic"
transition kind (scenarios are arrays, not code — DESIGN.md §6.1), a sweep
over N scenarios × S seeds under one policy is exactly ONE compile (plus
one for a static-scenario row if present).

Per-cell metric trajectories are persisted as JSON under
``results/sweep_<name>/`` — the machinery for the paper's Figs. 8-12
protocol under moving, flaky, heterogeneous clients.

    PYTHONPATH=src python -m repro.sweeps.grid --quick   # demo sweep
"""
from __future__ import annotations

import dataclasses
import json
import os
import time
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro import scenarios
from repro.core import engine
from repro.faults import FaultSpec


@dataclasses.dataclass(frozen=True)
class SweepCell:
    """One point of the grid (hashable; carries the RESOLVED scenario spec
    so custom parameterisations survive the trip through the runner)."""
    scenario: str                  # display label (preset name / kind)
    sspec: scenarios.ScenarioSpec
    policy: str
    allocator: str
    scheduler: str
    noma_enabled: bool
    seed: int
    engine_mode: str = "sync"      # sync | buffered (DESIGN.md §11)

    @property
    def cell_id(self) -> str:
        noma = "noma" if self.noma_enabled else "oma"
        # the sync id keeps the historical shape so existing result files
        # and tooling line up; buffered cells get an explicit suffix
        mode = "" if self.engine_mode == "sync" else f"__{self.engine_mode}"
        return (f"{self.scenario}__{self.policy}__{self.allocator}"
                f"__{self.scheduler}__{noma}__s{self.seed}{mode}")


@dataclasses.dataclass
class SweepGrid:
    """The declarative grid: every field is an axis of the cross product.

    ``scenarios`` entries may be preset names / kind strings, ScenarioSpec
    instances, or ``(label, ScenarioSpec)`` pairs — use a pair to give a
    custom parameterisation a distinct cell label.
    """
    name: str
    scenarios: Sequence[Any] = ("static",)
    policies: Sequence[str] = ("fcea",)
    allocators: Sequence[str] = ("mid",)
    schedulers: Sequence[str] = ("pdd",)
    noma: Sequence[bool] = (True,)
    seeds: Sequence[int] = (0,)
    n_rounds: int = 10
    iid: bool = True
    # (N, K) candidate frontier for every cell (DESIGN.md §9): None =
    # dense; K ≥ the max in-coverage degree is bit-identical to dense (at
    # sizes where the dense path runs its sorted SIC), so flipping this on
    # a sweep changes speed, not results
    candidates_k: "int | None" = None
    # dense-path SIC formulation (EngineSpec.sic_impl); the candidate
    # path's compact SIC is the sorted/top-k formulation regardless
    sic_impl: str = "auto"
    # in-scan telemetry (DESIGN.md §10): every cell also persists its
    # per-round RoundTrace as ``<cell_id>.trace.json`` beside the metrics
    telemetry: bool = False
    # engine-mode axis (DESIGN.md §11): "sync" is the paper's barrier
    # round; "buffered" runs the same n_rounds as semi-async MICRO-steps.
    # The buffer_* fields parameterise every buffered cell's trigger.
    engine_modes: Sequence[str] = ("sync",)
    buffer_fill: int = 0           # 0 = auto ((quota · M) // 2)
    timeout_s: float = 10.0
    n_tiers: int = 4
    retier_every: int = 8
    # fault injection (DESIGN.md §12): a FaultSpec turns every cell into
    # a chaos cell (edge churn, uplink loss, quarantine...); None keeps
    # the fault layer structurally absent
    faults: "FaultSpec | None" = None
    # per-group DDPG training budget (used when the grid has
    # allocator="ddpg" cells and no pre-trained actor is supplied)
    ddpg_episodes: int = 12
    ddpg_steps: int = 40
    ddpg_warmup: int = 64
    ddpg_hidden: int = 64


def _resolve_scenario(entry: Any) -> Tuple[str, scenarios.ScenarioSpec]:
    """(label, spec) for a grid scenario entry, preserving its parameters."""
    if isinstance(entry, tuple):
        label, spec = entry
        return str(label), scenarios.preset(spec)
    if isinstance(entry, scenarios.ScenarioSpec):
        return entry.kind, entry
    return str(entry), scenarios.preset(entry)


def expand_grid(grid: SweepGrid) -> List[SweepCell]:
    cells = [SweepCell(label, sspec, po, al, sch, nm, sd, em)
             for label, sspec in map(_resolve_scenario, grid.scenarios)
             for po in grid.policies for al in grid.allocators
             for sch in grid.schedulers for nm in grid.noma
             for sd in grid.seeds for em in grid.engine_modes]
    ids = [c.cell_id for c in cells]
    if len(set(ids)) != len(ids):
        dupes = sorted({i for i in ids if ids.count(i) > 1})
        raise ValueError(
            f"ambiguous sweep cells {dupes}: two scenario entries share a "
            f"label — use (label, ScenarioSpec) pairs to disambiguate")
    return cells


def _spec_for(cell: SweepCell, grid: SweepGrid) -> engine.EngineSpec:
    return engine.EngineSpec(policy=cell.policy, allocator=cell.allocator,
                             scheduler=cell.scheduler,
                             noma_enabled=cell.noma_enabled,
                             scenario=cell.sspec.engine_kind(),
                             candidates_k=grid.candidates_k,
                             sic_impl=grid.sic_impl,
                             telemetry=grid.telemetry,
                             engine_mode=cell.engine_mode,
                             buffer_fill=grid.buffer_fill,
                             timeout_s=grid.timeout_s,
                             n_tiers=grid.n_tiers,
                             retier_every=grid.retier_every,
                             faults=grid.faults)


def _group_cells(cells: Sequence[SweepCell], grid: SweepGrid
                 ) -> Dict[engine.EngineSpec, List[SweepCell]]:
    groups: Dict[engine.EngineSpec, List[SweepCell]] = {}
    for cell in cells:
        groups.setdefault(_spec_for(cell, grid), []).append(cell)
    return groups


def run_sweep(cfg, grid: SweepGrid, *, out_dir: str = "results",
              write_json: bool = True, actor_params=None,
              mesh=None) -> Dict[str, Any]:
    """Execute the grid; returns (and persists) a summary + per-cell rows.

    One ``run_fleet`` call — hence one compile — per static-spec group;
    inside a group all scenarios × seeds run vmapped in a single program.
    Pass ``mesh`` (e.g. ``engine.fleet_mesh()``) to shard every group's
    fleet axis across devices (DESIGN.md §8.3) — per-cell results are
    identical to the unsharded run, only placement changes.

    ``allocator="ddpg"`` cells need a trained actor.  By default every
    ddpg CELL trains its own actor on its own world (scenario × seed) via
    the scanned ``ddpg.train_allocator`` (budgeted by the grid's
    ``ddpg_*`` fields; one training compile serves the whole group), and
    the stacked actors ride the fleet vmap (``run_fleet_actors``) — a
    dynamic group trains on the (3N,) scenario-sliced observation, a
    static group on (2N,), so mixed grids just work and no cell is ever
    billed with an actor trained on a different scenario.  Pass
    ``actor_params`` (a pre-trained actor pytree) to use one shared actor
    for every ddpg cell instead; then the grid must not mix observation
    shapes.
    """
    cells = expand_grid(grid)
    ddpg_cells = [c for c in cells if c.allocator == "ddpg"]
    if ddpg_cells and actor_params is not None:
        if len({c.sspec.engine_kind() == "static" for c in ddpg_cells}) > 1:
            raise ValueError(
                "ddpg cells mix static (2N,) and dynamic (3N,) observation "
                "shapes — one actor cannot serve both; split the grid or "
                "drop actor_params to train per group")
    groups = _group_cells(cells, grid)
    sweep_dir = os.path.join(out_dir, f"sweep_{grid.name}")
    if write_json:
        os.makedirs(sweep_dir, exist_ok=True)

    per_cell: Dict[str, Dict[str, list]] = {}
    timings: List[Dict[str, Any]] = []
    # cells differing only in policy/allocator/scheduler/NOMA share the
    # exact same (seed, scenario) world — init it once, not once per cell
    init_cache: Dict[Tuple[int, scenarios.ScenarioSpec], tuple] = {}

    def _init(c: SweepCell):
        k = (c.seed, c.sspec)
        if k not in init_cache:
            init_cache[k] = engine.init_simulation(cfg, seed=c.seed,
                                                   iid=grid.iid,
                                                   scenario=c.sspec)[:2]
        return init_cache[k]

    failed: Dict[str, str] = {}

    def _run_group(spec: engine.EngineSpec, members: List[SweepCell]) -> None:
        pairs = [_init(c) for c in members]
        states, bundles = engine.stack_fleet(pairs)
        cell_actors, train_s = None, 0.0
        if spec.allocator == "ddpg" and actor_params is None:
            # train ONE actor PER CELL on that cell's own world, all the
            # cells of the group vmapped into a single XLA program
            # (train_allocator_fleet), then ride the stacked actors
            # through the fleet vmap: every ddpg row in the persisted
            # JSON ran an actor trained on exactly the scenario × seed it
            # reports
            from repro.core import ddpg
            t0 = time.perf_counter()
            # fold a tag into each seed root so the training stream is
            # decorrelated from init_simulation(seed)'s world-init stream
            # (same root key, children 0/1 already spent on model/gains)
            keys = jnp.stack([jax.random.fold_in(jax.random.key(c.seed),
                                                 7919) for c in members])
            agents, _ = ddpg.train_allocator_fleet(
                cfg, spec, states, bundles, None, keys,
                episodes=grid.ddpg_episodes,
                steps_per_episode=grid.ddpg_steps,
                warmup=grid.ddpg_warmup, hidden=grid.ddpg_hidden)
            cell_actors = jax.block_until_ready(agents.actor)
            train_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        if mesh is not None:
            _, out = engine.run_fleet_sharded(
                cfg, spec, states, bundles, grid.n_rounds,
                cell_actors if cell_actors is not None else actor_params,
                mesh=mesh, per_sim_actors=cell_actors is not None)
        elif cell_actors is not None:
            _, out = engine.run_fleet_actors(cfg, spec, states, bundles,
                                             grid.n_rounds, cell_actors)
        else:
            _, out = engine.run_fleet(cfg, spec, states, bundles,
                                      grid.n_rounds, actor_params)
        ms, traces = engine.split_output(spec, out)
        jax.block_until_ready(ms.cost)
        dt = time.perf_counter() - t0
        timing = {"spec": dataclasses.asdict(spec),
                  "n_cells": len(members), "wall_s": round(dt, 4)}
        if spec.allocator == "ddpg":
            timing["ddpg_trained"] = actor_params is None
            timing["ddpg_train_s"] = round(train_s, 4)
            timing["ddpg_actors"] = (len(members) if actor_params is None
                                     else "shared")
        timings.append(timing)
        # one device->host transfer per metrics leaf for the WHOLE group
        host = {k: np.asarray(v) for k, v in ms._asdict().items()}
        tr_host = (None if traces is None else
                   {k: np.asarray(v) for k, v in traces._asdict().items()})
        for i, cell in enumerate(members):
            rows = {k: v[i].tolist() for k, v in host.items()}
            per_cell[cell.cell_id] = rows
            if write_json:
                payload = {"cell": dataclasses.asdict(cell),
                           "spec": dataclasses.asdict(spec),
                           "n_rounds": grid.n_rounds,
                           "metrics": rows}
                with open(os.path.join(sweep_dir,
                                       f"{cell.cell_id}.json"), "w") as fh:
                    json.dump(payload, fh, indent=1)
                if tr_host is not None:
                    # the per-stage Eq. 23a decomposition + association/
                    # scheduler internals, beside the metrics JSON
                    tp = {"cell": dataclasses.asdict(cell),
                          "n_rounds": grid.n_rounds,
                          "trace": {k: v[i].tolist()
                                    for k, v in tr_host.items()}}
                    with open(os.path.join(
                            sweep_dir,
                            f"{cell.cell_id}.trace.json"), "w") as fh:
                        json.dump(tp, fh, indent=1)

    for spec, members in groups.items():
        # one crashed group (a divergent chaos cell, an OOM'd compile)
        # must not take down the rest of the sweep: record the failure
        # against every member cell and keep going
        try:
            _run_group(spec, members)
        except Exception as exc:  # noqa: BLE001
            for cell in members:
                failed[cell.cell_id] = repr(exc)
            timings.append({"spec": dataclasses.asdict(spec),
                            "n_cells": len(members),
                            "error": repr(exc)})

    summary = {
        "name": grid.name,
        "n_cells": len(cells),
        "n_compiles": len(groups),     # one vmapped run_fleet per group
        "n_rounds": grid.n_rounds,
        "axes": {"scenarios": [_resolve_scenario(s)[0]
                               for s in grid.scenarios],
                 "policies": list(grid.policies),
                 "allocators": list(grid.allocators),
                 "schedulers": list(grid.schedulers),
                 "noma": list(grid.noma),
                 "seeds": list(grid.seeds),
                 "engine_modes": list(grid.engine_modes)},
        "groups": timings,
        "final": summarize(per_cell),
        "failed_cells": failed,
    }
    if write_json:
        with open(os.path.join(sweep_dir, "summary.json"), "w") as fh:
            json.dump(summary, fh, indent=1)
    summary["cells"] = per_cell
    return summary


def summarize(per_cell: Dict[str, Dict[str, list]]) -> Dict[str, dict]:
    """Final-round view per cell: the numbers the paper's figures plot."""
    out = {}
    for cid, rows in per_cell.items():
        out[cid] = {"accuracy": rows["accuracy"][-1],
                    "loss": rows["loss"][-1],
                    "cost": rows["cost"][-1],
                    "mean_cost": float(np.mean(rows["cost"])),
                    "n_associated": rows["n_associated"][-1],
                    "n_available": rows["n_available"][-1]}
    return out


def main(argv=None) -> None:
    import argparse
    import dataclasses as dc

    from repro.configs.hfl_mnist import CONFIG

    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--out", default="results")
    ap.add_argument("--sharded", action="store_true",
                    help="shard each group's fleet axis over all devices")
    ap.add_argument("--candidates", type=int, default=None, metavar="K",
                    help="run every cell on the (N, K) candidate frontier")
    ap.add_argument("--telemetry", action="store_true",
                    help="persist per-round RoundTrace JSON beside each "
                         "cell's metrics")
    ap.add_argument("--buffered", action="store_true",
                    help="add the semi-async buffered engine as a second "
                         "engine-mode axis value (DESIGN.md §11)")
    ap.add_argument("--faults", action="store_true",
                    help="run the chaos-smoke grid instead: the buffered "
                         "engine under edge churn + SINR-tied uplink loss "
                         "with telemetry on (DESIGN.md §12)")
    args = ap.parse_args(argv)

    cfg = dc.replace(CONFIG, n_clients=32, n_edges=4, min_samples=60,
                     max_samples=120, hidden=32, input_dim=64)
    if args.faults:
        grid = SweepGrid(
            name="chaos",
            scenarios=("static", "markov_dropout"),
            policies=("gcea",),
            seeds=(0,) if args.quick else (0, 1),
            n_rounds=3 if args.quick else 10,
            candidates_k=args.candidates,
            telemetry=True,
            engine_modes=("buffered",),
            faults=FaultSpec(edge_p_kill=0.2, edge_p_respawn=0.5,
                             uplink_p_loss=0.1, uplink_loss_slope=0.2))
    else:
        grid = SweepGrid(
            name="demo",
            scenarios=("static", "random_waypoint", "markov_dropout",
                       "hetero_devices", "full_dynamic", "flash_crowd"),
            policies=("fcea", "gcea"),
            seeds=(0,) if args.quick else (0, 1),
            n_rounds=3 if args.quick else 10,
            candidates_k=args.candidates,
            telemetry=args.telemetry,
            engine_modes=("sync", "buffered") if args.buffered else ("sync",))
    summary = run_sweep(cfg, grid, out_dir=args.out,
                        mesh=engine.fleet_mesh() if args.sharded else None)
    print(json.dumps({k: summary[k] for k in
                      ("name", "n_cells", "n_compiles", "groups")}, indent=1))
    for cid, row in summary["final"].items():
        print(f"{cid}: acc={row['accuracy']:.3f} "
              f"cost={row['mean_cost']:.3f} avail={row['n_available']}")
    if summary["failed_cells"]:
        for cid, err in summary["failed_cells"].items():
            print(f"FAILED {cid}: {err}")
        if not summary["final"]:
            # every cell failed — the sweep produced nothing usable
            raise SystemExit(1)


if __name__ == "__main__":
    main()
