"""End-to-end HFL engine (the paper's full system, §II–§IV).

One ``HFLSimulation`` instance owns the wireless topology, the federated
dataset, the stacked client models and the staleness state, and advances one
*global round* per :meth:`run_round`:

  1. fade the channels; fuzzy-score every (client, edge) pair (§III),
  2. associate clients (FCEA / GCEA / RCEA),
  3. allocate (p, f) — DDPG policy or RRA/FPA/FCA baselines (§IV-C),
  4. τ₂ edge iterations, each = τ₁ local SGD steps on every associated
     client (vmapped: all clients train as ONE batched XLA program) +
     edge aggregation (Eq. 11),
  5. PDD (or fastest-M_c) semi-synchronous edge selection (§IV-B),
  6. cloud aggregation (Eq. 17), staleness update (Eq. 20), cost (Eq. 23a).

The TPU-native mapping (DESIGN.md §3): the client axis is a vmap axis that
the mesh ``data`` dimension can shard, so edge aggregation is an in-group
reduce and cloud aggregation a masked cross-group reduce.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (aggregation, association, cost, ddpg, env, fuzzy,
                        noma, pdd, staleness)
from repro.data import federated
from repro.models.mlp import MLPClassifier

Params = Any


# ---------------------------------------------------------------------------
# Topology (paper §V: 500 m square, cloud at centre, 4 edges at midpoints
# of the corner-to-centre lines, clients uniform)
# ---------------------------------------------------------------------------

def make_topology(rng: np.random.Generator, *, n_clients: int, n_edges: int,
                  area_side_m: float) -> Dict[str, np.ndarray]:
    half = area_side_m / 2.0
    cloud = np.array([half, half])
    corners = np.array([[0.0, 0.0], [0.0, area_side_m],
                        [area_side_m, 0.0], [area_side_m, area_side_m]])
    mids = (corners + cloud) / 2.0
    if n_edges <= 4:
        edges = mids[:n_edges]
    else:  # extra edges uniformly placed
        extra = rng.uniform(0.0, area_side_m, (n_edges - 4, 2))
        edges = np.concatenate([mids, extra], axis=0)
    clients = rng.uniform(0.0, area_side_m, (n_clients, 2))
    dist = np.linalg.norm(clients[:, None, :] - edges[None, :, :], axis=-1)
    return {"cloud": cloud, "edges": edges, "clients": clients, "dist": dist}


# ---------------------------------------------------------------------------
# Local training (vmapped over the client axis)
# ---------------------------------------------------------------------------

def _local_sgd(model: MLPClassifier, lr: float, tau1: int, batch_size: int):
    """Returns a jitted fn: (params_N, x_N, y_N, count_N, key_N) -> params_N."""

    def one_client(params, x, y, count, key):
        cap = x.shape[0]

        def step(carry, k):
            p = carry
            idx = jax.random.randint(k, (batch_size,), 0, jnp.maximum(count, 1))
            bx, by = x[idx], y[idx]
            g = jax.grad(model.loss)(p, (bx, by))
            p = jax.tree.map(lambda w, gw: w - lr * gw, p, g)
            return p, None

        ks = jax.random.split(key, tau1)
        params, _ = jax.lax.scan(step, params, ks)
        return params

    return jax.jit(jax.vmap(one_client))


# ---------------------------------------------------------------------------
# Simulation
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RoundMetrics:
    round: int
    accuracy: float
    loss: float
    avg_staleness: float
    total_time_s: float
    total_energy_j: float
    cost: float
    n_associated: int
    z: np.ndarray


class HFLSimulation:
    """The paper's simulation: 64 clients, 4 edges, NOMA uplink, MNIST-like
    classification."""

    def __init__(self, cfg, *, seed: int = 0, iid: bool = True,
                 policy: str = "fcea", noma_enabled: bool = True,
                 allocator: str = "mid", scheduler: str = "pdd",
                 fading_rho: float = 0.9, oma_quota_factor: float = 0.5):
        self.cfg = cfg
        self.policy = policy
        self.noma_enabled = noma_enabled
        self.allocator = allocator
        self.scheduler = scheduler
        self.rho = fading_rho
        self.oma_quota_factor = oma_quota_factor
        self.rng = np.random.default_rng(seed)
        self.key = jax.random.key(seed)

        self.topo = make_topology(self.rng, n_clients=cfg.n_clients,
                                  n_edges=cfg.n_edges,
                                  area_side_m=cfg.area_side_m)
        self.data = federated.make_federated(
            self.rng, n_clients=cfg.n_clients, dim=cfg.input_dim,
            n_classes=cfg.n_classes, iid=iid,
            min_samples=cfg.min_samples, max_samples=cfg.max_samples,
            dirichlet_alpha=cfg.dirichlet_alpha,
            noise=getattr(cfg, "data_noise", 1.2))
        self.model = MLPClassifier(cfg.input_dim, cfg.hidden, cfg.n_classes)
        self.key, k = jax.random.split(self.key)
        self.global_params = self.model.init(k)
        self.client_params = aggregation.replicate(self.global_params,
                                                   cfg.n_clients)
        self.staleness = staleness.init_staleness(cfg.n_clients)
        self.round = 0
        # coverage: generous enough that every client can reach ≥1 edge
        self.coverage_m = cfg.area_side_m * 0.75
        self._local_fit = _local_sgd(self.model, cfg.lr, cfg.tau1,
                                     cfg.local_batch)
        dist = jnp.asarray(self.topo["dist"])
        self.key, k = jax.random.split(self.key)
        self.gains = noma.rayleigh_gains(
            k, dist, path_loss_exponent=cfg.path_loss_exponent)
        # DDPG agent (lazily trained by examples / benchmarks)
        self.agent: Optional[ddpg.DDPGState] = None
        self.agent_cfg: Optional[ddpg.DDPGConfig] = None

    # -- per-round pieces -----------------------------------------------------

    def _fade(self):
        self.key, k = jax.random.split(self.key)
        self.gains = noma.evolve_gains(
            k, self.gains, jnp.asarray(self.topo["dist"]),
            path_loss_exponent=self.cfg.path_loss_exponent, rho=self.rho)

    def _scores(self) -> np.ndarray:
        """(N, M) fuzzy competency: per-edge CQ, shared DQ and MS.

        CQ is normalised in dB (Eq. 21 on log-gain): raw |h|² spans four
        decades of path loss, so a linear V/MV map collapses all but the
        nearest clients to 0 — the dB scale is what 'channel quality'
        means in practice.
        """
        gains = np.asarray(self.gains)
        n, m = gains.shape
        db = 10.0 * np.log10(np.maximum(gains, 1e-30))
        lo, hi = db.min(), db.max()
        cq = np.asarray(fuzzy.normalize(
            jnp.asarray(db - lo), float(max(hi - lo, 1e-9))))
        dq = np.asarray(fuzzy.normalize(jnp.asarray(self.data.counts,
                                                    dtype=np.float32),
                                        float(self.cfg.max_samples)))
        ms = np.asarray(fuzzy.normalize(
            jnp.asarray(self.staleness, dtype=jnp.float32),
            float(max(np.max(np.asarray(self.staleness)), 1))))
        scores = np.zeros((n, m), np.float32)
        for j in range(m):
            scores[:, j] = np.asarray(
                fuzzy.fuzzy_scores(jnp.asarray(np.ascontiguousarray(
                    cq[:, j])), jnp.asarray(dq), jnp.asarray(ms)))
        return scores

    def _associate(self) -> np.ndarray:
        # OMA admits fewer clients per edge: each needs an orthogonal
        # channel slice (paper §V-B — "insufficient orchestrated clients")
        quota = self.cfg.clients_per_edge
        if not self.noma_enabled:
            quota = max(1, int(quota * self.oma_quota_factor))
        return association.associate(
            self.policy, scores=self._scores(),
            gains_to_edges=np.asarray(self.gains), dist=self.topo["dist"],
            quota=quota,
            coverage_radius_m=self.coverage_m, rng=self.rng)

    def _allocate(self, assoc: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
        """(p_w (N,), f_hz (N,)) per the configured allocator."""
        cfg = self.cfg
        n = cfg.n_clients
        self.key, k = jax.random.split(self.key)
        if self.allocator == "ddpg" and self.agent is not None:
            e = env.NomaHflEnv(cfg, assoc, jnp.ones((cfg.n_edges,)),
                               jnp.asarray(self.topo["dist"]),
                               jnp.asarray(self.data.counts, jnp.float32))
            obs = e._observe(self.gains)
            act = ddpg.actor_apply(self.agent.actor, obs)
            return e.decode_action(act)
        if self.allocator == "rra":
            a = jax.random.uniform(k, (2, n))
            p = cfg.p_min_w + a[0] * (cfg.p_max_w - cfg.p_min_w)
            f = cfg.f_min_hz + a[1] * (cfg.f_max_hz - cfg.f_min_hz)
            return p, f
        if self.allocator == "fpa":     # fixed power, optimised-ish freq
            p = jnp.full((n,), 0.5 * (cfg.p_min_w + cfg.p_max_w))
            f = jnp.full((n,), cfg.f_max_hz)
            return p, f
        if self.allocator == "fca":     # fixed computation, midpoint power
            p = jnp.full((n,), 0.5 * (cfg.p_min_w + cfg.p_max_w))
            f = jnp.full((n,), 0.5 * (cfg.f_min_hz + cfg.f_max_hz))
            return p, f
        # "mid": deterministic midpoint defaults
        p = jnp.full((n,), 0.5 * (cfg.p_min_w + cfg.p_max_w))
        f = jnp.full((n,), 0.5 * (cfg.f_min_hz + cfg.f_max_hz))
        return p, f

    def _schedule(self, assoc, p, f) -> Tuple[jnp.ndarray, cost.RoundCost]:
        """Semi-synchronous edge selection (z) + final round cost."""
        cfg = self.cfg
        quota = max(1, int(round(cfg.semi_sync_fraction * cfg.n_edges)))
        ones = jnp.ones((cfg.n_edges,))
        rc_all = cost.round_cost(cfg, power_w=p, f_hz=f, gains=self.gains,
                                 assoc=assoc, z=ones,
                                 n_samples=jnp.asarray(self.data.counts,
                                                       jnp.float32),
                                 noma_enabled=self.noma_enabled)
        if self.scheduler == "pdd":
            t_cloud = jnp.full((cfg.n_edges,),
                               cfg.edge_model_size_bits / cfg.edge_rate_bps)
            U = jnp.max(rc_all.client_time_s)
            res = pdd.pdd_schedule(rc_all.per_edge_energy_j, t_cloud, U,
                                   lam_t=cfg.lambda_t, lam_e=cfg.lambda_e,
                                   quota=quota)
            z = res.z_binary
        else:  # "fastest"
            z = pdd.semi_sync_fastest(rc_all.per_edge_time_s, quota)
        rc = cost.round_cost(cfg, power_w=p, f_hz=f, gains=self.gains,
                             assoc=assoc, z=z,
                             n_samples=jnp.asarray(self.data.counts,
                                                   jnp.float32),
                             noma_enabled=self.noma_enabled)
        return z, rc

    def _train_clients(self, assoc: jnp.ndarray, z: jnp.ndarray) -> None:
        """τ₂ edge iterations of (local SGD + edge aggregation), then the
        semi-synchronous cloud aggregation over the selected edges."""
        cfg = self.cfg
        counts = jnp.asarray(self.data.counts, jnp.float32)
        x = jnp.asarray(self.data.x)
        y = jnp.asarray(self.data.y)
        selected = jnp.sum(assoc, axis=1) > 0

        # associated clients start from the global model
        edge_params = aggregation.replicate(self.global_params, cfg.n_edges)
        client_params = aggregation.broadcast_to_clients(
            None, assoc, edge_params, self.client_params)

        for _ in range(cfg.tau2):
            self.key, k = jax.random.split(self.key)
            ks = jax.random.split(k, cfg.n_clients)
            trained = self._local_fit(client_params, x, y, counts, ks)
            # only associated clients actually train (others keep params)
            client_params = jax.tree.map(
                lambda new, old: jnp.where(
                    selected.reshape((-1,) + (1,) * (new.ndim - 1)),
                    new, old), trained, client_params)
            edge_params = aggregation.edge_aggregate(client_params, assoc,
                                                     counts)
            client_params = aggregation.broadcast_to_clients(
                None, assoc, edge_params, client_params)

        edge_data = jnp.sum(assoc * counts[:, None], axis=0)      # (M,)
        has_clients = (edge_data > 0).astype(z.dtype)
        z_eff = z * has_clients
        if float(jnp.sum(z_eff * edge_data)) > 0:
            self.global_params = aggregation.cloud_aggregate(
                edge_params, z_eff, edge_data)
        self.client_params = client_params

    # -- public API -------------------------------------------------------------

    def run_round(self) -> RoundMetrics:
        cfg = self.cfg
        self._fade()
        assoc_np = self._associate()
        assoc = jnp.asarray(assoc_np, jnp.float32)
        p, f = self._allocate(assoc)
        z, rc = self._schedule(assoc, p, f)
        self._train_clients(assoc, z)

        selected = np.asarray(assoc_np).sum(axis=1) > 0
        # Eq. 20: staleness resets only for clients whose edge was selected
        z_np = np.asarray(z) > 0
        effective = selected & z_np[np.argmax(assoc_np, axis=1)]
        self.staleness = staleness.update_staleness(
            self.staleness, jnp.asarray(effective))

        acc = float(self.model.accuracy(self.global_params,
                                        jnp.asarray(self.data.test_x),
                                        jnp.asarray(self.data.test_y)))
        loss = float(self.model.loss(self.global_params,
                                     (jnp.asarray(self.data.test_x),
                                      jnp.asarray(self.data.test_y))))
        self.round += 1
        return RoundMetrics(
            round=self.round, accuracy=acc, loss=loss,
            avg_staleness=float(jnp.mean(self.staleness.astype(jnp.float32))),
            total_time_s=float(rc.total_time_s),
            total_energy_j=float(rc.total_energy_j), cost=float(rc.cost),
            n_associated=int(selected.sum()), z=np.asarray(z))

    def run(self, n_rounds: int) -> list:
        return [self.run_round() for _ in range(n_rounds)]

    # -- DDPG training (paper Algorithm 2 driver) --------------------------------

    def train_ddpg(self, *, episodes: int = 20, steps_per_episode: int = 50,
                   warmup: int = 64, hidden: int = 128) -> Dict[str, list]:
        """Train the DDPG allocator on the current association's env."""
        cfg = self.cfg
        assoc = jnp.asarray(self._associate(), jnp.float32)
        e = env.NomaHflEnv(cfg, assoc, jnp.ones((cfg.n_edges,)),
                           jnp.asarray(self.topo["dist"]),
                           jnp.asarray(self.data.counts, jnp.float32),
                           fading_rho=self.rho)
        dcfg = ddpg.DDPGConfig(state_dim=e.state_dim, action_dim=e.action_dim,
                               hidden=hidden, buffer_size=4096, batch_size=64)
        self.key, k = jax.random.split(self.key)
        agent = ddpg.init_ddpg(k, dcfg)
        history: Dict[str, list] = {"episode_reward": []}
        total_steps = 0
        for ep in range(episodes):
            self.key, k = jax.random.split(self.key)
            state, obs = e.reset(k)
            ep_reward = 0.0
            for t in range(steps_per_episode):
                self.key, ka, kt = jax.random.split(self.key, 3)
                act = ddpg.select_action(ka, agent, obs)
                state, obs2, reward, _ = e.step(state, act)
                agent = ddpg.store(agent, dcfg, obs, act, reward, obs2)
                obs = obs2
                ep_reward += float(reward)
                total_steps += 1
                if total_steps >= warmup:
                    agent, _ = ddpg.train_step(kt, agent, dcfg)
            history["episode_reward"].append(ep_reward / steps_per_episode)
        self.agent, self.agent_cfg = agent, dcfg
        return history
