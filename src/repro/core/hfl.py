"""Stateful compatibility wrapper around the pure round engine.

The actual per-round physics/learning pipeline lives in
``repro.core.engine`` as the pure ``round_step`` (DESIGN.md §2); this module
keeps the familiar ``HFLSimulation`` object API on top of it:

* ``run_round()``     — one jitted ``round_step`` call (eager driver),
* ``run(n)``          — n eager rounds,
* ``run_scanned(n)``  — the whole experiment as one compiled ``lax.scan``,
* ``train_ddpg(...)`` — paper Algorithm 2 driver for the DDPG allocator.

Both drivers advance the SAME ``RoundState`` pytree through the SAME pure
function, so eager and scanned runs are bit-for-bit interchangeable (the
parity tests in tests/test_round_engine.py assert it).  For multi-seed
sweeps use ``engine.run_fleet`` directly.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import scenarios
from repro.core import association, ddpg, engine
from repro.core.engine import (EngineSpec, RoundBundle, RoundState,
                               make_topology)

__all__ = ["HFLSimulation", "RoundMetrics", "make_topology"]


@dataclasses.dataclass
class RoundMetrics:
    """Host-side (float/ndarray) view of one round — the legacy record."""
    round: int
    accuracy: float
    loss: float
    avg_staleness: float
    total_time_s: float
    total_energy_j: float
    cost: float
    n_associated: int
    n_available: int
    z: np.ndarray

    @classmethod
    def from_engine(cls, m: engine.RoundMetrics,
                    i: Optional[int] = None) -> "RoundMetrics":
        return cls(**engine.metrics_row(m, i))


class HFLSimulation:
    """The paper's simulation: 64 clients, 4 edges, NOMA uplink, MNIST-like
    classification — now a thin shell holding a ``RoundState``."""

    def __init__(self, cfg, *, seed: int = 0, iid: bool = True,
                 policy: str = "fcea", noma_enabled: bool = True,
                 allocator: str = "mid", scheduler: str = "pdd",
                 fading_rho: float = 0.9, oma_quota_factor: float = 0.5,
                 scenario=None):
        if policy not in association.POLICIES:
            raise ValueError(f"unknown association policy {policy!r}")
        self.cfg = cfg
        sspec = scenarios.preset(scenario)
        self.scenario_spec = sspec
        self.spec = EngineSpec(policy=policy, allocator=allocator,
                               scheduler=scheduler,
                               noma_enabled=noma_enabled,
                               fading_rho=fading_rho,
                               oma_quota_factor=oma_quota_factor,
                               scenario=sspec.engine_kind())
        self._state, self.bundle, aux = engine.init_simulation(
            cfg, seed=seed, iid=iid, scenario=sspec)
        self.topo = aux["topo"]
        self.data = aux["data"]
        self.model = aux["model"]
        self.rng = aux["rng"]
        self.coverage_m = engine.coverage_radius(cfg)
        # DDPG agent (lazily trained by examples / benchmarks)
        self.agent: Optional[ddpg.DDPGState] = None
        self.agent_cfg: Optional[ddpg.DDPGConfig] = None

    # -- state views (legacy attribute API) -----------------------------------

    @property
    def state(self) -> RoundState:
        return self._state

    @property
    def policy(self) -> str:
        return self.spec.policy

    @property
    def noma_enabled(self) -> bool:
        return self.spec.noma_enabled

    @property
    def allocator(self) -> str:
        return self.spec.allocator

    @property
    def scheduler(self) -> str:
        return self.spec.scheduler

    @property
    def gains(self) -> jnp.ndarray:
        return self._state.gains

    @property
    def staleness(self) -> jnp.ndarray:
        return self._state.staleness

    @property
    def global_params(self):
        return self._state.global_params

    @property
    def client_params(self):
        return self._state.client_params

    @property
    def round(self) -> int:
        return int(self._state.round_idx)

    def _actor_params(self):
        return self.agent.actor if self.agent is not None else None

    # -- association snapshot (used by the DDPG trainer / benchmarks) ----------

    def _associate(self) -> np.ndarray:
        """One-off association on the CURRENT state (does not advance it)."""
        return np.asarray(engine.associate_snapshot(
            self.cfg, self.spec, self._state, self.bundle))

    # -- public API -------------------------------------------------------------

    def run_round(self) -> RoundMetrics:
        self._state, m = engine.round_step_jit(
            self.cfg, self.spec, self._state, self.bundle,
            self._actor_params())
        return RoundMetrics.from_engine(m)

    def run(self, n_rounds: int) -> List[RoundMetrics]:
        return [self.run_round() for _ in range(n_rounds)]

    def run_scanned(self, n_rounds: int) -> List[RoundMetrics]:
        """Same trajectory as ``run``, but as ONE compiled XLA program."""
        self._state, ms = engine.run_scanned(
            self.cfg, self.spec, self._state, self.bundle, n_rounds,
            self._actor_params())
        ms_host = jax.tree.map(np.asarray, ms)    # one transfer per leaf
        return [RoundMetrics.from_engine(ms_host, i)
                for i in range(n_rounds)]

    # -- DDPG training (paper Algorithm 2 driver) --------------------------------

    def train_ddpg(self, *, episodes: int = 20, steps_per_episode: int = 50,
                   warmup: int = 64, hidden: int = 128) -> Dict[str, list]:
        """Train the DDPG allocator on the current association's env.

        A thin shell over the pure scanned driver ``ddpg.train_allocator``
        (DESIGN.md §7): the whole of Algorithm 2 runs as one compiled XLA
        program; this wrapper only advances the simulation key and keeps
        the legacy list-of-floats history shape."""
        key, k_train = jax.random.split(self._state.key)
        dcfg = ddpg.allocator_config(self.cfg, self.spec, hidden=hidden)
        agent, history = ddpg.train_allocator(
            self.cfg, self.spec, self._state, self.bundle, dcfg, k_train,
            episodes=episodes, steps_per_episode=steps_per_episode,
            warmup=warmup)
        self.agent, self.agent_cfg = jax.block_until_ready(agent), dcfg
        self._state = self._state._replace(key=key)
        return {k: np.asarray(v).tolist() for k, v in history.items()}
