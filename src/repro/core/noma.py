"""NOMA uplink model (paper §II-A2): SIC decoding order, SINR, rates.

Clients associated with one edge server transmit simultaneously on the same
channel.  The receiver decodes in descending received power
p_n·|h_{n,m}|² (paper's assumption), so client n's interference is the sum of
the received powers decoded *after* it (Eq. 7).  Rates follow Shannon
(Eq. 8).  All functions are pure jnp over per-edge client vectors; masked
entries (non-associated slots) carry zero power.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def rayleigh_gains(key, dist_m: jnp.ndarray, *, path_loss_exponent: float
                   ) -> jnp.ndarray:
    """|h|² gains: distance path loss × unit-mean Rayleigh fading power."""
    pl = jnp.maximum(dist_m, 1.0) ** (-path_loss_exponent)
    # |CN(0,1)|² is Exp(1)
    fading = jax.random.exponential(key, dist_m.shape)
    return pl * fading


def evolve_gains(key, gains: jnp.ndarray, dist_m: jnp.ndarray, *,
                 path_loss_exponent: float, rho: float = 0.9) -> jnp.ndarray:
    """First-order Gauss-Markov fading: keeps the dry channel time-varying."""
    fresh = rayleigh_gains(key, dist_m, path_loss_exponent=path_loss_exponent)
    return rho * gains + (1.0 - rho) * fresh


def sic_sinr(power_w: jnp.ndarray, gain: jnp.ndarray, noise_w: float,
             mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Per-client SINR under SIC (Eq. 7), returned in the input order.

    power_w, gain: (K,) per-client transmit power and |h|² gain.
    mask: (K,) bool — False entries are absent clients (zero contribution).
    """
    rx = power_w * gain
    if mask is not None:
        rx = jnp.where(mask, rx, 0.0)
    # Sort-free SIC: client i's interference is the sum of received powers
    # decoded AFTER it, i.e. those strictly weaker (index tie-break).  The
    # pairwise form is O(K²) on K ≤ tens of clients, gather-free (vmap- and
    # grad-friendly), and equals the sorted cumulative-sum formulation.
    k = rx.shape[-1]
    idx = jnp.arange(k)
    weaker = (rx[None, :] < rx[:, None]) | \
        ((rx[None, :] == rx[:, None]) & (idx[None, :] > idx[:, None]))
    interference = jnp.sum(jnp.where(weaker, rx[None, :], 0.0), axis=-1)
    sinr = rx / (interference + noise_w)
    if mask is not None:
        sinr = jnp.where(mask, sinr, 0.0)
    return sinr


def achievable_rates(power_w: jnp.ndarray, gain: jnp.ndarray, *,
                     bandwidth_hz: float, noise_w: float,
                     mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Eq. 8: R = B log2(1 + SINR), in bits/s."""
    sinr = sic_sinr(power_w, gain, noise_w, mask)
    return bandwidth_hz * jnp.log2(1.0 + sinr)


def sic_rates_matrix(power_w: jnp.ndarray, gains: jnp.ndarray,
                     mask: jnp.ndarray, *, bandwidth_hz: float,
                     noise_w: float,
                     max_per_edge: int | None = None) -> jnp.ndarray:
    """All M edges' SIC rates in one shot: (N,) power, (N, M) gains/mask
    -> (N, M) rates (masked entries zero).

    The sorted cumulative-interference formulation of Eqs. 7-8: per edge,
    decode in descending received power (stable sort, so exact-power ties
    break on the lower client index — the same order as ``sic_sinr``'s
    pairwise tie-break) and read each client's interference off a reversed
    cumulative sum.  O(N log N) per edge instead of the pairwise O(N²),
    and ONE program for all edges — this is what lets ``cost.uplink``
    scale past ~10³ clients, where the pairwise form would materialise an
    (N, N) block per edge (2 GB of temps at 4096×32).  Equal to the
    pairwise form up to float summation order (parity-tested).

    ``max_per_edge``: a STATIC upper bound on the number of unmasked
    clients per edge (the engine passes its admission quota).  When
    given, a ``lax.top_k`` of that many candidates replaces the full-N
    sort — the masked-out majority carries zero received power and
    neither interferes nor rates, so only the bound must be honest
    (a tighter decode set would silently drop interferers).
    """
    rx = jnp.where(mask, power_w[:, None] * gains, 0.0)          # (N, M)
    if max_per_edge is not None and max_per_edge < rx.shape[0]:
        k = max_per_edge
        srx, sidx = jax.lax.top_k(rx.T, k)                       # (M, k)
        csum = jnp.cumsum(srx, axis=1)
        interference = jnp.maximum(csum[:, -1:] - csum, 0.0)
        sinr = srx / (interference + noise_w)
        rate = bandwidth_hz * jnp.log2(1.0 + sinr)               # (M, k)
        m_edges = rx.shape[1]
        out = jnp.zeros((m_edges, rx.shape[0]), rate.dtype)
        out = out.at[jnp.arange(m_edges)[:, None], sidx].set(rate)
        return jnp.where(mask, out.T, 0.0)
    order = jnp.argsort(-rx, axis=0)          # stable: ties by client index
    srx = jnp.take_along_axis(rx, order, axis=0)
    csum = jnp.cumsum(srx, axis=0)
    # interference = received power decoded after me (strictly weaker)
    interference = jnp.maximum(csum[-1:] - csum, 0.0)
    sinr = srx / (interference + noise_w)
    rate = bandwidth_hz * jnp.log2(1.0 + sinr)
    inv = jnp.argsort(order, axis=0)
    return jnp.where(mask, jnp.take_along_axis(rate, inv, axis=0), 0.0)


def sic_rates_assigned(power_w: jnp.ndarray, own_gain: jnp.ndarray,
                       assigned: jnp.ndarray, *, n_edges: int,
                       max_per_edge: int, bandwidth_hz: float,
                       noise_w: float) -> jnp.ndarray:
    """SIC rates from the COMPACT association (DESIGN.md §9): (N,) power,
    (N,) gain to the assigned edge, (N,) assigned edge (−1 = unmatched)
    -> (N,) rates at each client's own edge, 0.0 for unmatched clients.

    Bit-identical to the dense top-k ``sic_rates_matrix`` read at the
    associated pairs: one lexsort groups clients by (edge, received power
    desc, client index) — the exact decode order of the sorted and
    pairwise forms — and a scatter builds the same (M, k) per-edge decode
    table ``lax.top_k`` would, zeros in the empty slots; the cumulative-
    interference/SINR/rate arithmetic then runs the identical code on
    identical values.  No (N, M) tensor is ever touched: the cost is
    O(N log N) for the sort plus O(M·k) table work.

    ``max_per_edge`` must bound the true per-edge occupancy (the engine
    passes its admission quota), exactly like ``sic_rates_matrix``.
    """
    n = power_w.shape[0]
    k = min(int(max_per_edge), n)
    matched = assigned >= 0
    rx = jnp.where(matched, power_w * own_gain, 0.0)             # (N,)
    edge_key = jnp.where(matched, assigned, n_edges)             # sentinel
    # (edge asc, rx desc, client asc): stable lexsort, flat order = client
    perm = jnp.lexsort((-rx, edge_key))                          # (N,)
    se = edge_key[perm]
    iota = jnp.arange(n, dtype=jnp.int32)
    is_start = jnp.concatenate([jnp.ones((1,), bool), se[1:] != se[:-1]])
    pos = iota - jax.lax.cummax(jnp.where(is_start, iota, 0))    # in-segment
    # the same (M, k) decode table top_k would build: descending rx per
    # edge, ties already broken on the lower client index by the sort
    tbl_e = jnp.where((se < n_edges) & (pos < k), se, n_edges)
    tbl_p = jnp.minimum(pos, k - 1)
    srx = jnp.zeros((n_edges, k), rx.dtype).at[tbl_e, tbl_p].set(
        rx[perm], mode="drop")
    csum = jnp.cumsum(srx, axis=1)
    interference = jnp.maximum(csum[:, -1:] - csum, 0.0)
    sinr = srx / (interference + noise_w)
    rate = bandwidth_hz * jnp.log2(1.0 + sinr)                   # (M, k)
    # back to client order: client at sorted slot i sits at table cell
    # (se[i], pos[i]); unmatched (sentinel) clients rate 0
    rate_sorted = jnp.where((se < n_edges) & (pos < k),
                            rate[jnp.minimum(se, n_edges - 1), tbl_p], 0.0)
    out = jnp.zeros((n,), rate.dtype).at[perm].set(rate_sorted)
    return jnp.where(matched, out, 0.0)


def noise_power_w(noise_dbm_per_hz: float, bandwidth_hz: float) -> float:
    """AWGN power over the band: σ² = N0 · B."""
    return 10.0 ** (noise_dbm_per_hz / 10.0) / 1000.0 * bandwidth_hz


def sum_rate_upper_bound(power_w: jnp.ndarray, gain: jnp.ndarray, *,
                         bandwidth_hz: float, noise_w: float) -> jnp.ndarray:
    """Multiple-access capacity: B log2(1 + Σ p g / σ²).

    SIC achieves exactly this bound (property-tested) — the classic NOMA
    sum-rate identity.
    """
    total = jnp.sum(power_w * gain)
    return bandwidth_hz * jnp.log2(1.0 + total / noise_w)
