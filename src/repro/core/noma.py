"""NOMA uplink model (paper §II-A2): SIC decoding order, SINR, rates.

Clients associated with one edge server transmit simultaneously on the same
channel.  The receiver decodes in descending received power
p_n·|h_{n,m}|² (paper's assumption), so client n's interference is the sum of
the received powers decoded *after* it (Eq. 7).  Rates follow Shannon
(Eq. 8).  All functions are pure jnp over per-edge client vectors; masked
entries (non-associated slots) carry zero power.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def rayleigh_gains(key, dist_m: jnp.ndarray, *, path_loss_exponent: float
                   ) -> jnp.ndarray:
    """|h|² gains: distance path loss × unit-mean Rayleigh fading power."""
    pl = jnp.maximum(dist_m, 1.0) ** (-path_loss_exponent)
    # |CN(0,1)|² is Exp(1)
    fading = jax.random.exponential(key, dist_m.shape)
    return pl * fading


def evolve_gains(key, gains: jnp.ndarray, dist_m: jnp.ndarray, *,
                 path_loss_exponent: float, rho: float = 0.9) -> jnp.ndarray:
    """First-order Gauss-Markov fading: keeps the dry channel time-varying."""
    fresh = rayleigh_gains(key, dist_m, path_loss_exponent=path_loss_exponent)
    return rho * gains + (1.0 - rho) * fresh


def sic_sinr(power_w: jnp.ndarray, gain: jnp.ndarray, noise_w: float,
             mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Per-client SINR under SIC (Eq. 7), returned in the input order.

    power_w, gain: (K,) per-client transmit power and |h|² gain.
    mask: (K,) bool — False entries are absent clients (zero contribution).
    """
    rx = power_w * gain
    if mask is not None:
        rx = jnp.where(mask, rx, 0.0)
    # Sort-free SIC: client i's interference is the sum of received powers
    # decoded AFTER it, i.e. those strictly weaker (index tie-break).  The
    # pairwise form is O(K²) on K ≤ tens of clients, gather-free (vmap- and
    # grad-friendly), and equals the sorted cumulative-sum formulation.
    k = rx.shape[-1]
    idx = jnp.arange(k)
    weaker = (rx[None, :] < rx[:, None]) | \
        ((rx[None, :] == rx[:, None]) & (idx[None, :] > idx[:, None]))
    interference = jnp.sum(jnp.where(weaker, rx[None, :], 0.0), axis=-1)
    sinr = rx / (interference + noise_w)
    if mask is not None:
        sinr = jnp.where(mask, sinr, 0.0)
    return sinr


def achievable_rates(power_w: jnp.ndarray, gain: jnp.ndarray, *,
                     bandwidth_hz: float, noise_w: float,
                     mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Eq. 8: R = B log2(1 + SINR), in bits/s."""
    sinr = sic_sinr(power_w, gain, noise_w, mask)
    return bandwidth_hz * jnp.log2(1.0 + sinr)


def noise_power_w(noise_dbm_per_hz: float, bandwidth_hz: float) -> float:
    """AWGN power over the band: σ² = N0 · B."""
    return 10.0 ** (noise_dbm_per_hz / 10.0) / 1000.0 * bandwidth_hz


def sum_rate_upper_bound(power_w: jnp.ndarray, gain: jnp.ndarray, *,
                         bandwidth_hz: float, noise_w: float) -> jnp.ndarray:
    """Multiple-access capacity: B log2(1 + Σ p g / σ²).

    SIC achieves exactly this bound (property-tested) — the classic NOMA
    sum-rate identity.
    """
    total = jnp.sum(power_w * gain)
    return bandwidth_hz * jnp.log2(1.0 + total / noise_w)
