"""Penalty-dual-decomposition edge-server scheduling (paper §IV-B, Alg. 1).

Solves problem (24): min over z ∈ {0,1}^M of  λt·W + λe·Σ z_m E_m  with
W = max_m z_m (T_m^cloud + U), using the paper's double loop:

* inner loop — block-coordinate closed forms: z̃* (Eqs. 26-27), z* (Lemma 1 /
  Eq. 29), U*, W* (Eqs. 32-33), plus a projected-subgradient step on the
  multiplier γ_m of constraint (28b);
* outer loop — dual updates (Eqs. 34-35) and penalty shrink v ← c·v.

One documented deviation (DESIGN.md §3): the paper's objective admits the
degenerate z = 0 (select nothing, pay nothing).  Its semi-synchronous
mechanism in fact requires M_c edge servers per cloud round (§II-B2), so we
add the quota Σ z_m = M_c as one more penalised equality with its own dual
variable — squarely inside the PDD framework.  Setting ``quota=None``
recovers the paper's literal formulation.

All updates are pure jnp and the whole solver is jittable (lax.fori_loop).
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class PDDResult(NamedTuple):
    z: jnp.ndarray             # (M,) relaxed solution in [0, 1]
    z_binary: jnp.ndarray      # (M,) rounded {0, 1}
    objective: jnp.ndarray     # λt·W + λe·Σ z E at the binary point
    W: jnp.ndarray
    residual: jnp.ndarray      # max |z - z̃| + |z(1-z̃)| (PDD feasibility)
    iterations: jnp.ndarray


def _objective(z, U, edge_energy, t_cloud, lam_t, lam_e):
    W = jnp.max(z * (t_cloud + U))
    return lam_t * W + lam_e * jnp.sum(z * edge_energy)


@functools.partial(jax.jit, static_argnames=("outer_iters", "inner_iters",
                                             "quota"))
def pdd_schedule(edge_energy: jnp.ndarray, t_cloud: jnp.ndarray,
                 U: jnp.ndarray, *, lam_t: float, lam_e: float,
                 quota: Optional[int] = None,
                 outer_iters: int = 30, inner_iters: int = 40,
                 v0: float = 1.0, v_shrink: float = 0.8) -> PDDResult:
    """edge_energy (M,) = E_m^cloud + E^edge; t_cloud (M,); U (Eq. 32) is
    the edge-iteration time — a scalar in the paper's formulation, or (M,)
    per-edge (the engine passes τ₂·max_{n∈N_m} t_n so the objective is
    exactly the billed Eq. 23a cost; every update broadcasts)."""
    m = edge_energy.shape[0]
    tu = t_cloud + U

    def inner_body(_, state):
        z, zt, q, qt, gamma, mu, W, v = state
        # --- z̃ update, Eqs. 26-27 (closed form, then clip) ----------------
        zt_u = (z ** 2 + q * z * v + z + qt * v) / (z ** 2 + 1.0)
        zt = jnp.clip(zt_u, 0.0, 1.0)
        # --- z update, Lemma 1 / Eq. 29 ------------------------------------
        I_m = (zt / v - qt - q * (1.0 - zt)
               - lam_e * edge_energy - gamma * tu)
        if quota is not None:
            # quota equality Σz = M_c enters the AL: + (Σz - Mc + v·mu)²/(2v)
            I_m = I_m - mu - (jnp.sum(z) - quota) / v
        z = jnp.clip(I_m * v / (1.0 + (1.0 - zt) ** 2), 0.0, 1.0)
        # --- W update, Eq. 33 ------------------------------------------------
        W = jnp.max(z * tu)
        # --- γ projected subgradient on constraint (28b) ---------------------
        gamma = jnp.maximum(0.0, gamma + (z * tu - W) / jnp.maximum(v, 1e-6)
                            * 0.1)
        return z, zt, q, qt, gamma, mu, W, v

    def outer_body(_, state):
        z, zt, q, qt, gamma, mu, W, v = state
        state = jax.lax.fori_loop(0, inner_iters, inner_body, state)
        z, zt, q, qt, gamma, mu, W, v = state
        # --- dual updates, Eqs. 34-35 ---------------------------------------
        q = q + (z * (1.0 - zt)) / v
        qt = qt + (z - zt) / v
        if quota is not None:
            mu = mu + (jnp.sum(z) - quota) / v
        v = v * v_shrink
        return z, zt, q, qt, gamma, mu, W, v

    z0 = jnp.full((m,), 0.5)
    state = (z0, z0, jnp.zeros(m), jnp.zeros(m), jnp.zeros(m),
             jnp.zeros(()), jnp.max(tu), jnp.asarray(v0))
    state = jax.lax.fori_loop(0, outer_iters, outer_body, state)
    z, zt, q, qt, gamma, mu, W, v = state

    if quota is not None:
        # deterministic rounding to exactly M_c servers (largest z first)
        thresh = jnp.sort(z)[m - quota]
        z_bin = (z >= thresh).astype(jnp.float32)
        # tie-break: keep exactly `quota`
        excess = jnp.cumsum(z_bin) > quota
        z_bin = jnp.where(excess, 0.0, z_bin)
    else:
        z_bin = (z > 0.5).astype(jnp.float32)

    residual = jnp.max(jnp.abs(z - zt)) + jnp.max(jnp.abs(z * (1.0 - zt)))
    obj = _objective(z_bin, U, edge_energy, t_cloud, lam_t, lam_e)
    return PDDResult(z, z_bin, obj, jnp.max(z_bin * tu), residual,
                     jnp.asarray(outer_iters * inner_iters))


def semi_sync_fastest(per_edge_time: jnp.ndarray, quota: int) -> jnp.ndarray:
    """Paper §II-B2 baseline selector: the M_c fastest edge servers."""
    m = per_edge_time.shape[0]
    order = jnp.argsort(per_edge_time)
    z = jnp.zeros((m,)).at[order[:quota]].set(1.0)
    return z
