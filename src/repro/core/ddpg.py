"""DDPG resource allocation (paper §IV-C, Algorithm 2) in pure JAX.

Actor–critic with target networks, experience replay and soft updates
(Lillicrap et al. [38]).  All clients form ONE agent (paper's choice): the
state stacks every associated client's channel gain and data size, the
action is the 2·K vector of (transmit power, CPU frequency) per client.

Every update is jitted; an entire episode (env rollout + learning) can run
inside ``lax.scan`` because the NOMA/cost environment is pure JAX too.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Networks
# ---------------------------------------------------------------------------

def _mlp_init(key, sizes) -> Params:
    ks = jax.random.split(key, len(sizes) - 1)
    return {f"w{i}": layers.scaled_init(ks[i], (sizes[i], sizes[i + 1]),
                                        jnp.float32)
            for i in range(len(sizes) - 1)} | \
           {f"b{i}": jnp.zeros((sizes[i + 1],), jnp.float32)
            for i in range(len(sizes) - 1)}


def _mlp_apply(params: Params, x: jnp.ndarray, n_layers: int) -> jnp.ndarray:
    for i in range(n_layers):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n_layers - 1:
            x = jax.nn.relu(x)
    return x


class DDPGConfig(NamedTuple):
    state_dim: int
    action_dim: int
    hidden: int = 256
    actor_lr: float = 1e-3
    critic_lr: float = 1e-3
    gamma: float = 0.99          # ψ discount
    tau: float = 0.005           # ζ soft-update speed (Eq. 40)
    buffer_size: int = 20_000
    batch_size: int = 64
    noise_sigma: float = 0.1
    noise_decay: float = 0.999


class DDPGState(NamedTuple):
    actor: Params
    critic: Params
    target_actor: Params
    target_critic: Params
    actor_opt: Params
    critic_opt: Params
    buffer: Params               # {"s","a","r","s2"} ring arrays
    buffer_idx: jnp.ndarray
    buffer_full: jnp.ndarray
    noise_sigma: jnp.ndarray
    step: jnp.ndarray


def init_ddpg(key, cfg: DDPGConfig) -> DDPGState:
    ka, kc = jax.random.split(key)
    actor = _mlp_init(ka, (cfg.state_dim, cfg.hidden, cfg.hidden,
                           cfg.action_dim))
    critic = _mlp_init(kc, (cfg.state_dim + cfg.action_dim, cfg.hidden,
                            cfg.hidden, 1))
    zeros_like = lambda p: jax.tree.map(jnp.zeros_like, p)
    buffer = {
        "s": jnp.zeros((cfg.buffer_size, cfg.state_dim)),
        "a": jnp.zeros((cfg.buffer_size, cfg.action_dim)),
        "r": jnp.zeros((cfg.buffer_size,)),
        "s2": jnp.zeros((cfg.buffer_size, cfg.state_dim)),
    }
    return DDPGState(actor, critic, jax.tree.map(jnp.copy, actor),
                     jax.tree.map(jnp.copy, critic),
                     {"m": zeros_like(actor), "v": zeros_like(actor)},
                     {"m": zeros_like(critic), "v": zeros_like(critic)},
                     buffer, jnp.zeros((), jnp.int32),
                     jnp.zeros((), jnp.bool_),
                     jnp.asarray(cfg.noise_sigma), jnp.zeros((), jnp.int32))


def actor_apply(params: Params, state: jnp.ndarray) -> jnp.ndarray:
    """State -> action in [0, 1]^A (env rescales to physical bounds)."""
    return jax.nn.sigmoid(_mlp_apply(params, state, 3))


def critic_apply(params: Params, state: jnp.ndarray, action: jnp.ndarray
                 ) -> jnp.ndarray:
    return _mlp_apply(params, jnp.concatenate([state, action], -1), 3)[..., 0]


def select_action(key, ddpg: DDPGState, state: jnp.ndarray) -> jnp.ndarray:
    """Algorithm 2 line 8: A = ν(S|θ) + exploration noise, clipped."""
    a = actor_apply(ddpg.actor, state)
    noise = ddpg.noise_sigma * jax.random.normal(key, a.shape)
    return jnp.clip(a + noise, 0.0, 1.0)


# ---------------------------------------------------------------------------
# Replay + Adam + updates
# ---------------------------------------------------------------------------

def store(ddpg: DDPGState, cfg: DDPGConfig, s, a, r, s2) -> DDPGState:
    i = ddpg.buffer_idx
    buf = {
        "s": ddpg.buffer["s"].at[i].set(s),
        "a": ddpg.buffer["a"].at[i].set(a),
        "r": ddpg.buffer["r"].at[i].set(r),
        "s2": ddpg.buffer["s2"].at[i].set(s2),
    }
    nxt = (i + 1) % cfg.buffer_size
    return ddpg._replace(buffer=buf, buffer_idx=nxt,
                         buffer_full=ddpg.buffer_full | (nxt == 0))


def _adam(params, grads, opt, lr, step, b1=0.9, b2=0.999, eps=1e-8):
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)
    t = step.astype(jnp.float32) + 1.0
    mhat = jax.tree.map(lambda x: x / (1 - b1 ** t), m)
    vhat = jax.tree.map(lambda x: x / (1 - b2 ** t), v)
    new = jax.tree.map(lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps),
                       params, mhat, vhat)
    return new, {"m": m, "v": v}


@functools.partial(jax.jit, static_argnames=("cfg",))
def train_step(key, ddpg: DDPGState, cfg: DDPGConfig) -> Tuple[DDPGState, Dict]:
    """One mini-batch update of critic (Eq. 38) + actor (Eq. 39) + targets (Eq. 40)."""
    size = jnp.where(ddpg.buffer_full, cfg.buffer_size, ddpg.buffer_idx)
    size = jnp.maximum(size, 1)
    idx = jax.random.randint(key, (cfg.batch_size,), 0, size)
    s = ddpg.buffer["s"][idx]
    a = ddpg.buffer["a"][idx]
    r = ddpg.buffer["r"][idx]
    s2 = ddpg.buffer["s2"][idx]

    # y_j = R_j + ψ Q'(S_{j+1}, ν'(S_{j+1}))
    a2 = actor_apply(ddpg.target_actor, s2)
    y = r + cfg.gamma * critic_apply(ddpg.target_critic, s2, a2)

    def critic_loss(cp):
        q = critic_apply(cp, s, a)
        return jnp.mean((y - q) ** 2)

    cl, cg = jax.value_and_grad(critic_loss)(ddpg.critic)
    critic, critic_opt = _adam(ddpg.critic, cg, ddpg.critic_opt,
                               cfg.critic_lr, ddpg.step)

    def actor_loss(ap):
        return -jnp.mean(critic_apply(critic, s, actor_apply(ap, s)))

    al, ag = jax.value_and_grad(actor_loss)(ddpg.actor)
    actor, actor_opt = _adam(ddpg.actor, ag, ddpg.actor_opt,
                             cfg.actor_lr, ddpg.step)

    soft = lambda t, o: jax.tree.map(
        lambda tt, oo: (1 - cfg.tau) * tt + cfg.tau * oo, t, o)
    new = ddpg._replace(
        actor=actor, critic=critic,
        target_actor=soft(ddpg.target_actor, actor),
        target_critic=soft(ddpg.target_critic, critic),
        actor_opt=actor_opt, critic_opt=critic_opt,
        noise_sigma=ddpg.noise_sigma * cfg.noise_decay,
        step=ddpg.step + 1)
    return new, {"critic_loss": cl, "actor_loss": al}
