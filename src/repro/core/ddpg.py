"""DDPG resource allocation (paper §IV-C, Algorithm 2) in pure JAX.

Actor–critic with target networks, experience replay and soft updates
(Lillicrap et al. [38]).  All clients form ONE agent (paper's choice): the
state stacks every associated client's channel gain and data size, the
action is the 2·K vector of (transmit power, CPU frequency) per client.

Every update is jitted; an entire episode (env rollout + learning) can run
inside ``lax.scan`` because the NOMA/cost environment is pure JAX too.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import layers

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Networks
# ---------------------------------------------------------------------------

def _mlp_init(key, sizes) -> Params:
    ks = jax.random.split(key, len(sizes) - 1)
    return {f"w{i}": layers.scaled_init(ks[i], (sizes[i], sizes[i + 1]),
                                        jnp.float32)
            for i in range(len(sizes) - 1)} | \
           {f"b{i}": jnp.zeros((sizes[i + 1],), jnp.float32)
            for i in range(len(sizes) - 1)}


def _mlp_apply(params: Params, x: jnp.ndarray, n_layers: int) -> jnp.ndarray:
    for i in range(n_layers):
        x = x @ params[f"w{i}"] + params[f"b{i}"]
        if i < n_layers - 1:
            x = jax.nn.relu(x)
    return x


class DDPGConfig(NamedTuple):
    state_dim: int
    action_dim: int
    hidden: int = 256
    actor_lr: float = 1e-3
    critic_lr: float = 1e-3
    gamma: float = 0.99          # ψ discount
    tau: float = 0.005           # ζ soft-update speed (Eq. 40)
    buffer_size: int = 20_000
    batch_size: int = 64
    noise_sigma: float = 0.1
    noise_decay: float = 0.999


class DDPGState(NamedTuple):
    actor: Params
    critic: Params
    target_actor: Params
    target_critic: Params
    actor_opt: Params
    critic_opt: Params
    buffer: Params               # {"s","a","r","s2"} ring arrays
    buffer_idx: jnp.ndarray
    buffer_full: jnp.ndarray
    noise_sigma: jnp.ndarray
    step: jnp.ndarray


def init_ddpg(key, cfg: DDPGConfig) -> DDPGState:
    ka, kc = jax.random.split(key)
    actor = _mlp_init(ka, (cfg.state_dim, cfg.hidden, cfg.hidden,
                           cfg.action_dim))
    critic = _mlp_init(kc, (cfg.state_dim + cfg.action_dim, cfg.hidden,
                            cfg.hidden, 1))
    zeros_like = lambda p: jax.tree.map(jnp.zeros_like, p)
    buffer = {
        "s": jnp.zeros((cfg.buffer_size, cfg.state_dim)),
        "a": jnp.zeros((cfg.buffer_size, cfg.action_dim)),
        "r": jnp.zeros((cfg.buffer_size,)),
        "s2": jnp.zeros((cfg.buffer_size, cfg.state_dim)),
    }
    return DDPGState(actor, critic, jax.tree.map(jnp.copy, actor),
                     jax.tree.map(jnp.copy, critic),
                     {"m": zeros_like(actor), "v": zeros_like(actor)},
                     {"m": zeros_like(critic), "v": zeros_like(critic)},
                     buffer, jnp.zeros((), jnp.int32),
                     jnp.zeros((), jnp.bool_),
                     jnp.asarray(cfg.noise_sigma), jnp.zeros((), jnp.int32))


def actor_apply(params: Params, state: jnp.ndarray) -> jnp.ndarray:
    """State -> action in [0, 1]^A (env rescales to physical bounds)."""
    return jax.nn.sigmoid(_mlp_apply(params, state, 3))


def critic_apply(params: Params, state: jnp.ndarray, action: jnp.ndarray
                 ) -> jnp.ndarray:
    return _mlp_apply(params, jnp.concatenate([state, action], -1), 3)[..., 0]


def select_action(key, ddpg: DDPGState, state: jnp.ndarray) -> jnp.ndarray:
    """Algorithm 2 line 8: A = ν(S|θ) + exploration noise, clipped."""
    a = actor_apply(ddpg.actor, state)
    noise = ddpg.noise_sigma * jax.random.normal(key, a.shape)
    return jnp.clip(a + noise, 0.0, 1.0)


# ---------------------------------------------------------------------------
# Replay + Adam + updates
# ---------------------------------------------------------------------------

def store(ddpg: DDPGState, cfg: DDPGConfig, s, a, r, s2) -> DDPGState:
    i = ddpg.buffer_idx
    buf = {
        "s": ddpg.buffer["s"].at[i].set(s),
        "a": ddpg.buffer["a"].at[i].set(a),
        "r": ddpg.buffer["r"].at[i].set(r),
        "s2": ddpg.buffer["s2"].at[i].set(s2),
    }
    nxt = (i + 1) % cfg.buffer_size
    return ddpg._replace(buffer=buf, buffer_idx=nxt,
                         buffer_full=ddpg.buffer_full | (nxt == 0))


def _adam(params, grads, opt, lr, step, b1=0.9, b2=0.999, eps=1e-8):
    m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, opt["m"], grads)
    v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, opt["v"], grads)
    t = step.astype(jnp.float32) + 1.0
    mhat = jax.tree.map(lambda x: x / (1 - b1 ** t), m)
    vhat = jax.tree.map(lambda x: x / (1 - b2 ** t), v)
    new = jax.tree.map(lambda p, mh, vh: p - lr * mh / (jnp.sqrt(vh) + eps),
                       params, mhat, vhat)
    return new, {"m": m, "v": v}


@functools.partial(jax.jit, static_argnames=("cfg",))
def train_step(key, ddpg: DDPGState, cfg: DDPGConfig) -> Tuple[DDPGState, Dict]:
    """One mini-batch update of critic (Eq. 38) + actor (Eq. 39) + targets (Eq. 40).

    Calling this before any ``store`` is a masked no-op: an empty replay
    buffer holds only the all-zero init transitions, and training on those
    would corrupt the networks before the first real experience arrives.
    """
    empty = (ddpg.buffer_idx == 0) & ~ddpg.buffer_full
    size = jnp.where(ddpg.buffer_full, cfg.buffer_size, ddpg.buffer_idx)
    size = jnp.maximum(size, 1)
    idx = jax.random.randint(key, (cfg.batch_size,), 0, size)
    s = ddpg.buffer["s"][idx]
    a = ddpg.buffer["a"][idx]
    r = ddpg.buffer["r"][idx]
    s2 = ddpg.buffer["s2"][idx]

    # y_j = R_j + ψ Q'(S_{j+1}, ν'(S_{j+1}))
    a2 = actor_apply(ddpg.target_actor, s2)
    y = r + cfg.gamma * critic_apply(ddpg.target_critic, s2, a2)

    def critic_loss(cp):
        q = critic_apply(cp, s, a)
        return jnp.mean((y - q) ** 2)

    cl, cg = jax.value_and_grad(critic_loss)(ddpg.critic)
    critic, critic_opt = _adam(ddpg.critic, cg, ddpg.critic_opt,
                               cfg.critic_lr, ddpg.step)

    def actor_loss(ap):
        return -jnp.mean(critic_apply(critic, s, actor_apply(ap, s)))

    al, ag = jax.value_and_grad(actor_loss)(ddpg.actor)
    actor, actor_opt = _adam(ddpg.actor, ag, ddpg.actor_opt,
                             cfg.actor_lr, ddpg.step)

    soft = lambda t, o: jax.tree.map(
        lambda tt, oo: (1 - cfg.tau) * tt + cfg.tau * oo, t, o)
    new = ddpg._replace(
        actor=actor, critic=critic,
        target_actor=soft(ddpg.target_actor, actor),
        target_critic=soft(ddpg.target_critic, critic),
        actor_opt=actor_opt, critic_opt=critic_opt,
        noise_sigma=ddpg.noise_sigma * cfg.noise_decay,
        step=ddpg.step + 1)
    new = jax.tree.map(lambda old, upd: jnp.where(empty, old, upd),
                       ddpg, new)
    zero = jnp.zeros_like(cl)
    return new, {"critic_loss": jnp.where(empty, zero, cl),
                 "actor_loss": jnp.where(empty, zero, al)}


# ---------------------------------------------------------------------------
# The pure scanned trainer (paper Algorithm 2 as ONE XLA program)
# ---------------------------------------------------------------------------

def allocator_config(cfg, spec, *, hidden: int = 128,
                     buffer_size: int = 4096,
                     batch_size: int = 64) -> DDPGConfig:
    """The DDPGConfig matching an engine (cfg, spec) pair: dynamic
    scenarios add the availability slice to the observation, so the state
    is (3N,) instead of (2N,) (DESIGN.md §6/§7)."""
    n = cfg.n_clients
    state_dim = (2 + (spec.scenario != "static")) * n
    return DDPGConfig(state_dim=state_dim, action_dim=2 * n, hidden=hidden,
                      buffer_size=buffer_size, batch_size=batch_size)


def rollout_step(cfg, params, dcfg: DDPGConfig, carry, *,
                 noma_enabled: bool = True, warmup: int = 64):
    """Algorithm 2 lines 8-14 as ONE scan step: act (with exploration
    noise), step the pure env, store the transition, then a mini-batch
    update masked out during the replay warmup.

    ``carry`` = (agent, env_state, obs, key, total_steps).  The masked
    update consumes its PRNG key either way, so the key stream — and hence
    the trajectory — is identical to an eager loop that *skips* the call.
    """
    from repro.core import env as env_mod
    agent, est, obs, key, t = carry
    key, ka, kt = jax.random.split(key, 3)
    act = select_action(ka, agent, obs)
    est, obs2, reward, _ = env_mod.env_step(cfg, params, est, act,
                                            noma_enabled=noma_enabled)
    agent = store(agent, dcfg, obs, act, reward, obs2)
    t = t + 1
    trained, losses = train_step(kt, agent, dcfg)
    do_train = t >= warmup
    agent = jax.tree.map(lambda upd, old: jnp.where(do_train, upd, old),
                         trained, agent)
    losses = {k: jnp.where(do_train, v, jnp.zeros_like(v))
              for k, v in losses.items()}
    return (agent, est, obs2, key, t), (reward, losses)


@functools.partial(jax.jit, static_argnames=("cfg", "dcfg", "episodes",
                                             "steps_per_episode", "warmup",
                                             "noma_enabled"))
def _train_scanned(cfg, params, dcfg: DDPGConfig, key, *, episodes: int,
                   steps_per_episode: int, warmup: int,
                   noma_enabled: bool):
    """episodes × steps as scan-of-scans: zero per-step host dispatch."""
    from repro.core import env as env_mod
    key, k_agent = jax.random.split(key)
    agent0 = init_ddpg(k_agent, dcfg)

    def episode(carry, _):
        agent, key, t = carry
        key, k_reset = jax.random.split(key)
        est, obs = env_mod.env_reset(cfg, params, k_reset)

        def step(c, _):
            return rollout_step(cfg, params, dcfg, c,
                                noma_enabled=noma_enabled, warmup=warmup)

        (agent, _, _, key, t), (rewards, losses) = jax.lax.scan(
            step, (agent, est, obs, key, t), None,
            length=steps_per_episode)
        ep = {"episode_reward": jnp.mean(rewards),
              "critic_loss": jnp.mean(losses["critic_loss"]),
              "actor_loss": jnp.mean(losses["actor_loss"])}
        return (agent, key, t), ep

    t0 = jnp.zeros((), jnp.int32)
    (agent, key, _), history = jax.lax.scan(
        episode, (agent0, key, t0), None, length=episodes)
    return agent, history


def _episode_params(cfg, spec, state, bundle):
    """The training MDP for the CURRENT round state:
    ``engine.associate_snapshot`` (the one definition of the one-off
    association) over the scenario's cost surface.  Lazy engine import —
    the engine itself lazily imports this module for its ddpg allocator
    path."""
    from repro.core import engine, env as env_mod
    dynamic = spec.scenario != "static"
    scen = state.scenario
    dist = scen.dist if dynamic else bundle.dist
    assoc = engine.associate_snapshot(cfg, spec, state,
                                      bundle).astype(jnp.float32)
    return env_mod.make_env_params(
        cfg, assoc, jnp.ones((cfg.n_edges,)), dist, bundle.counts,
        fading_rho=spec.fading_rho,
        avail=scen.avail if dynamic else None,
        kappa=scen.kappa if dynamic else None,
        p_max_w=scen.p_max_w if dynamic else None,
        f_max_hz=scen.f_max_hz if dynamic else None,
        p_drop=scen.p_drop if dynamic else None,
        p_return=scen.p_return if dynamic else None)


def train_allocator(cfg, spec, state, bundle, dcfg: Optional[DDPGConfig],
                    key, *, episodes: int = 20, steps_per_episode: int = 50,
                    warmup: int = 64, hidden: int = 128
                    ) -> Tuple[DDPGState, Dict[str, jnp.ndarray]]:
    """Train the DDPG resource allocator for an engine simulation, fully
    scanned: one episode (env rollout + ``store`` + ``train_step``) is a
    single ``lax.scan``, and episodes scan on top — the whole of paper
    Algorithm 2 is ONE compiled XLA program.

    ``state``/``bundle`` are the engine's ``RoundState``/``RoundBundle``;
    the observation and the billed cost follow the (cfg, spec) scenario
    contract, so ``spec.scenario != "static"`` trains on the (3N,)
    scenario-sliced observation.  Returns the trained ``DDPGState`` and a
    history dict of per-episode (episodes,) arrays.
    """
    if dcfg is None:
        dcfg = allocator_config(cfg, spec, hidden=hidden)
    params = _episode_params(cfg, spec, state, bundle)
    return _train_scanned(cfg, params, dcfg, key, episodes=episodes,
                          steps_per_episode=steps_per_episode,
                          warmup=warmup, noma_enabled=spec.noma_enabled)


def train_allocator_fleet(cfg, spec, states, bundles,
                          dcfg: Optional[DDPGConfig], keys, *,
                          episodes: int = 20, steps_per_episode: int = 50,
                          warmup: int = 64, hidden: int = 128
                          ) -> Tuple[DDPGState, Dict[str, jnp.ndarray]]:
    """``train_allocator`` vmapped over a fleet of stacked cells (states /
    bundles / keys with a leading fleet axis, as from
    ``engine.stack_fleet``): every cell trains its own actor on its own
    world, all inside ONE XLA program — the training-side twin of
    ``engine.run_fleet_actors``.  Returned leaves carry the fleet axis.
    """
    if dcfg is None:
        dcfg = allocator_config(cfg, spec, hidden=hidden)

    def one(state, bundle, key):
        params = _episode_params(cfg, spec, state, bundle)
        return _train_scanned(cfg, params, dcfg, key, episodes=episodes,
                              steps_per_episode=steps_per_episode,
                              warmup=warmup,
                              noma_enabled=spec.noma_enabled)

    return jax.vmap(one)(states, bundles, keys)


def train_allocator_eager(cfg, spec, state, bundle,
                          dcfg: Optional[DDPGConfig], key, *,
                          episodes: int = 20, steps_per_episode: int = 50,
                          warmup: int = 64, hidden: int = 128
                          ) -> Tuple[DDPGState, Dict[str, jnp.ndarray]]:
    """The eager oracle for ``train_allocator``: the same PRNG layout and
    the same pure pieces, dispatched step by step from Python.  Exists for
    the parity tests and the scanned-vs-eager benchmark — use
    ``train_allocator`` for real work."""
    from repro.core import env as env_mod
    if dcfg is None:
        dcfg = allocator_config(cfg, spec, hidden=hidden)
    params = _episode_params(cfg, spec, state, bundle)
    key, k_agent = jax.random.split(key)
    agent = init_ddpg(k_agent, dcfg)
    history = {"episode_reward": [], "critic_loss": [], "actor_loss": []}
    total = 0
    for _ in range(episodes):
        key, k_reset = jax.random.split(key)
        est, obs = env_mod.env_reset(cfg, params, k_reset)
        rewards, closs, aloss = [], [], []
        for _ in range(steps_per_episode):
            key, ka, kt = jax.random.split(key, 3)
            act = select_action(ka, agent, obs)
            est, obs2, reward, _ = env_mod.env_step(
                cfg, params, est, act, noma_enabled=spec.noma_enabled)
            agent = store(agent, dcfg, obs, act, reward, obs2)
            obs = obs2
            total += 1
            rewards.append(reward)
            if total >= warmup:
                agent, losses = train_step(kt, agent, dcfg)
                closs.append(losses["critic_loss"])
                aloss.append(losses["actor_loss"])
            else:
                closs.append(jnp.zeros(()))
                aloss.append(jnp.zeros(()))
        history["episode_reward"].append(jnp.mean(jnp.stack(rewards)))
        history["critic_loss"].append(jnp.mean(jnp.stack(closs)))
        history["actor_loss"].append(jnp.mean(jnp.stack(aloss)))
    return agent, {k: jnp.stack(v) for k, v in history.items()}
