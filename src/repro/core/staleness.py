"""Model staleness tracking (paper Eq. 20).

A_n^i = A_n^{i-1} + 1 if client n was not orchestrated at round i-1, else 1.
"""
from __future__ import annotations

import jax.numpy as jnp


def update_staleness(staleness: jnp.ndarray, selected: jnp.ndarray
                     ) -> jnp.ndarray:
    """staleness (N,) int; selected (N,) bool — selected clients reset to 1."""
    return jnp.where(selected, 1, staleness + 1)


def init_staleness(n_clients: int) -> jnp.ndarray:
    return jnp.ones((n_clients,), jnp.int32)
