"""Model staleness tracking (paper Eq. 20) + buffered-engine weighting.

A_n^i = A_n^{i-1} + 1 if client n was not orchestrated at round i-1, else 1.

The counter saturates at ``STALENESS_MAX``: a long-horizon buffered run
(DESIGN.md §11) advances the counter once per MICRO-step, so an int32
counter left uncapped would eventually overflow and a permanently-idle
client would walk off the fixed 8-bucket telemetry histogram's last
(open-ended) bucket edge.  Above the cap the value carries no extra
information — every consumer (fuzzy staleness normalisation, the FedBuff
buffer weight, the histogram) treats "very stale" uniformly — so the
saturating add changes no behaviour below it.
"""
from __future__ import annotations

import jax.numpy as jnp

# Saturation ceiling for the Eq. 20 counter.  Far above any horizon the
# sweeps run (10⁶ micro-steps) yet far below int32 overflow; also the cap
# fed to ``buffer_age`` so the staleness weight stays strictly positive.
STALENESS_MAX = 1 << 20


def update_staleness(staleness: jnp.ndarray, selected: jnp.ndarray
                     ) -> jnp.ndarray:
    """staleness (N,) int; selected (N,) bool — selected clients reset to 1.

    The +1 branch saturates at ``STALENESS_MAX`` (see module docstring).
    """
    return jnp.where(selected, 1,
                     jnp.minimum(staleness + 1, STALENESS_MAX))


def init_staleness(n_clients: int) -> jnp.ndarray:
    return jnp.ones((n_clients,), jnp.int32)


def buffer_age(version: jnp.ndarray, pulled_version: jnp.ndarray
               ) -> jnp.ndarray:
    """FedBuff update age: how many cloud aggregations happened between a
    client pulling the global model and its update landing in the buffer,
    plus 1 so a fresh update has age 1 (weight 1).  Saturating like the
    Eq. 20 counter."""
    age = jnp.maximum(version - pulled_version, 0) + 1
    return jnp.minimum(age, STALENESS_MAX)


def buffer_weight(age: jnp.ndarray, *, exponent: float = 0.5
                  ) -> jnp.ndarray:
    """Polynomial staleness discount  w(a) = a^(-exponent)  (FedBuff's
    s^(-1/2) at the default).  ``age`` ≥ 1, so w ∈ (0, 1] and a fresh
    update (age 1) is undiscounted."""
    a = jnp.maximum(age.astype(jnp.float32), 1.0)
    return a ** jnp.float32(-exponent)
