"""Client-edge association policies (paper §III + §V benchmarks).

* FCEA — the paper's fuzzy-based policy: each edge server ranks in-coverage
  clients by fuzzy competency NO* and admits the top N_m; a client picked by
  several edges goes to the *nearest* one, and the losing edges substitute
  the next client in their queue (paper §III-B last paragraph).
* GCEA — greedy single-criterion benchmark: strongest channel gain.
* RCEA — random association benchmark.

Two implementations live side by side (DESIGN.md §2.3):

* the original numpy ``_resolve`` — kept as the *parity oracle*: small,
  obviously-correct host code that the property tests check the JAX path
  against;
* ``resolve_jax`` — the same greedy round-robin admission re-expressed as a
  bounded ``lax.while_loop`` so that association can live *inside* the
  jitted ``round_step`` with no host callback.  ``POLICIES`` is the
  registry mapping policy names to JAX preference-matrix builders.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fuzzy


def _resolve(order_per_edge: np.ndarray, dist: np.ndarray, quota: int,
             coverage: np.ndarray) -> np.ndarray:
    """Greedy conflict resolution.

    order_per_edge: (M, N) client indices sorted by per-edge preference.
    Returns assoc (N, M) one-hot.
    """
    m_edges, n_clients = order_per_edge.shape
    assoc = np.zeros((n_clients, m_edges), dtype=np.int32)
    # queue pointer per edge
    ptr = np.zeros(m_edges, dtype=np.int64)
    filled = np.zeros(m_edges, dtype=np.int64)
    taken = np.full(n_clients, -1, dtype=np.int64)  # -> edge or -1

    # Round-robin admission with nearest-edge conflict resolution: iterate
    # until every edge filled its quota or exhausted its queue.
    progress = True
    while progress:
        progress = False
        for m in range(m_edges):
            while filled[m] < quota and ptr[m] < n_clients:
                c = order_per_edge[m, ptr[m]]
                ptr[m] += 1
                if not coverage[c, m]:
                    continue
                if taken[c] == -1:
                    taken[c] = m
                    filled[m] += 1
                    progress = True
                    break
                other = taken[c]
                if other != m and dist[c, m] < dist[c, other]:
                    # steal: client prefers the nearer edge; the loser refills
                    taken[c] = m
                    filled[m] += 1
                    filled[other] -= 1
                    progress = True
                    break
    for c in range(n_clients):
        if taken[c] >= 0:
            assoc[c, taken[c]] = 1
    return assoc


def fcea(scores: np.ndarray, dist: np.ndarray, quota: int,
         coverage_radius_m: float) -> np.ndarray:
    """Fuzzy-based association.

    scores: (N,) one competency per client, or (N, M) per (client, edge) —
    the latter lets CQ be the *per-edge* channel quality (paper §III-A1).
    """
    n, m = dist.shape
    coverage = dist <= coverage_radius_m
    scores = np.asarray(scores)
    if scores.ndim == 1:
        scores = np.broadcast_to(scores[:, None], (n, m))
    # per-edge ranking by NO* (descending); out-of-coverage pushed to the end
    pref = np.where(coverage, scores, -np.inf)                 # (N, M)
    order = np.argsort(-pref, axis=0).T                        # (M, N)
    return _resolve(order, dist, quota, coverage)


def gcea(gains: np.ndarray, dist: np.ndarray, quota: int,
         coverage_radius_m: float) -> np.ndarray:
    """Greedy benchmark: rank by channel gain only."""
    coverage = dist <= coverage_radius_m
    pref = np.where(coverage, gains, -np.inf)                  # (N, M)
    order = np.argsort(-pref, axis=0).T
    return _resolve(order, dist, quota, coverage)


def rcea(rng: np.random.Generator, dist: np.ndarray, quota: int,
         coverage_radius_m: float) -> np.ndarray:
    """Random benchmark."""
    n, m = dist.shape
    coverage = dist <= coverage_radius_m
    pref = np.where(coverage, rng.random((n, m)), -np.inf)
    order = np.argsort(-pref, axis=0).T
    return _resolve(order, dist, quota, coverage)


# ---------------------------------------------------------------------------
# JAX-native path (used inside the jitted round engine)
# ---------------------------------------------------------------------------

def resolve_jax(order: jnp.ndarray, dist: jnp.ndarray, quota: int,
                coverage: jnp.ndarray) -> jnp.ndarray:
    """``_resolve`` as a bounded ``lax.while_loop`` (one pop attempt per
    iteration), bit-compatible with the numpy oracle given the same
    ``order``.

    order: (M, N) int — per-edge client indices by descending preference.
    Returns assoc (N, M) one-hot int32.
    """
    m_edges, n_clients = order.shape
    # Each iteration either advances an edge's queue pointer (≤ N·M pops
    # total) or advances to the next edge (≤ M per pass; ≤ N·M + 1 passes,
    # since every non-final pass changes `taken` at least once and each
    # client's assigned-edge distance strictly shrinks per steal).
    max_iter = n_clients * m_edges + m_edges * (n_clients * m_edges + 2) + 2

    def cond(s):
        return (~s[5]) & (s[6] < max_iter)

    def body(s):
        taken, ptr, filled, m, progress, done, it = s
        can_pop = (filled[m] < quota) & (ptr[m] < n_clients)
        c = order[m, jnp.minimum(ptr[m], n_clients - 1)]
        t = taken[c]
        vacant = t < 0
        safe_t = jnp.maximum(t, 0)
        steal = (~vacant) & (t != m) & (dist[c, m] < dist[c, safe_t])
        admit = can_pop & coverage[c, m] & (vacant | steal)
        ptr = ptr.at[m].add(can_pop.astype(ptr.dtype))
        taken = jnp.where(admit, taken.at[c].set(m), taken)
        filled = filled.at[m].add(admit.astype(filled.dtype))
        filled = filled.at[safe_t].add(
            -(admit & ~vacant).astype(filled.dtype))
        progress = progress | admit
        advance = (~can_pop) | admit      # inner loop ends: next edge
        m_next = jnp.where(advance, m + 1, m)
        wrap = m_next >= m_edges
        done = done | (wrap & ~progress)
        m_next = jnp.where(wrap, 0, m_next)
        progress = progress & ~wrap       # fresh pass
        return taken, ptr, filled, m_next, progress, done, it + 1

    taken0 = jnp.full((n_clients,), -1, jnp.int32)
    zeros_m = jnp.zeros((m_edges,), jnp.int32)
    state = (taken0, zeros_m, zeros_m, jnp.asarray(0, jnp.int32),
             jnp.asarray(False), jnp.asarray(False), jnp.asarray(0, jnp.int32))
    taken = jax.lax.while_loop(cond, body, state)[0]
    return ((taken[:, None] == jnp.arange(m_edges)[None, :]) &
            (taken[:, None] >= 0)).astype(jnp.int32)


# Registry: policy name -> preference-matrix builder (N, M).  ``scores`` may
# be None for policies that don't use the fuzzy competency.
PrefBuilder = Callable[..., jnp.ndarray]

POLICIES: Dict[str, PrefBuilder] = {
    "fcea": lambda scores, gains, key: scores,
    "gcea": lambda scores, gains, key: gains,
    "rcea": lambda scores, gains, key: jax.random.uniform(key, gains.shape),
}


def associate_jax(policy: str, *, scores: jnp.ndarray | None,
                  gains: jnp.ndarray, dist: jnp.ndarray, quota: int,
                  coverage_radius_m: float, key,
                  avail: jnp.ndarray | None = None) -> jnp.ndarray:
    """JAX-native association (N, M) one-hot; pure, jit/vmap-safe.

    ``avail`` (N,) is the scenario availability mask (DESIGN.md §6): an
    unavailable client is treated as out of every edge's coverage, so no
    policy can admit it and its quota slot goes to the next candidate.
    """
    if policy not in POLICIES:
        raise ValueError(f"unknown association policy {policy!r}")
    pref = POLICIES[policy](scores, gains, key)
    if pref.ndim == 1:
        pref = jnp.broadcast_to(pref[:, None], dist.shape)
    coverage = dist <= coverage_radius_m
    if avail is not None:
        coverage = coverage & (avail > 0)[:, None]
    pref = jnp.where(coverage, pref, -jnp.inf)
    order = jnp.argsort(-pref, axis=0).T                       # (M, N)
    return resolve_jax(order, dist, quota, coverage)


def associate(policy: str, *, scores: np.ndarray, gains_to_edges: np.ndarray,
              dist: np.ndarray, quota: int, coverage_radius_m: float,
              rng: np.random.Generator) -> np.ndarray:
    if policy == "fcea":
        return fcea(scores, dist, quota, coverage_radius_m)
    if policy == "gcea":
        # single-criterion: strongest channel to each edge
        return gcea(gains_to_edges, dist, quota, coverage_radius_m)
    if policy == "rcea":
        return rcea(rng, dist, quota, coverage_radius_m)
    raise ValueError(f"unknown association policy {policy!r}")
