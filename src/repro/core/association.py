"""Client-edge association policies (paper §III + §V benchmarks).

* FCEA — the paper's fuzzy-based policy: each edge server ranks in-coverage
  clients by fuzzy competency NO* and admits the top N_m; a client picked by
  several edges goes to the *nearest* one, and the losing edges substitute
  the next client in their queue (paper §III-B last paragraph).
* GCEA — greedy single-criterion benchmark: strongest channel gain.
* RCEA — random association benchmark.

Association is control-plane work on small (N, M) arrays once per round —
implemented with numpy on host for clarity; the resulting one-hot matrix
feeds the jitted cost/aggregation paths.
"""
from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core import fuzzy


def _resolve(order_per_edge: np.ndarray, dist: np.ndarray, quota: int,
             coverage: np.ndarray) -> np.ndarray:
    """Greedy conflict resolution.

    order_per_edge: (M, N) client indices sorted by per-edge preference.
    Returns assoc (N, M) one-hot.
    """
    m_edges, n_clients = order_per_edge.shape
    assoc = np.zeros((n_clients, m_edges), dtype=np.int32)
    # queue pointer per edge
    ptr = np.zeros(m_edges, dtype=np.int64)
    filled = np.zeros(m_edges, dtype=np.int64)
    taken = np.full(n_clients, -1, dtype=np.int64)  # -> edge or -1

    # Round-robin admission with nearest-edge conflict resolution: iterate
    # until every edge filled its quota or exhausted its queue.
    progress = True
    while progress:
        progress = False
        for m in range(m_edges):
            while filled[m] < quota and ptr[m] < n_clients:
                c = order_per_edge[m, ptr[m]]
                ptr[m] += 1
                if not coverage[c, m]:
                    continue
                if taken[c] == -1:
                    taken[c] = m
                    filled[m] += 1
                    progress = True
                    break
                other = taken[c]
                if other != m and dist[c, m] < dist[c, other]:
                    # steal: client prefers the nearer edge; the loser refills
                    taken[c] = m
                    filled[m] += 1
                    filled[other] -= 1
                    progress = True
                    break
    for c in range(n_clients):
        if taken[c] >= 0:
            assoc[c, taken[c]] = 1
    return assoc


def fcea(scores: np.ndarray, dist: np.ndarray, quota: int,
         coverage_radius_m: float) -> np.ndarray:
    """Fuzzy-based association.

    scores: (N,) one competency per client, or (N, M) per (client, edge) —
    the latter lets CQ be the *per-edge* channel quality (paper §III-A1).
    """
    n, m = dist.shape
    coverage = dist <= coverage_radius_m
    scores = np.asarray(scores)
    if scores.ndim == 1:
        scores = np.broadcast_to(scores[:, None], (n, m))
    # per-edge ranking by NO* (descending); out-of-coverage pushed to the end
    pref = np.where(coverage, scores, -np.inf)                 # (N, M)
    order = np.argsort(-pref, axis=0).T                        # (M, N)
    return _resolve(order, dist, quota, coverage)


def gcea(gains: np.ndarray, dist: np.ndarray, quota: int,
         coverage_radius_m: float) -> np.ndarray:
    """Greedy benchmark: rank by channel gain only."""
    coverage = dist <= coverage_radius_m
    pref = np.where(coverage, gains, -np.inf)                  # (N, M)
    order = np.argsort(-pref, axis=0).T
    return _resolve(order, dist, quota, coverage)


def rcea(rng: np.random.Generator, dist: np.ndarray, quota: int,
         coverage_radius_m: float) -> np.ndarray:
    """Random benchmark."""
    n, m = dist.shape
    coverage = dist <= coverage_radius_m
    pref = np.where(coverage, rng.random((n, m)), -np.inf)
    order = np.argsort(-pref, axis=0).T
    return _resolve(order, dist, quota, coverage)


def associate(policy: str, *, scores: np.ndarray, gains_to_edges: np.ndarray,
              dist: np.ndarray, quota: int, coverage_radius_m: float,
              rng: np.random.Generator) -> np.ndarray:
    if policy == "fcea":
        return fcea(scores, dist, quota, coverage_radius_m)
    if policy == "gcea":
        # single-criterion: strongest channel to each edge
        return gcea(gains_to_edges, dist, quota, coverage_radius_m)
    if policy == "rcea":
        return rcea(rng, dist, quota, coverage_radius_m)
    raise ValueError(f"unknown association policy {policy!r}")
