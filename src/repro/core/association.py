"""Client-edge association policies (paper §III + §V benchmarks).

* FCEA — the paper's fuzzy-based policy: each edge server ranks in-coverage
  clients by fuzzy competency NO* and admits the top N_m; a client picked by
  several edges goes to the *nearest* one, and the losing edges substitute
  the next client in their queue (paper §III-B last paragraph).
* GCEA — greedy single-criterion benchmark: strongest channel gain.
* RCEA — random association benchmark.

Three implementations live side by side (DESIGN.md §2.3, §8.1):

* the original numpy ``_resolve`` — kept as the *parity oracle*: small,
  obviously-correct host code that the property tests check the JAX paths
  against;
* ``resolve_jax`` — the same greedy round-robin admission re-expressed as a
  bounded ``lax.while_loop`` (one queue pop per accelerator step) so that
  association can live *inside* the jitted ``round_step`` with no host
  callback.  Kept behind ``EngineSpec.resolver="serial"`` for A/B;
* ``resolve_parallel`` — the default: a vectorized quota-round resolver.
  Each sweep proposes, for ALL edges at once, the per-edge top-ranked
  unclaimed in-coverage clients and resolves multi-edge conflicts by
  nearest edge in one masked ``argmin``.  The greedy admission is exactly
  edge-proposing deferred acceptance (Gale–Shapley with quotas), whose
  outcome is independent of proposal order once preferences are strict —
  so the sweep resolver is bit-identical to the serial oracle (proof
  sketch in DESIGN.md §8.1).  Strictness is what the (distance,
  edge-index) lexicographic tie-break below buys.

``POLICIES`` is the registry mapping policy names to JAX
preference-matrix builders.
"""
from __future__ import annotations

from typing import Callable, Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import fuzzy


def _prefers(dist: np.ndarray, c: int, m: int, other: int) -> bool:
    """Client c strictly prefers edge m over ``other``: nearest edge wins,
    exact distance ties break on the lower edge index.  The index tie-break
    makes client preferences STRICT, which is what guarantees the serial
    and parallel resolvers compute the same matching (DESIGN.md §8.1);
    on continuous topologies ties are measure-zero, so this is invisible
    to the golden trajectories."""
    return dist[c, m] < dist[c, other] or \
        (dist[c, m] == dist[c, other] and m < other)


def _resolve(order_per_edge: np.ndarray, dist: np.ndarray, quota: int,
             coverage: np.ndarray) -> np.ndarray:
    """Greedy conflict resolution.

    order_per_edge: (M, N) client indices sorted by per-edge preference.
    Returns assoc (N, M) one-hot.
    """
    m_edges, n_clients = order_per_edge.shape
    assoc = np.zeros((n_clients, m_edges), dtype=np.int32)
    # queue pointer per edge
    ptr = np.zeros(m_edges, dtype=np.int64)
    filled = np.zeros(m_edges, dtype=np.int64)
    taken = np.full(n_clients, -1, dtype=np.int64)  # -> edge or -1

    # Round-robin admission with nearest-edge conflict resolution: iterate
    # until every edge filled its quota or exhausted its queue.
    progress = True
    while progress:
        progress = False
        for m in range(m_edges):
            while filled[m] < quota and ptr[m] < n_clients:
                c = order_per_edge[m, ptr[m]]
                ptr[m] += 1
                if not coverage[c, m]:
                    continue
                if taken[c] == -1:
                    taken[c] = m
                    filled[m] += 1
                    progress = True
                    break
                other = taken[c]
                if other != m and _prefers(dist, c, m, other):
                    # steal: client prefers the nearer edge; the loser refills
                    taken[c] = m
                    filled[m] += 1
                    filled[other] -= 1
                    progress = True
                    break
    for c in range(n_clients):
        if taken[c] >= 0:
            assoc[c, taken[c]] = 1
    return assoc


def fcea(scores: np.ndarray, dist: np.ndarray, quota: int,
         coverage_radius_m: float) -> np.ndarray:
    """Fuzzy-based association.

    scores: (N,) one competency per client, or (N, M) per (client, edge) —
    the latter lets CQ be the *per-edge* channel quality (paper §III-A1).
    """
    n, m = dist.shape
    coverage = dist <= coverage_radius_m
    scores = np.asarray(scores)
    if scores.ndim == 1:
        scores = np.broadcast_to(scores[:, None], (n, m))
    # per-edge ranking by NO* (descending); out-of-coverage pushed to the end
    pref = np.where(coverage, scores, -np.inf)                 # (N, M)
    order = np.argsort(-pref, axis=0).T                        # (M, N)
    return _resolve(order, dist, quota, coverage)


def gcea(gains: np.ndarray, dist: np.ndarray, quota: int,
         coverage_radius_m: float) -> np.ndarray:
    """Greedy benchmark: rank by channel gain only."""
    coverage = dist <= coverage_radius_m
    pref = np.where(coverage, gains, -np.inf)                  # (N, M)
    order = np.argsort(-pref, axis=0).T
    return _resolve(order, dist, quota, coverage)


def rcea(rng: np.random.Generator, dist: np.ndarray, quota: int,
         coverage_radius_m: float) -> np.ndarray:
    """Random benchmark."""
    n, m = dist.shape
    coverage = dist <= coverage_radius_m
    pref = np.where(coverage, rng.random((n, m)), -np.inf)
    order = np.argsort(-pref, axis=0).T
    return _resolve(order, dist, quota, coverage)


# ---------------------------------------------------------------------------
# JAX-native path (used inside the jitted round engine)
# ---------------------------------------------------------------------------

def resolve_jax(order: jnp.ndarray, dist: jnp.ndarray, quota: int,
                coverage: jnp.ndarray, return_sweeps: bool = False
                ) -> jnp.ndarray:
    """``_resolve`` as a bounded ``lax.while_loop`` (one pop attempt per
    iteration), bit-compatible with the numpy oracle given the same
    ``order``.

    order: (M, N) int — per-edge client indices by descending preference.
    Returns assoc (N, M) one-hot int32; with ``return_sweeps`` also the
    loop's pop-attempt count (the serial analogue of a sweep count — the
    counter already lives in the while state, so asking for it is free).
    """
    m_edges, n_clients = order.shape
    # Each iteration either advances an edge's queue pointer (≤ N·M pops
    # total) or advances to the next edge (≤ M per pass; ≤ N·M + 1 passes,
    # since every non-final pass changes `taken` at least once and each
    # client's assigned-edge distance strictly shrinks per steal).
    max_iter = n_clients * m_edges + m_edges * (n_clients * m_edges + 2) + 2

    def cond(s):
        return (~s[5]) & (s[6] < max_iter)

    def body(s):
        taken, ptr, filled, m, progress, done, it = s
        can_pop = (filled[m] < quota) & (ptr[m] < n_clients)
        c = order[m, jnp.minimum(ptr[m], n_clients - 1)]
        t = taken[c]
        vacant = t < 0
        safe_t = jnp.maximum(t, 0)
        # strict client preference: (distance, edge index) lexicographic —
        # the same tie-break as the numpy oracle's ``_prefers``
        nearer = (dist[c, m] < dist[c, safe_t]) | \
            ((dist[c, m] == dist[c, safe_t]) & (m < t))
        steal = (~vacant) & (t != m) & nearer
        admit = can_pop & coverage[c, m] & (vacant | steal)
        ptr = ptr.at[m].add(can_pop.astype(ptr.dtype))
        taken = jnp.where(admit, taken.at[c].set(m), taken)
        filled = filled.at[m].add(admit.astype(filled.dtype))
        filled = filled.at[safe_t].add(
            -(admit & ~vacant).astype(filled.dtype))
        progress = progress | admit
        advance = (~can_pop) | admit      # inner loop ends: next edge
        m_next = jnp.where(advance, m + 1, m)
        wrap = m_next >= m_edges
        done = done | (wrap & ~progress)
        m_next = jnp.where(wrap, 0, m_next)
        progress = progress & ~wrap       # fresh pass
        return taken, ptr, filled, m_next, progress, done, it + 1

    taken0 = jnp.full((n_clients,), -1, jnp.int32)
    zeros_m = jnp.zeros((m_edges,), jnp.int32)
    state = (taken0, zeros_m, zeros_m, jnp.asarray(0, jnp.int32),
             jnp.asarray(False), jnp.asarray(False), jnp.asarray(0, jnp.int32))
    final = jax.lax.while_loop(cond, body, state)
    taken = final[0]
    assoc = ((taken[:, None] == jnp.arange(m_edges)[None, :]) &
             (taken[:, None] >= 0)).astype(jnp.int32)
    if return_sweeps:
        return assoc, final[6]
    return assoc


def _blocking_pair_dense(assigned: jnp.ndarray, rank: jnp.ndarray,
                         dist: jnp.ndarray, coverage: jnp.ndarray,
                         quota: int) -> jnp.ndarray:
    """Does ``assigned`` (N,) admit a blocking pair under TODAY's market?

    Pair (c, m) blocks when the EDGE wants c — in coverage, not already
    held, and either m has a free slot or ranks c above its worst-held
    client — AND the CLIENT wants m: unmatched, or m beats its current
    edge by the strict (distance, edge-index) order.  A matching with no
    blocking pair is stable; the cold resolver's result never has one
    (deferred acceptance), so this is the warm path's acceptance test
    (DESIGN.md §13.4)."""
    m_edges, n = rank.shape
    col = jnp.arange(m_edges, dtype=jnp.int32)
    held = assigned[None, :] == col[:, None]                   # (M, N)
    deficit = quota - jnp.sum(held, axis=1)                    # (M,)
    worst = jnp.max(jnp.where(held, rank, -1), axis=1)         # (M,)
    edge_wants = coverage.T & (~held) & \
        ((deficit > 0)[:, None] | (rank < worst[:, None]))
    cur = assigned
    cur_dist = jnp.take_along_axis(dist, jnp.maximum(cur, 0)[:, None],
                                   axis=1)[:, 0]
    nearer = (dist < cur_dist[:, None]) | \
        ((dist == cur_dist[:, None]) & (col[None, :] < cur[:, None]))
    client_wants = (cur < 0)[:, None] | nearer                 # (N, M)
    return jnp.any(edge_wants & client_wants.T)


def resolve_parallel(order: jnp.ndarray, dist: jnp.ndarray, quota: int,
                     coverage: jnp.ndarray, return_sweeps: bool = False,
                     seed: jnp.ndarray | None = None) -> jnp.ndarray:
    """Vectorized quota-round resolver — the default inside ``round_step``.

    One *sweep* plays a whole batch of deferred-acceptance proposals:

    1. every edge proposes to its top ``quota - held`` not-yet-rejected
       in-coverage clients (per-edge rank threshold, no serial queue);
    2. every client keeps the best offer among {incumbent ∪ proposals} by
       the strict (distance, edge-index) order — ONE masked ``argmin``
       per client (``argmin`` returns the first minimum, which IS the
       lexicographic tie-break);
    3. losing offers are rejected permanently (a client's held offer only
       improves, so a rejected edge can never become acceptable again).

    Each (edge, client) pair is proposed at most once, so ``N·M + 1``
    sweeps provably suffice; the ``lax.while_loop`` exits at the first
    proposal-free sweep (a fixed point — the body is idempotent there,
    which also makes the loop vmap-safe).  Gale–Shapley order-independence
    makes the result bit-identical to the serial oracle (DESIGN.md §8.1),
    while the accelerator-step depth drops from O(N²M²) queue pops to the
    observed handful of sweeps, each a top-k plus a few masked reductions.

    order: (M, N) int — per-edge client indices by descending preference.
    Returns assoc (N, M) one-hot int32; with ``return_sweeps`` also the
    sweep count from the while state (free — no extra compute).

    ``seed`` (N,) int32 — a previous round's assigned vector — WARM-STARTS
    the sweeps (DESIGN.md §13.4): still-in-coverage seeds become the
    initial tentative holds (a previous matching holds ≤ quota per edge,
    and coverage loss only shrinks it, so seeded holds never violate
    quotas), the UNCHANGED sweep loop runs to its fixed point, and the
    result is kept only if it has no blocking pair — otherwise one cold
    resolution runs from scratch (``lax.cond``, so only the taken branch
    executes).  The warm result is therefore always a stable matching of
    today's market; it equals the cold (edge-optimal) matching whenever
    the stable matching is unique — and the fallback fires on every
    detectable divergence.  ``seed=None`` (the default) is bit-identical
    to the pre-warm resolver.
    """
    m_edges, n_clients = order.shape
    # rank[m, c] = position of client c in edge m's queue: the inverse
    # permutation via one scatter (O(N·M)) instead of a second argsort
    rows = jnp.arange(m_edges, dtype=jnp.int32)[:, None]
    pos = jnp.broadcast_to(jnp.arange(n_clients, dtype=jnp.int32),
                           order.shape)
    rank = jnp.zeros(order.shape, jnp.int32).at[rows, order].set(pos)
    big = jnp.asarray(n_clients + 1, jnp.int32)
    col = jnp.arange(m_edges, dtype=jnp.int32)
    k_top = min(quota, n_clients)
    max_sweeps = n_clients * m_edges + 2

    def cond(s):
        _, _, done, it = s
        return (~done) & (it < max_sweeps)

    def body(s):
        assigned, rejected, _, it = s
        held = assigned[None, :] == col[:, None]                  # (M, N)
        deficit = quota - jnp.sum(held, axis=1)                   # (M,)
        elig = (~rejected.T) & (~held)                            # (M, N)
        keys = jnp.where(elig, rank, big)
        # the deficit-th smallest eligible rank is the proposal cut-off;
        # ranks are distinct, so exactly min(deficit, #eligible) propose.
        # deficit ≤ quota, so a top-k of the k = quota best candidates
        # replaces a full per-edge sort (top_k ties break on the lower
        # index, but rank keys are unique anyway).
        kth = big - jax.lax.top_k(big - keys, k_top)[0]           # (M, k)
        thr_idx = jnp.clip(deficit - 1, 0, k_top - 1)
        thr = jnp.take_along_axis(kth, thr_idx[:, None], axis=1)[:, 0]
        propose = elig & (keys <= thr[:, None]) & (deficit > 0)[:, None]
        # candidates per client: incumbent + incoming proposals
        cand = propose.T | (assigned[:, None] == col[None, :])    # (N, M)
        ckey = jnp.where(cand, dist, jnp.inf)
        best = jnp.argmin(ckey, axis=1).astype(jnp.int32)
        has = jnp.any(cand, axis=1)
        assigned = jnp.where(has, best, jnp.asarray(-1, jnp.int32))
        # everything a client turned down (incl. a bumped incumbent) is
        # rejected for good — monotone, hence the sweep-count bound
        rejected = rejected | (cand & (col[None, :] != best[:, None]))
        return assigned, rejected, ~jnp.any(propose), it + 1

    def run(assigned0):
        state = (assigned0, ~coverage, jnp.asarray(False),
                 jnp.asarray(0, jnp.int32))
        final = jax.lax.while_loop(cond, body, state)
        return final[0], final[3]

    cold0 = jnp.full((n_clients,), -1, jnp.int32)
    if seed is None:
        taken, sweeps = run(cold0)
    else:
        ok = (seed >= 0) & jnp.take_along_axis(
            coverage, jnp.maximum(seed, 0)[:, None], axis=1)[:, 0]
        taken_w, sweeps_w = run(jnp.where(ok, seed.astype(jnp.int32), -1))
        taken, extra = jax.lax.cond(
            _blocking_pair_dense(taken_w, rank, dist, coverage, quota),
            lambda: run(cold0),
            lambda: (taken_w, jnp.asarray(0, jnp.int32)))
        sweeps = sweeps_w + extra
    assoc = ((taken[:, None] == col[None, :]) &
             (taken[:, None] >= 0)).astype(jnp.int32)
    if return_sweeps:
        return assoc, sweeps
    return assoc


def _blocking_pair_frontier(assigned: jnp.ndarray, idx: jnp.ndarray,
                            valid: jnp.ndarray, inv: jnp.ndarray,
                            quota: int, n_edges: int) -> jnp.ndarray:
    """``_blocking_pair_dense`` on the (N, K) frontier: pair ranks come
    from the resolver's global (edge asc, score desc) rank order ``inv``
    (compared only within one edge's segment), and the CLIENT side is the
    slot order itself — frontier rows are (distance, edge)-sorted, so
    client c strictly prefers slot j to its held slot hj iff j < hj."""
    n, k = idx.shape
    flat_e = idx.reshape(-1)
    held = (assigned[:, None] == idx) & (assigned >= 0)[:, None] & valid
    held_f = held.reshape(-1)
    filled = jnp.zeros((n_edges,), jnp.int32).at[flat_e].add(
        held_f.astype(jnp.int32))
    worst = jnp.full((n_edges,), -1, jnp.int32).at[flat_e].max(
        jnp.where(held_f, inv, -1))
    pair_rank = inv.reshape(n, k)
    edge_wants = valid & (~held) & \
        (((quota - filled) > 0)[idx] | (pair_rank < worst[idx]))
    col_k = jnp.arange(k, dtype=jnp.int32)
    held_slot = jnp.min(jnp.where(held, col_k[None, :],
                                  jnp.asarray(k, jnp.int32)), axis=1)
    client_wants = col_k[None, :] < held_slot[:, None]         # (N, K)
    return jnp.any(edge_wants & client_wants)


def resolve_candidates(pref: jnp.ndarray, cand, quota: int,
                       n_edges: int, return_sweeps: bool = False,
                       seed: jnp.ndarray | None = None) -> jnp.ndarray:
    """``resolve_parallel`` re-expressed over the (N, K) candidate frontier
    (DESIGN.md §9): the same batched deferred-acceptance sweeps, with every
    per-sweep tensor O(N·K) instead of O(N·M) and the per-edge proposal
    cut-off read off ONE segmented cumulative count over a rank order
    built once — a scatter-built inverse index over the N·K pairs replaces
    the (M, N) argsort + per-sweep ``top_k`` of the dense resolver.

    Sweep-for-sweep equivalence with ``resolve_parallel``: when ``valid``
    covers every in-coverage pair (K ≥ max coverage degree) the eligible
    pair set, the per-edge preference order (score desc, client index
    asc), the proposal rule (rank among eligible < deficit) and the client
    choice (first-minimum over (distance, edge)-sorted slots ==
    (distance, edge-index) lexicographic argmin) all coincide with the
    dense sweep's, so ``assigned`` evolves identically at every sweep and
    the matching is bit-identical (pinned by tests/test_candidates.py).
    With a smaller K the same sweeps play Gale–Shapley on the pruned pair
    set: the result is still a feasible stable matching of that sub-market
    (quota / one-edge-per-client / validity invariants hold).

    pref: (N, K) per-pair preference (higher = better; invalid pairs may
    hold any value).  ``cand.idx`` rows MUST be (distance, edge)-sorted —
    ``build_candidates`` guarantees it.
    Returns assigned (N,) int32 — edge index or −1; with ``return_sweeps``
    also the sweep count from the while state.

    ``seed`` warm-starts the sweeps exactly like ``resolve_parallel``'s:
    seeds whose edge still sits on the client's VALID frontier become the
    initial holds, the unchanged loop runs, and a blocking-pair check
    (``_blocking_pair_frontier``) gates a cold-restart fallback.
    """
    idx, valid, dist = cand.idx, cand.valid, cand.dist
    n, k = idx.shape
    nk = n * k
    flat_e = idx.reshape(-1)
    flat_s = jnp.where(valid, pref, -jnp.inf).reshape(-1)
    # one rank order for the whole resolution: pairs by (edge asc, score
    # desc, flat order asc) — lexsort is stable, and flat order is client-
    # major, so exact score ties break on the lower client index, exactly
    # like the dense stable ``argsort(-pref, axis=0)``
    perm = jnp.lexsort((-flat_s, flat_e))                      # (NK,)
    inv = jnp.zeros((nk,), jnp.int32).at[perm].set(
        jnp.arange(nk, dtype=jnp.int32))
    sorted_e = flat_e[perm]
    iota = jnp.arange(nk, dtype=jnp.int32)
    is_start = jnp.concatenate(
        [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]])
    seg_start = jax.lax.cummax(jnp.where(is_start, iota, 0))   # (NK,)
    col_k = jnp.arange(k, dtype=jnp.int32)
    max_sweeps = nk + 2

    def cond(s):
        _, _, done, it = s
        return (~done) & (it < max_sweeps)

    def body(s):
        assigned, rejected, _, it = s
        held = (assigned[:, None] == idx) & (assigned >= 0)[:, None]
        # per-edge held count: ints scatter-add exactly; a −1 (unmatched)
        # client adds weight 0 at slot 0
        deficit = quota - jnp.zeros((n_edges,), jnp.int32).at[
            jnp.maximum(assigned, 0)].add((assigned >= 0).astype(jnp.int32))
        elig = valid & (~rejected) & (~held)                   # (N, K)
        es = elig.reshape(-1)[perm]                            # rank order
        # eligible-with-smaller-rank count via ONE segmented cumsum: the
        # deficit-th smallest eligible rank cut-off of the dense resolver,
        # without per-sweep top_k
        c = jnp.cumsum(es.astype(jnp.int32))
        before = jnp.where(seg_start > 0, c[jnp.maximum(seg_start - 1, 0)],
                           0)
        n_better = c - es.astype(jnp.int32) - before
        prop_sorted = es & (n_better < deficit[sorted_e])
        propose = prop_sorted[inv].reshape(n, k)
        offer = propose | held
        # slots are (distance, edge)-sorted, so the FIRST minimum over the
        # offer-masked distances is the strict lexicographic best offer
        ckey = jnp.where(offer, dist, jnp.inf)
        best = jnp.argmin(ckey, axis=1).astype(jnp.int32)
        has = jnp.any(offer, axis=1)
        assigned = jnp.where(
            has, jnp.take_along_axis(idx, best[:, None], axis=1)[:, 0],
            jnp.asarray(-1, jnp.int32))
        rejected = rejected | (offer & (col_k[None, :] != best[:, None]))
        return assigned, rejected, ~jnp.any(propose), it + 1

    def run(assigned0):
        state = (assigned0, ~valid, jnp.asarray(False),
                 jnp.asarray(0, jnp.int32))
        final = jax.lax.while_loop(cond, body, state)
        return final[0], final[3]

    cold0 = jnp.full((n,), -1, jnp.int32)
    if seed is None:
        assigned, sweeps = run(cold0)
    else:
        ok = (seed >= 0) & jnp.any((idx == seed[:, None]) & valid, axis=1)
        a_w, sweeps_w = run(jnp.where(ok, seed.astype(jnp.int32), -1))
        assigned, extra = jax.lax.cond(
            _blocking_pair_frontier(a_w, idx, valid, inv, quota, n_edges),
            lambda: run(cold0),
            lambda: (a_w, jnp.asarray(0, jnp.int32)))
        sweeps = sweeps_w + extra
    if return_sweeps:
        return assigned, sweeps
    return assigned


def associate_candidates(policy: str, *, scores: jnp.ndarray | None,
                         gains: jnp.ndarray, cand, quota: int, key,
                         n_edges: int, return_sweeps: bool = False,
                         seed: jnp.ndarray | None = None) -> jnp.ndarray:
    """Candidate-frontier association (DESIGN.md §9): the (N, K) analogue
    of ``associate_jax``, returning the compact assigned vector (N,).

    ``scores``: fcea competency ALREADY on the frontier — (N, K) from
    ``fuzzy.score_candidates`` — or a per-client (N,) vector (broadcast
    here).  A dense (N, M) matrix is NOT accepted: with K = M its shape is
    indistinguishable from the frontier layout, so the caller must gather
    (``candidates.gather``) explicitly.  gcea gathers the gains; rcea
    draws its uniform preference at the DENSE (N, M) shape and gathers,
    so the PRNG stream — and hence the matching — is bit-identical to the
    dense path for every policy.
    """
    from repro.core import candidates as _cand
    if policy == "fcea":
        pref = scores
        if pref.ndim == 1:
            pref = jnp.broadcast_to(pref[:, None], cand.idx.shape)
        if pref.shape != cand.idx.shape:
            raise ValueError(
                f"fcea candidate scores must be (N, K) {cand.idx.shape} "
                f"(frontier layout), got {pref.shape}")
    elif policy == "gcea":
        pref = _cand.gather(cand, gains)
    elif policy == "rcea":
        pref = _cand.gather(cand, jax.random.uniform(key, gains.shape))
    else:
        raise ValueError(f"unknown association policy {policy!r}")
    return resolve_candidates(pref, cand, quota, n_edges,
                              return_sweeps=return_sweeps, seed=seed)


RESOLVERS: Dict[str, Callable[..., jnp.ndarray]] = {
    "parallel": resolve_parallel,
    "serial": resolve_jax,
}


# Registry: policy name -> preference-matrix builder (N, M).  ``scores`` may
# be None for policies that don't use the fuzzy competency.
PrefBuilder = Callable[..., jnp.ndarray]

POLICIES: Dict[str, PrefBuilder] = {
    "fcea": lambda scores, gains, key: scores,
    "gcea": lambda scores, gains, key: gains,
    "rcea": lambda scores, gains, key: jax.random.uniform(key, gains.shape),
}


def associate_jax(policy: str, *, scores: jnp.ndarray | None,
                  gains: jnp.ndarray, dist: jnp.ndarray, quota: int,
                  coverage_radius_m: float, key,
                  avail: jnp.ndarray | None = None,
                  resolver: str = "parallel",
                  return_sweeps: bool = False,
                  seed: jnp.ndarray | None = None) -> jnp.ndarray:
    """JAX-native association (N, M) one-hot; pure, jit/vmap-safe.

    ``avail`` (N,) is the scenario availability mask (DESIGN.md §6): an
    unavailable client is treated as out of every edge's coverage, so no
    policy can admit it and its quota slot goes to the next candidate.
    ``resolver`` picks the conflict-resolution implementation — both
    compute the same matching (DESIGN.md §8.1); "serial" is the legacy
    one-pop-per-step while-loop kept for A/B benchmarking.
    """
    if policy not in POLICIES:
        raise ValueError(f"unknown association policy {policy!r}")
    if resolver not in RESOLVERS:
        raise ValueError(f"unknown association resolver {resolver!r}; "
                         f"choose from {sorted(RESOLVERS)}")
    pref = POLICIES[policy](scores, gains, key)
    if pref.ndim == 1:
        pref = jnp.broadcast_to(pref[:, None], dist.shape)
    coverage = dist <= coverage_radius_m
    if avail is not None:
        coverage = coverage & (avail > 0)[:, None]
    pref = jnp.where(coverage, pref, -jnp.inf)
    order = jnp.argsort(-pref, axis=0).T                       # (M, N)
    if seed is not None:
        if resolver != "parallel":
            raise ValueError("warm-start seeding needs the 'parallel' "
                             "resolver (the serial legacy loop has no "
                             "seeded-hold start)")
        return resolve_parallel(order, dist, quota, coverage,
                                return_sweeps=return_sweeps, seed=seed)
    return RESOLVERS[resolver](order, dist, quota, coverage,
                               return_sweeps=return_sweeps)


def associate(policy: str, *, scores: np.ndarray, gains_to_edges: np.ndarray,
              dist: np.ndarray, quota: int, coverage_radius_m: float,
              rng: np.random.Generator) -> np.ndarray:
    if policy == "fcea":
        return fcea(scores, dist, quota, coverage_radius_m)
    if policy == "gcea":
        # single-criterion: strongest channel to each edge
        return gcea(gains_to_edges, dist, quota, coverage_radius_m)
    if policy == "rcea":
        return rcea(rng, dist, quota, coverage_radius_m)
    raise ValueError(f"unknown association policy {policy!r}")
