"""Hierarchical model aggregation (paper Eqs. 11, 17).

Client models live STACKED along a leading client axis (the vmap axis that
the mesh `data` dimension shards), so edge aggregation is a data-weighted
reduction over association groups and the semi-synchronous cloud aggregation
is a masked reduction over edges — both single fused XLA reductions, which is
the TPU-native mapping of the paper's client→edge→cloud hierarchy
(DESIGN.md §3).
"""
from __future__ import annotations

from typing import Any, Dict

import jax
import jax.numpy as jnp

Params = Any


def weighted_mean(stacked: Params, weights: jnp.ndarray) -> Params:
    """Σ w_i · leaf_i / Σ w_i over the leading axis."""
    total = jnp.maximum(jnp.sum(weights), 1e-12)

    def avg(leaf):
        w = weights.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)
        return jnp.sum(leaf * w, axis=0) / total.astype(leaf.dtype)

    return jax.tree.map(avg, stacked)


def edge_aggregate(client_params: Params, assoc: jnp.ndarray,
                   n_samples: jnp.ndarray) -> Params:
    """Eq. 11 for every edge at once.

    client_params: leaves (N, ...); assoc (N, M); n_samples (N,).
    Returns leaves (M, ...) — edge m's data-weighted average of its clients.
    """
    w = assoc * n_samples[:, None]                    # (N, M)
    denom = jnp.maximum(jnp.sum(w, axis=0), 1e-12)    # (M,)

    def agg(leaf):
        wl = w.astype(leaf.dtype)
        out = jnp.einsum("nm,n...->m...", wl, leaf)
        return out / denom.reshape((-1,) + (1,) * (leaf.ndim - 1)).astype(leaf.dtype)

    return jax.tree.map(agg, client_params)


def cloud_aggregate(edge_params: Params, z: jnp.ndarray,
                    edge_data: jnp.ndarray) -> Params:
    """Eq. 17: semi-synchronous masked aggregation over edges.

    edge_params: leaves (M, ...); z (M,) selection mask; edge_data (M,)
    aggregated data sizes D_{N_m}.
    """
    return weighted_mean(edge_params, z * edge_data)


def broadcast_to_clients(params: Params, assoc: jnp.ndarray,
                         edge_params: Params, client_params: Params) -> Params:
    """Edge model broadcast: associated clients adopt their edge's model,
    unassociated clients keep their local params."""
    is_assoc = jnp.sum(assoc, axis=1) > 0             # (N,)

    def pick(edge_leaf, client_leaf):
        # client n's edge model (N, ...)
        from_edge = jnp.einsum("nm,m...->n...", assoc.astype(edge_leaf.dtype),
                               edge_leaf)
        mask = is_assoc.reshape((-1,) + (1,) * (edge_leaf.ndim - 1))
        return jnp.where(mask, from_edge, client_leaf)

    return jax.tree.map(pick, edge_params, client_params)


def replicate(params: Params, n: int) -> Params:
    """Tile a single model into a stacked (n, ...) pytree."""
    return jax.tree.map(lambda l: jnp.broadcast_to(l[None], (n,) + l.shape), params)


# ---------------------------------------------------------------------------
# Staleness-weighted aggregation buffer (DESIGN.md §11)
#
# The buffered engine replaces the semi-synchronous Eq. 17 barrier with a
# FedBuff-style running buffer: client updates land as weighted DELTAS
# (trained params minus the global model they pulled) whenever their
# virtual finish time passes, and the cloud applies the weighted-mean
# delta on a fill-or-timeout trigger.  The three functions below are that
# buffer's whole algebra: zero, accumulate, apply — all pure tree maps, so
# the buffer rides the scan carry like any other pytree.
# ---------------------------------------------------------------------------

def faulted_cloud_aggregate(global_params: Params, client_deltas: Params,
                            assoc_eff: jnp.ndarray, n_samples: jnp.ndarray,
                            z: jnp.ndarray) -> Params:
    """The sync round's cloud epilogue under faults, in DELTA space.

    With crashes/losses/quarantine the surviving cohort can shrink to
    anything — including nothing — so the hierarchy aggregates client
    DELTAS (trained − global) instead of raw params: a client that
    contributes nothing moves nothing, and an edge (or round) with zero
    surviving data leaves the global model bit-unchanged.

    client_deltas: leaves (N, ...) — already quarantined (guard-cleaned);
    assoc_eff (N, M) — association masked to surviving clients;
    n_samples (N,); z (M,) scheduler selection.
    """
    edge_delta = edge_aggregate(client_deltas, assoc_eff, n_samples)
    edge_data = jnp.sum(assoc_eff * n_samples[:, None], axis=0)   # (M,)
    z_eff = z * (edge_data > 0).astype(z.dtype)
    agg = cloud_aggregate(edge_delta, z_eff, edge_data)
    has_data = jnp.sum(z_eff * edge_data) > 0

    def upd(g, d):
        return jnp.where(has_data, g + d.astype(g.dtype), g)

    return jax.tree.map(upd, global_params, agg)


def buffer_zeros(params: Params) -> Params:
    """A zeroed delta accumulator shaped like the global model."""
    return jax.tree.map(jnp.zeros_like, params)


def buffer_accumulate(delta_sum: Params, weight_sum: jnp.ndarray,
                      deltas: Params, weights: jnp.ndarray
                      ) -> tuple:
    """Fold a batch of per-client deltas into the buffer.

    deltas: leaves (N, ...); weights (N,) — zero for clients that did not
    land this micro-step (their pending delta contributes nothing).
    Returns (delta_sum', weight_sum').
    """

    def add(acc, d):
        w = weights.reshape((-1,) + (1,) * (d.ndim - 1)).astype(d.dtype)
        return acc + jnp.sum(d * w, axis=0)

    return (jax.tree.map(add, delta_sum, deltas),
            weight_sum + jnp.sum(weights))


def buffer_apply(global_params: Params, delta_sum: Params,
                 weight_sum: jnp.ndarray, lr: float,
                 apply_mask: jnp.ndarray) -> Params:
    """The trigger: global' = global + lr · Σw·Δ / Σw  when ``apply_mask``
    (and the buffer is non-empty), else the global model unchanged.

    Dividing by ``weight_sum`` makes the EFFECTIVE per-update weights
    w_n / Σw sum to exactly 1 — the buffered merge is a weighted mean of
    deltas, invariant to a common rescaling of the raw weights (pinned by
    tests/test_buffered.py).
    """
    ok = apply_mask & (weight_sum > 0)
    denom = jnp.maximum(weight_sum, 1e-12)

    def upd(g, d):
        return jnp.where(ok, g + jnp.asarray(lr, g.dtype)
                         * d.astype(g.dtype) / denom.astype(g.dtype), g)

    return jax.tree.map(upd, global_params, delta_sum)
