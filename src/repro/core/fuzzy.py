"""Fuzzy-logic client competency scoring (paper §III).

Three normalised inputs in [0, 100] — channel quality (CQ), data quantity
(DQ), model staleness (MS) — pass through triangular membership functions
(paper Fig. 4), the 27-rule Mamdani table (paper Table I) with Max–Min
inference, and centre-of-gravity defuzzification (Eq. 22).  The output
NO* ∈ [0, 100] is the client's competency level for client-edge association.

Everything is pure jnp and vmappable over clients; the whole scoring of N
clients fuses into one XLA program.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# Fuzzy set indices
WEAK, MEDIUM, STRONG = 0, 1, 2                 # CQ
SHORTAGE, AVERAGE_DQ, SUFFICIENT = 0, 1, 2     # DQ
FRESH, MEDIUM_MS, STALE = 0, 1, 2              # MS
POOR, FAIR, AVG, GOOD, EXCELLENT = 0, 1, 2, 3, 4

# Paper Table I: RULES[cq, dq, ms] -> output set index.
RULES = jnp.array([
    # CQ = weak (rules 19-27)
    [[POOR, POOR, FAIR],        # DQ shortage: MS fresh/medium/stale
     [POOR, FAIR, AVG],         # DQ average
     [FAIR, AVG, GOOD]],        # DQ sufficient
    # CQ = medium (rules 10-18)
    [[POOR, FAIR, AVG],
     [FAIR, AVG, GOOD],
     [AVG, GOOD, EXCELLENT]],
    # CQ = strong (rules 1-9)
    [[FAIR, AVG, GOOD],
     [AVG, GOOD, EXCELLENT],
     [GOOD, EXCELLENT, EXCELLENT]],
], dtype=jnp.int32)

# Triangular membership (a, b, c): peak at b, support [a, c].
_IN_TRIS = jnp.array([      # the three input sets share one geometry
    [-50.0, 0.0, 50.0],     # weak / shortage / fresh
    [0.0, 50.0, 100.0],     # medium / average / medium
    [50.0, 100.0, 150.0],   # strong / sufficient / stale
])

_OUT_TRIS = jnp.array([
    [-25.0, 0.0, 25.0],     # poor
    [0.0, 25.0, 50.0],      # fair
    [25.0, 50.0, 75.0],     # average
    [50.0, 75.0, 100.0],    # good
    [75.0, 100.0, 125.0],   # excellent
])

_COG_GRID = jnp.linspace(0.0, 100.0, 201)


def tri(x: jnp.ndarray, abc: jnp.ndarray) -> jnp.ndarray:
    """Triangular membership value(s); broadcasts x against abc rows."""
    a, b, c = abc[..., 0], abc[..., 1], abc[..., 2]
    up = (x - a) / jnp.maximum(b - a, 1e-9)
    down = (c - x) / jnp.maximum(c - b, 1e-9)
    return jnp.clip(jnp.minimum(up, down), 0.0, 1.0)


def input_memberships(v: jnp.ndarray) -> jnp.ndarray:
    """Scalar normalised input -> membership degrees over the 3 input sets."""
    return tri(v[..., None], _IN_TRIS)


def normalize(v: jnp.ndarray, max_value: float) -> jnp.ndarray:
    """Paper Eq. (21): NV = V / MV × 100%."""
    return jnp.clip(v / jnp.maximum(max_value, 1e-12), 0.0, 1.0) * 100.0


def rule_strengths(cq: jnp.ndarray, dq: jnp.ndarray, ms: jnp.ndarray
                   ) -> jnp.ndarray:
    """Max–Min inference: per-output-set firing strength, shape (5,).

    Rule degree = min of the three memberships (paper's Min); when several
    rules map to the same output set, the strongest wins (paper's Max).
    """
    m_cq = input_memberships(cq)          # (3,)
    m_dq = input_memberships(dq)
    m_ms = input_memberships(ms)
    # (3,3,3) rule firing degrees
    deg = jnp.minimum(jnp.minimum(m_cq[:, None, None], m_dq[None, :, None]),
                      m_ms[None, None, :])
    out = jnp.zeros((5,))
    out = out.at[RULES.reshape(-1)].max(deg.reshape(-1))
    return out


def defuzzify_cog(strengths: jnp.ndarray) -> jnp.ndarray:
    """Mamdani clip + aggregate + COG over the output domain (Eq. 22)."""
    mu_out = tri(_COG_GRID[:, None], _OUT_TRIS[None, :, :])   # (G, 5)
    clipped = jnp.minimum(mu_out, strengths[None, :])
    agg = jnp.max(clipped, axis=-1)                           # (G,)
    num = jnp.sum(_COG_GRID * agg)
    den = jnp.maximum(jnp.sum(agg), 1e-9)
    return num / den


def fuzzy_score(cq: jnp.ndarray, dq: jnp.ndarray, ms: jnp.ndarray
                ) -> jnp.ndarray:
    """Normalised inputs in [0,100] -> competency NO* in [0,100]."""
    return defuzzify_cog(rule_strengths(cq, dq, ms))


# Vectorised over clients: (N,), (N,), (N,) -> (N,)
fuzzy_scores = jax.jit(jax.vmap(fuzzy_score))


def normalized_inputs(gains: jnp.ndarray, counts: jnp.ndarray,
                      staleness: jnp.ndarray, *, data_max: float):
    """The Eq. 21 normalisation stage shared by the jnp ``score_matrix``
    and the Pallas kernel (``kernels.hfl_ops.score_matrix``): returns
    (cq (N, M), dq (N,), ms (N,)) in [0, 100].

    CQ is the per-edge channel quality normalised in dB: raw |h|² spans
    four decades of path loss, so a linear V/MV map collapses all but the
    nearest clients to 0 — the dB scale is what 'channel quality' means
    in practice.  DQ and MS are shared across edges.
    """
    db = 10.0 * jnp.log10(jnp.maximum(gains, 1e-30))
    lo, hi = jnp.min(db), jnp.max(db)
    cq = normalize(db - lo, jnp.maximum(hi - lo, 1e-9))          # (N, M)
    dq = normalize(counts.astype(jnp.float32), data_max)          # (N,)
    ms = normalize(staleness.astype(jnp.float32),
                   jnp.maximum(jnp.max(staleness), 1).astype(jnp.float32))
    return cq, dq, ms


def score_matrix(gains: jnp.ndarray, counts: jnp.ndarray,
                 staleness: jnp.ndarray, *, data_max: float) -> jnp.ndarray:
    """(N, M) competency matrix, fully inside JAX (no host round-trips).

    This is the jittable replacement for the per-edge host loop the eager
    simulation used to run (DESIGN.md §2); the Pallas-fused variant lives
    in ``kernels.hfl_ops`` behind ``EngineSpec.pallas_score``.
    """
    cq, dq, ms = normalized_inputs(gains, counts, staleness,
                                   data_max=data_max)
    per_edge = jax.vmap(fuzzy_scores, in_axes=(1, None, None), out_axes=1)
    return per_edge(cq, dq, ms)


def score_candidates(gains: jnp.ndarray, cand, counts: jnp.ndarray,
                     staleness: jnp.ndarray, *, data_max: float
                     ) -> jnp.ndarray:
    """(N, K) competency scores on the candidate frontier (DESIGN.md §9).

    The Eq. 21 CQ normalisation keeps its GLOBAL dB min/max over the full
    (N, M) gain field — an O(N·M) elementwise reduction — and only the
    expensive per-pair Mamdani inference + CoG defuzzification (the
    O(N·M·G·5) term the dense ``score_matrix`` pays) is pruned to the N·K
    candidate pairs.  Gather-then-normalise equals normalise-then-gather
    elementwise, so each returned score is bit-identical to the dense
    matrix entry at the same (client, edge) pair.
    """
    cq, dq, ms = normalized_inputs(gains, counts, staleness,
                                   data_max=data_max)
    cq_k = jnp.take_along_axis(cq, cand.idx, axis=1)            # (N, K)
    per_slot = jax.vmap(fuzzy_scores, in_axes=(1, None, None), out_axes=1)
    return per_slot(cq_k, dq, ms)


def score_clients(channel_gain: jnp.ndarray, data_quantity: jnp.ndarray,
                  staleness: jnp.ndarray, *, gain_max: float | jnp.ndarray,
                  data_max: float | jnp.ndarray,
                  staleness_max: float | jnp.ndarray) -> jnp.ndarray:
    """End-to-end: raw per-client criteria -> NO* scores (N,)."""
    cq = normalize(channel_gain, gain_max)
    dq = normalize(data_quantity, data_max)
    ms = normalize(staleness, staleness_max)
    return fuzzy_scores(cq, dq, ms)
