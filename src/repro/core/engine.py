"""Pure-functional HFL round engine (DESIGN.md §2).

The paper's global round (fade → fuzzy-score → associate → allocate →
τ₂·τ₁ training → schedule → cloud aggregate, §II-§IV) as ONE pure function:

    round_step(cfg, spec, state, bundle) -> (state', RoundMetrics)

* ``RoundState``  — everything that evolves across rounds, as a pytree:
  stacked global/client params, channel gains, staleness, the PRNG key and
  the round index.
* ``RoundBundle`` — everything that is fixed for one scenario but differs
  between scenarios (topology distances, the federated dataset): traced
  arrays, so a *batch* of scenarios is just a stacked bundle.
* ``cfg``/``spec`` — hashable static configuration; they select code paths
  at trace time (association policy, allocator, scheduler, NOMA vs OMA).

Because ``round_step`` is end-to-end jittable (association included — see
``association.resolve_jax``), two compiled drivers come for free:

* ``run_scanned``  — ``lax.scan`` over rounds: an entire experiment is one
  XLA program (no per-round dispatch, no host sync);
* ``run_fleet``    — ``vmap`` over a batch of independent simulations for
  multi-seed / multi-scenario sweeps, on top of the scanned driver.

The legacy ``HFLSimulation`` class survives as a thin stateful wrapper in
``repro.core.hfl``.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (aggregation, association, candidates, cost, env,
                        fuzzy, noma, pdd, staleness)
from repro.core.candidates import CandidateSet
from repro import telemetry
from repro.telemetry.spans import stage as _stage
from repro.data import federated
from repro.faults import guard as fault_guard
from repro.faults import inject as fault_inject
from repro.faults.spec import FaultSpec, FaultState, init_faults
from repro.models import layers
from repro.models.mlp import MLPClassifier
from repro import scenarios
from repro.scenarios import ScenarioSpec, ScenarioState

Params = Any


# ---------------------------------------------------------------------------
# Static spec + pytrees
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class EngineSpec:
    """Static (hashable) per-simulation switches; a jit static argument."""
    policy: str = "fcea"            # fcea | gcea | rcea
    allocator: str = "mid"          # mid | rra | fpa | fca | ddpg
    scheduler: str = "pdd"          # pdd | fastest
    noma_enabled: bool = True
    fading_rho: float = 0.9
    oma_quota_factor: float = 0.5
    # scenario transition KIND only (a trace-time switch into
    # scenarios.TRANSITIONS) — the scenario's numbers live in the
    # ScenarioState arrays, so different parameterisations share a compile.
    scenario: str = "static"
    # hot-path implementation switches (DESIGN.md §8).  All of them pick
    # between bit-compatible (resolver) or float-summation-order-compatible
    # (sic_impl, pallas_score) implementations of the SAME math:
    # * resolver — "parallel" sweep deferred-acceptance (default) vs the
    #   legacy "serial" one-pop-per-step while-loop, kept for A/B;
    # * sic_impl — "auto" (sorted cumulative-interference from N ≥ 64,
    #   bit-stable pairwise below) | "pairwise" | "sorted" | "pallas";
    # * pallas_score — route fcea fuzzy scoring through the fused
    #   kernels.hfl_ops.score_matrix kernel (interpret-mode on CPU).
    resolver: str = "parallel"
    sic_impl: str = "auto"
    pallas_score: bool = False
    # (N, K) candidate frontier (DESIGN.md §9): score/associate/bill only
    # each client's K nearest edges instead of all M.  ``None`` = dense
    # (the golden-pinned PR-4 path, bit-for-bit); K ≥ the max in-coverage
    # degree is bit-identical to dense by the §9 parity contract, smaller
    # K prunes the market (feasibility invariants still hold).
    candidates_k: Optional[int] = None
    # in-scan telemetry (DESIGN.md §10): with it on, ``round_step`` returns
    # ``(state', (RoundMetrics, telemetry.RoundTrace))`` — the per-stage
    # Eq. 23a decomposition plus association/scheduler internals riding the
    # scan outputs.  Off (the default) the trace is STRUCTURALLY absent:
    # the lowered program and every output are bit-identical to the
    # telemetry-less engine (golden parity holds un-re-recorded).
    telemetry: bool = False
    # semi-async buffered round engine (DESIGN.md §11).  "sync" is the
    # paper's semi-synchronous barrier — bit-for-bit today's program, with
    # the aggregation buffer STRUCTURALLY absent from the carry.
    # "buffered" turns ``round_step`` into a MICRO-step: each scan step
    # admits one TiFL-style speed-tier cohort through the same fuzzy/
    # candidate/association pipeline, trains it, and lands its
    # staleness-weighted model deltas in a FedBuff aggregation buffer at
    # their per-client Eq. 13/15 virtual finish times; the cloud applies
    # the buffered merge when ``buffer_fill`` updates landed OR
    # ``timeout_s`` of virtual time elapsed since the last aggregation —
    # round throughput becomes buffer-drain rate instead of
    # min-over-clients.
    engine_mode: str = "sync"       # sync | buffered
    buffer_fill: int = 0            # 0 = auto: (quota · M) // 2
    timeout_s: float = 10.0         # virtual seconds between forced merges
    n_tiers: int = 4                # TiFL speed tiers (1 = no tiering)
    retier_every: int = 8           # micro-steps between quantile retiers
    buffer_lr: float = 1.0          # server step on the merged mean delta
    # fault injection & graceful degradation (DESIGN.md §12).  ``None``
    # (the default) keeps every fault path STRUCTURALLY absent — no
    # FaultState rides the carry, no fault op is traced, and every golden
    # trajectory stays bit-exact un-re-recorded (the telemetry/engine_mode
    # discipline).  Set a ``FaultSpec`` to turn on edge churn, SINR-tied
    # uplink loss with retry/backoff (buffered mode), mid-round crashes,
    # delta poisoning, and the update-quarantine guard.
    faults: Optional[FaultSpec] = None
    # training-stage implementation (DESIGN.md §13): how the admitted
    # cohort's τ₂·τ₁ local-SGD steps are computed.  Every impl consumes
    # the SAME fold_in minibatch-index lattice (``_batch_index_lattice``),
    # so they all optimise the same update stream:
    # * "batched" — ONE ``lax.scan`` over τ₁ whose body is a
    #   (K, B, D)-batched GEMM step over the stacked cohort (what "auto"
    #   resolves to — the fastest CPU/TPU XLA path);
    # * "vmap"    — the per-client τ₁ scan vmapped over the cohort (the
    #   reference the bit-parity tests pin "batched" against);
    # * "pallas"  — the fused ``kernels.hfl_ops.local_sgd_step`` kernel
    #   holding one client block's params + activations in VMEM across
    #   the τ₁ steps (interpret-mode on CPU; opt-in pending the ROADMAP's
    #   TPU validation, like ``pallas_score``/``sic_impl="pallas"``).
    train_impl: str = "auto"        # auto | batched | vmap | pallas
    # warm-started association (DESIGN.md §13.4): carry the previous
    # round's assigned vector in ``RoundState.warm`` and seed the
    # deferred-acceptance sweeps from it — under mobility the seed is
    # nearly stable, so the resolver converges in a sweep or two, with a
    # blocking-pair check + cold-resolver fallback guarding exactness.
    # Off (the default) the warm leaf is STRUCTURALLY absent and no seed
    # reaches the resolver: the cold program is bit-identical.
    warm_start: bool = False


class RoundBundle(NamedTuple):
    """Per-scenario constants (traced; leading batch axis under vmap)."""
    dist: jnp.ndarray        # (N, M) client-edge distances
    x: jnp.ndarray           # (N, cap, dim) padded client data
    y: jnp.ndarray           # (N, cap) labels
    counts: jnp.ndarray      # (N,) float32 — D_n
    test_x: jnp.ndarray      # (T, dim)
    test_y: jnp.ndarray      # (T,)


class BufferState(NamedTuple):
    """The buffered engine's extra scan carry (DESIGN.md §11): the FedBuff
    aggregation buffer + the per-client in-flight bookkeeping + the TiFL
    tier table.  Lives in ``RoundState.buffer`` on the buffered path and
    is ``None`` (structurally absent — zero leaves, zero program bytes)
    in ``engine_mode="sync"``."""
    pending_delta: Params    # (N, ...) trained-minus-pulled model deltas
    finish_s: jnp.ndarray    # (N,) f32 absolute virtual completion times
    in_flight: jnp.ndarray   # (N,) bool — admitted, not yet landed
    pulled_ver: jnp.ndarray  # (N,) int32 global version at admission
    obs_s: jnp.ndarray       # (N,) f32 EMA of measured finish durations
    tier: jnp.ndarray        # (N,) int32 TiFL speed tier (0 = fastest)
    delta_sum: Params        # global-shaped Σ w·Δ accumulator
    weight_sum: jnp.ndarray  # () f32 Σ w over buffered updates
    fill: jnp.ndarray        # () int32 updates landed since last trigger
    version: jnp.ndarray     # () int32 cloud aggregation count
    clock_s: jnp.ndarray     # () f32 virtual wall clock
    last_agg_s: jnp.ndarray  # () f32 clock at the last trigger
    step: jnp.ndarray        # () int32 micro-step counter


class RoundState(NamedTuple):
    """Everything that evolves across global rounds."""
    global_params: Params    # cloud model
    client_params: Params    # stacked (N, ...) client models
    gains: jnp.ndarray       # (N, M) current |h|²
    staleness: jnp.ndarray   # (N,) int32 — A_n
    key: jnp.ndarray         # PRNG key
    round_idx: jnp.ndarray   # () int32
    scenario: ScenarioState  # per-round world state (DESIGN.md §6)
    buffer: Any = None       # BufferState | None (DESIGN.md §11)
    faults: Any = None       # FaultState | None (DESIGN.md §12)
    warm: Any = None         # (N,) int32 prev assigned | None (§13.4)


class RoundMetrics(NamedTuple):
    """Per-round observables (jnp leaves; stacked along rounds by scan)."""
    round: jnp.ndarray
    accuracy: jnp.ndarray
    loss: jnp.ndarray
    avg_staleness: jnp.ndarray
    total_time_s: jnp.ndarray
    total_energy_j: jnp.ndarray
    cost: jnp.ndarray
    n_associated: jnp.ndarray
    n_available: jnp.ndarray
    z: jnp.ndarray           # (M,)


# ---------------------------------------------------------------------------
# Topology (paper §V: 500 m square, cloud at centre, 4 edges at midpoints
# of the corner-to-centre lines, clients uniform)
# ---------------------------------------------------------------------------

def make_topology(rng: np.random.Generator, *, n_clients: int, n_edges: int,
                  area_side_m: float) -> Dict[str, np.ndarray]:
    half = area_side_m / 2.0
    cloud = np.array([half, half])
    corners = np.array([[0.0, 0.0], [0.0, area_side_m],
                        [area_side_m, 0.0], [area_side_m, area_side_m]])
    mids = (corners + cloud) / 2.0
    if n_edges <= 4:
        edges = mids[:n_edges]
    else:  # extra edges uniformly placed
        extra = rng.uniform(0.0, area_side_m, (n_edges - 4, 2))
        edges = np.concatenate([mids, extra], axis=0)
    clients = rng.uniform(0.0, area_side_m, (n_clients, 2))
    dist = np.linalg.norm(clients[:, None, :] - edges[None, :, :], axis=-1)
    return {"cloud": cloud, "edges": edges, "clients": clients, "dist": dist}


def coverage_radius(cfg) -> float:
    """Generous enough that every client can reach ≥ 1 edge."""
    return cfg.area_side_m * 0.75


def quota_for(cfg, spec: EngineSpec) -> int:
    """OMA admits fewer clients per edge: each needs an orthogonal channel
    slice (paper §V-B — 'insufficient orchestrated clients')."""
    if spec.noma_enabled:
        return cfg.clients_per_edge
    return max(1, int(cfg.clients_per_edge * spec.oma_quota_factor))


def buffer_fill_for(cfg, spec: EngineSpec) -> int:
    """The fill half of the fill-or-timeout trigger.  ``buffer_fill=0``
    resolves to half the per-micro-step admission capacity (quota · M),
    so in steady state the trigger fires well before a whole cohort's
    straggler tail lands."""
    if spec.buffer_fill > 0:
        return int(spec.buffer_fill)
    return max(1, (quota_for(cfg, spec) * cfg.n_edges) // 2)


def init_buffer(cfg, spec: EngineSpec, state: "RoundState") -> BufferState:
    """A fresh (empty) aggregation buffer shaped for ``state``'s models.
    Tiers start round-robin over clients (balanced cohorts before any
    finish time has been observed); the first quantile retier replaces
    them with measured-speed tiers."""
    n = cfg.n_clients
    f32, i32 = jnp.float32, jnp.int32
    return BufferState(
        pending_delta=jax.tree.map(jnp.zeros_like, state.client_params),
        finish_s=jnp.zeros((n,), f32),
        in_flight=jnp.zeros((n,), bool),
        pulled_ver=jnp.zeros((n,), i32),
        obs_s=jnp.zeros((n,), f32),
        tier=jnp.arange(n, dtype=i32) % max(1, int(spec.n_tiers)),
        delta_sum=aggregation.buffer_zeros(state.global_params),
        weight_sum=jnp.zeros((), f32),
        fill=jnp.zeros((), i32),
        version=jnp.zeros((), i32),
        clock_s=jnp.zeros((), f32),
        last_agg_s=jnp.zeros((), f32),
        step=jnp.zeros((), i32))


def ensure_buffer(cfg, spec: EngineSpec, state: "RoundState") -> "RoundState":
    """Normalise ``state.buffer`` to the spec's engine mode: attach a
    fresh buffer for ``engine_mode="buffered"`` (keeping one that is
    already there, e.g. mid-scan), strip it for "sync" so the sync carry
    — and with it every golden program — stays structurally identical to
    the pre-buffer engine.  The check is on the pytree STRUCTURE (None or
    not), so it is trace-time static and jit-safe."""
    if spec.engine_mode == "buffered":
        if state.buffer is None:
            return state._replace(buffer=init_buffer(cfg, spec, state))
        return state
    if spec.engine_mode != "sync":
        raise ValueError(f"unknown engine_mode {spec.engine_mode!r}; "
                         f"choose 'sync' or 'buffered'")
    if state.buffer is not None:
        return state._replace(buffer=None)
    return state


def ensure_faults(cfg, spec: EngineSpec, state: "RoundState") -> "RoundState":
    """Normalise ``state.faults`` to the spec: attach a fresh
    ``FaultState`` when ``spec.faults`` is set (keeping one already there,
    e.g. mid-scan or restored from a checkpoint), strip it when faults are
    off so the no-fault carry — and with it every golden program — stays
    structurally identical to the pre-fault engine.  Like
    ``ensure_buffer``, the check is on pytree STRUCTURE (None or not), so
    it is trace-time static and jit-safe."""
    if spec.faults is not None:
        if state.faults is None:
            return state._replace(faults=init_faults(cfg))
        return state
    if state.faults is not None:
        return state._replace(faults=None)
    return state


def init_warm(cfg) -> jnp.ndarray:
    """A fresh warm-start seed: every client unassigned (−1), so the first
    warm round degenerates to the cold resolver's empty start."""
    return jnp.full((cfg.n_clients,), -1, jnp.int32)


def ensure_warm(cfg, spec: EngineSpec, state: "RoundState") -> "RoundState":
    """Normalise ``state.warm`` to the spec: attach the unassigned seed
    when ``spec.warm_start`` is on (keeping one already there, e.g.
    mid-scan or restored from a checkpoint), strip it when off so the
    cold carry — and with it every golden program — stays structurally
    identical to the pre-warm engine.  Same pytree-STRUCTURE check as
    ``ensure_buffer``/``ensure_faults``: trace-time static, jit-safe."""
    if spec.warm_start:
        if state.warm is None:
            return state._replace(warm=init_warm(cfg))
        return state
    if state.warm is not None:
        return state._replace(warm=None)
    return state


def ensure_carry(cfg, spec: EngineSpec, state: "RoundState") -> "RoundState":
    """Normalise the FULL scan carry to the spec's optional subsystems
    (aggregation buffer + fault state + warm-association seed) — the one
    entry point drivers use."""
    return ensure_warm(
        cfg, spec, ensure_faults(cfg, spec, ensure_buffer(cfg, spec, state)))


# ---------------------------------------------------------------------------
# Initialisation (host side: numpy RNG builds the scenario once)
# ---------------------------------------------------------------------------

def init_simulation(cfg, *, seed: int = 0, iid: bool = True,
                    scenario: "ScenarioSpec | str | None" = None
                    ) -> Tuple[RoundState, RoundBundle, Dict[str, Any]]:
    """Build one scenario: returns (state, bundle, aux) where aux carries
    the host-side objects (topo dict, FederatedData, model, numpy rng).

    ``scenario`` (a ScenarioSpec, preset name or kind string) parameterises
    the dynamic world; its numpy draws happen AFTER topology + data, so the
    same seed yields the same federation under every scenario."""
    sspec = scenarios.preset(scenario)
    rng = np.random.default_rng(seed)
    key = jax.random.key(seed)
    topo = make_topology(rng, n_clients=cfg.n_clients, n_edges=cfg.n_edges,
                         area_side_m=cfg.area_side_m)
    data = federated.make_federated(
        rng, n_clients=cfg.n_clients, dim=cfg.input_dim,
        n_classes=cfg.n_classes, iid=iid,
        min_samples=cfg.min_samples, max_samples=cfg.max_samples,
        dirichlet_alpha=cfg.dirichlet_alpha,
        noise=getattr(cfg, "data_noise", 1.2))
    model = MLPClassifier(cfg.input_dim, cfg.hidden, cfg.n_classes)
    key, k_init = jax.random.split(key)
    global_params = model.init(k_init)
    dist = jnp.asarray(topo["dist"])
    key, k_gain = jax.random.split(key)
    gains = noma.rayleigh_gains(k_gain, dist,
                                path_loss_exponent=cfg.path_loss_exponent)
    state = RoundState(
        global_params=global_params,
        client_params=aggregation.replicate(global_params, cfg.n_clients),
        gains=gains,
        staleness=staleness.init_staleness(cfg.n_clients),
        key=key,
        round_idx=jnp.asarray(0, jnp.int32),
        scenario=scenarios.init_scenario(cfg, sspec, rng, topo))
    bundle = RoundBundle(
        dist=dist,
        x=jnp.asarray(data.x),
        y=jnp.asarray(data.y),
        counts=jnp.asarray(data.counts, jnp.float32),
        test_x=jnp.asarray(data.test_x),
        test_y=jnp.asarray(data.test_y))
    aux = {"topo": topo, "data": data, "model": model, "rng": rng,
           "scenario_spec": sspec}
    return state, bundle, aux


def stack_fleet(states_and_bundles) -> Tuple[RoundState, RoundBundle]:
    """Stack per-seed (state, bundle) pairs along a new leading fleet axis
    so ``run_fleet`` can vmap over them."""
    states = [s for s, _ in states_and_bundles]
    bundles = [b for _, b in states_and_bundles]
    stack = lambda *ls: jnp.stack(ls)
    return (jax.tree.map(stack, *states), jax.tree.map(stack, *bundles))


# ---------------------------------------------------------------------------
# Round pieces (pure)
# ---------------------------------------------------------------------------

def _local_sgd(model: MLPClassifier, lr: float, tau1: int, batch_size: int):
    """(params_N, x_N, y_N, count_N, key_N) -> params_N, vmapped over N.

    The LEGACY per-client split-per-step stream, kept for the eager
    baseline simulator (benchmarks/bench_rounds.LegacyEagerSim) — the
    round engine itself draws from the ``_batch_index_lattice`` stream
    (DESIGN.md §13.2)."""

    def one_client(params, x, y, count, key):
        def step(carry, k):
            p = carry
            idx = jax.random.randint(k, (batch_size,), 0,
                                     jnp.maximum(count, 1))
            bx, by = x[idx], y[idx]
            g = jax.grad(model.loss)(p, (bx, by))
            p = jax.tree.map(lambda w, gw: w - lr * gw, p, g)
            return p, None

        ks = jax.random.split(key, tau1)
        params, _ = jax.lax.scan(step, params, ks)
        return params

    return jax.vmap(one_client)


def _batch_index_lattice(key, tau2: int, tau1: int, gid: jnp.ndarray,
                         counts: jnp.ndarray, batch_size: int) -> jnp.ndarray:
    """Every minibatch index of the round in ONE batched draw
    (DESIGN.md §13.2): the key for (edge-iteration t, local step i,
    client c) is ``fold_in(fold_in(split(key, τ₂)[t], i), c)`` with ``c``
    the client's GLOBAL index.

    One outer split + a fold_in lattice replaces the nested per-iteration
    ``jax.random.split`` calls of the legacy stream — no O(N) key fan-out
    inside the scan, and the drawn index stream is a pure function of
    (round key, t, i, global id): identical between the dense and
    gathered cohort paths, identical across every ``train_impl``, and
    independent of which OTHER clients were admitted.  Pad lanes repeat a
    real client's id (their draws are discarded with the lane).

    gid/counts: (K,) global ids + per-lane sample counts.
    Returns idx (τ₂, τ₁, K, B) int32 into each lane's data buffer.
    """
    k_t = jax.random.split(key, tau2)
    hi = jnp.maximum(counts, 1)

    def one(kt, i, c, cnt):
        kc = jax.random.fold_in(jax.random.fold_in(kt, i), c)
        return jax.random.randint(kc, (batch_size,), 0, cnt)

    per_c = jax.vmap(one, in_axes=(None, None, 0, 0))
    per_i = jax.vmap(per_c, in_axes=(None, 0, None, None))
    per_t = jax.vmap(per_i, in_axes=(0, None, None, None))
    return per_t(k_t, jnp.arange(tau1, dtype=jnp.int32), gid, hi)


def _train_impl_for(spec: EngineSpec) -> str:
    """Resolve the static training-impl switch ("auto" → "batched")."""
    impl = "batched" if spec.train_impl == "auto" else spec.train_impl
    if impl not in ("batched", "vmap", "pallas"):
        raise ValueError(f"unknown train_impl {spec.train_impl!r}; choose "
                         f"'auto', 'batched', 'vmap' or 'pallas'")
    return impl


def _cohort_fit(model: MLPClassifier, lr: float, impl: str):
    """One edge-iteration of τ₁ local-SGD steps over the stacked K-lane
    cohort: ``fit(params_K, x_K, y_K, idx) -> params_K`` with ``idx``
    (τ₁, K, B) pre-drawn minibatch indices from the lattice.

    The three impls compute the same update stream (same indices, same
    math — DESIGN.md §13.1):

    * "batched": ONE ``lax.scan`` over τ₁ whose body gathers the (K, B)
      minibatch and takes a (K, B, D)-batched GEMM gradient step — the
      einsum contractions lower to batched ``dot_general``, so XLA fuses
      the whole cohort step instead of K small matmuls;
    * "vmap": the per-client τ₁ scan vmapped over lanes — the reference
      formulation (scan-of-batched-body and vmap-of-scan commute in XLA,
      so the two are bit-identical; tests/test_train_impl.py pins it);
    * "pallas": the fused VMEM-resident kernel (minibatches pre-gathered
      host-side to (τ₁, K, B, D) — the kernel never touches the (K, cap)
      data buffers).
    """
    if impl == "pallas":
        from repro.kernels import hfl_ops            # cycle-free lazy import

        def fit_pallas(params, x, y, idx):
            bx = jax.vmap(lambda ix: jnp.take_along_axis(
                x, ix[:, :, None], axis=1))(idx)     # (tau1, K, B, D)
            by = jax.vmap(lambda ix: jnp.take_along_axis(y, ix, axis=1))(
                idx)                                 # (tau1, K, B)
            return hfl_ops.local_sgd_step(params, bx, by, lr=lr)

        return fit_pallas

    if impl == "vmap":
        def one_client(params, x, y, ixs):           # ixs (tau1, B)
            def step(p, ix):
                g = jax.grad(model.loss)(p, (x[ix], y[ix]))
                return jax.tree.map(lambda w, gw: w - lr * gw, p, g), None

            params, _ = jax.lax.scan(step, params, ixs)
            return params

        def fit_vmap(params, x, y, idx):
            return jax.vmap(one_client, in_axes=(0, 0, 0, 1))(
                params, x, y, idx)

        return fit_vmap

    def cohort_loss(p, bx, by):
        # forward as (K, B, D)-batched contractions (batched dot_general)
        h = jax.nn.relu(jnp.einsum("kbd,kdh->kbh", bx, p["w1"])
                        + p["b1"][:, None, :])
        h = jax.nn.relu(jnp.einsum("kbh,khj->kbj", h, p["w2"])
                        + p["b2"][:, None, :])
        logits = jnp.einsum("kbh,khv->kbv", h, p["w3"]) + p["b3"][:, None, :]
        # per-lane mean CE summed over lanes: the gradient w.r.t. lane
        # k's params is exactly that lane's own Eq. 11 loss gradient
        return jnp.sum(jax.vmap(layers.softmax_cross_entropy)(logits, by))

    def fit_batched(params, x, y, idx):
        def step(p, ix):                             # ix (K, B)
            bx = jnp.take_along_axis(x, ix[:, :, None], axis=1)
            by = jnp.take_along_axis(y, ix, axis=1)
            g = jax.grad(cohort_loss)(p, bx, by)
            return jax.tree.map(lambda w, gw: w - lr * gw, p, g), None

        params, _ = jax.lax.scan(step, params, idx)
        return params

    return fit_batched


def _associate(cfg, spec: EngineSpec, key, gains, dist, counts, stale,
               avail: Optional[jnp.ndarray] = None,
               cand: Optional[CandidateSet] = None,
               with_sweeps: bool = False,
               seed: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Association, fully in JAX.  ``avail`` (N,) masks unavailable
    clients out of coverage (scenario dropout).

    Dense (``cand=None``): returns the (N, M) one-hot.  Candidate mode
    (DESIGN.md §9): fuzzy scoring and the resolver sweeps run on the
    (N, K) frontier (``avail`` is already folded into ``cand.valid`` by
    the builder) and the COMPACT assigned vector (N,) comes back.
    ``with_sweeps`` (telemetry) makes the result a (result, sweep-count)
    pair — the counter already sits in the resolver's while state."""
    scores = None
    if spec.policy == "fcea":
        if cand is not None:
            if spec.pallas_score:
                from repro.kernels import hfl_ops    # cycle-free lazy import
                scores = hfl_ops.score_candidates(
                    gains, cand.idx, counts, stale,
                    data_max=float(cfg.max_samples))
            else:
                scores = fuzzy.score_candidates(
                    gains, cand, counts, stale,
                    data_max=float(cfg.max_samples))
        elif spec.pallas_score:
            from repro.kernels import hfl_ops        # cycle-free lazy import
            scores = hfl_ops.score_matrix(gains, counts, stale,
                                          data_max=float(cfg.max_samples))
        else:
            scores = fuzzy.score_matrix(gains, counts, stale,
                                        data_max=float(cfg.max_samples))
    if cand is not None:
        return association.associate_candidates(
            spec.policy, scores=scores, gains=gains, cand=cand,
            quota=quota_for(cfg, spec), key=key, n_edges=cfg.n_edges,
            return_sweeps=with_sweeps, seed=seed)
    return association.associate_jax(
        spec.policy, scores=scores, gains=gains, dist=dist,
        quota=quota_for(cfg, spec),
        coverage_radius_m=coverage_radius(cfg), key=key, avail=avail,
        resolver=spec.resolver, return_sweeps=with_sweeps, seed=seed)


def _next_warm(spec: EngineSpec, assoc, assigned) -> Optional[jnp.ndarray]:
    """The warm seed the NEXT round's resolver starts from: this round's
    assigned vector (N,) int32, or None with warm-start off (the leaf —
    and every op deriving it — stays structurally absent)."""
    if not spec.warm_start:
        return None
    if assigned is not None:              # candidate path: already compact
        return assigned.astype(jnp.int32)
    sel = jnp.sum(assoc, axis=1) > 0
    return jnp.where(sel, jnp.argmax(assoc, axis=1).astype(jnp.int32),
                     jnp.asarray(-1, jnp.int32))


def _build_candidates(cfg, spec: EngineSpec, dist,
                      avail: Optional[jnp.ndarray],
                      edge_up: Optional[jnp.ndarray] = None
                      ) -> Optional[CandidateSet]:
    """The per-round (N, K) frontier, or None on the dense path.
    ``edge_up`` (fault-layer churn) invalidates dead edges while keeping
    the frontier's distances physical."""
    if spec.candidates_k is None:
        return None
    return candidates.build_candidates(
        dist, spec.candidates_k, coverage_radius_m=coverage_radius(cfg),
        avail=avail, edge_up=edge_up)


def _grid_allocate(cfg, spec: EngineSpec, assoc, gains, counts, dist,
                   scen: Optional[ScenarioState], fixed_axis: int
                   ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """The paper's FPA/FCA benchmarks (§V-D): one action axis pinned at
    its maximum, the other grid-optimised against the SAME Eq. 23a bill
    the engine charges — literally ``env.grid_best_action``, the one
    implementation the env baselines use, over the allocator-stage
    surface (z = 1; ``assoc`` is already availability-masked upstream)."""
    params = env.make_env_params(
        cfg, assoc, jnp.ones((cfg.n_edges,)), dist, counts,
        kappa=scen.kappa if scen is not None else None,
        p_max_w=scen.p_max_w if scen is not None else None,
        f_max_hz=scen.f_max_hz if scen is not None else None)
    a = env.grid_best_action(cfg, params, gains, fixed_axis=fixed_axis,
                             fixed_frac=1.0,
                             noma_enabled=spec.noma_enabled)
    return env.env_decode_action(cfg, params, a)


def _allocate(cfg, spec: EngineSpec, key, assoc, gains, counts,
              actor_params, scen: Optional[ScenarioState], dist,
              assigned: Optional[jnp.ndarray] = None
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """(p_w (N,), f_hz (N,)) per the configured allocator (§IV-C).
    ``dist`` (N, M) feeds the fpa/fca grid search's EnvParams; on the
    candidate path ``assigned`` (N,) lets the DDPG observation gather its
    own-edge gains instead of the (N, M) one-hot product."""
    n = cfg.n_clients
    mid_p = jnp.full((n,), 0.5 * (cfg.p_min_w + cfg.p_max_w))
    mid_f = jnp.full((n,), 0.5 * (cfg.f_min_hz + cfg.f_max_hz))
    if spec.allocator == "ddpg" and actor_params is not None:
        from repro.core import ddpg                 # cycle-free lazy import
        # in a dynamic scenario the observation gains an availability slice
        avail = None if scen is None else scen.avail
        if assigned is not None:
            obs = env.observe_assigned(
                assigned, candidates.own_edge_gather(assigned, gains),
                counts, avail=avail)
        else:
            obs = env.observe(assoc, gains, counts, avail=avail)
        act = ddpg.actor_apply(actor_params, obs)
        return env.decode_action(cfg, act, n)
    if spec.allocator == "rra":
        a = jax.random.uniform(key, (2, n))
        p = cfg.p_min_w + a[0] * (cfg.p_max_w - cfg.p_min_w)
        f = cfg.f_min_hz + a[1] * (cfg.f_max_hz - cfg.f_min_hz)
        return p, f
    if spec.allocator == "fpa":     # power pinned at p_max, f optimised
        return _grid_allocate(cfg, spec, assoc, gains, counts, dist, scen,
                              fixed_axis=0)
    if spec.allocator == "fca":     # frequency pinned at f_max, p optimised
        return _grid_allocate(cfg, spec, assoc, gains, counts, dist, scen,
                              fixed_axis=1)
    # "mid" (and ddpg before an agent exists): midpoint defaults
    return mid_p, mid_f


def associate_snapshot(cfg, spec: EngineSpec, state: RoundState,
                       bundle: RoundBundle) -> jnp.ndarray:
    """One-off (N, M) association on the CURRENT state, without advancing
    it: the same key slot and inputs ``round_step`` consumes, taken
    pre-transition (a dynamic ``round_step`` advances the scenario and
    fades the channel first, so its deployed association is one world
    step ahead of this snapshot).  THE single definition of the
    snapshot — the DDPG trainer's episode MDP and the wrapper's
    ``HFLSimulation._associate`` both read it, so the two consumers
    cannot drift from each other."""
    dynamic = spec.scenario != "static"
    scen = state.scenario
    dist = scen.dist if dynamic else bundle.dist
    avail = scen.avail if dynamic else None
    edge_up = (state.faults.edge_up
               if spec.faults is not None and state.faults is not None
               else None)
    cand = _build_candidates(cfg, spec, dist, avail, edge_up)
    if edge_up is not None and cand is None:
        # dense path: route around the CURRENT dead edges the same way the
        # round does (masked distance field)
        dist = fault_inject.masked_dist(dist, edge_up)
    out = _associate(cfg, spec, round_keys(spec, state.key)[3],
                     state.gains, dist, bundle.counts, state.staleness,
                     avail, cand, seed=state.warm)
    if cand is not None:      # compact assigned vector -> the (N, M) view
        out = candidates.assigned_one_hot(out, cfg.n_edges)
    return out


def _schedule_traced(cfg, spec: EngineSpec, rc_all: cost.RoundCost
                     ) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, ...]]:
    """Semi-synchronous edge-selection mask z (M,) from ONE cost eval,
    plus the scheduler internals ``(iterations, residual, z_relaxed)``
    the telemetry trace records (zeros / the final z for the "fastest"
    baseline).  The internals ride along for free — ``pdd_schedule``
    already returns the full ``PDDResult``, so a telemetry-off caller
    that keeps only z leaves them to dead-code elimination.

    The PDD problem must optimise EXACTLY the Eq. 23a surface the engine
    bills: its per-edge time is ``t_cloud + U_m`` with
    ``U_m = τ₂ · max_{n∈N_m} t_n`` — the τ₂-scaled edge-iteration time of
    Eq. 13, not one bare client iteration.  With that U, the PDD objective
    at its own z equals ``apply_schedule(cfg, rc_all, z).cost`` identically
    (the regression test in tests/test_pdd.py pins it).
    """
    quota = max(1, int(round(cfg.semi_sync_fraction * cfg.n_edges)))
    if spec.scheduler == "pdd":
        t_cloud = jnp.full((cfg.n_edges,),
                           cfg.edge_model_size_bits / cfg.edge_rate_bps)
        U = rc_all.per_edge_time_s - t_cloud
        res = pdd.pdd_schedule(rc_all.per_edge_energy_j, t_cloud, U,
                               lam_t=cfg.lambda_t, lam_e=cfg.lambda_e,
                               quota=quota)
        return res.z_binary, (res.iterations, res.residual, res.z)
    z = pdd.semi_sync_fastest(rc_all.per_edge_time_s, quota)
    return z, (jnp.asarray(0, jnp.int32), jnp.asarray(0.0, jnp.float32),
               z.astype(jnp.float32))


def _schedule(cfg, spec: EngineSpec, rc_all: cost.RoundCost) -> jnp.ndarray:
    """The z-only view of ``_schedule_traced``."""
    return _schedule_traced(cfg, spec, rc_all)[0]


def _train_cohort(cfg, spec: EngineSpec, model: MLPClassifier, key,
                  state: RoundState, bundle: RoundBundle, assoc
                  ) -> Tuple[Params, Params]:
    """τ₂ × (τ₁ local SGD + edge aggregation) as a lax.scan (Eqs. 11, 13)
    — the per-cohort training stage shared by the sync round (which cloud-
    aggregates the result, ``_train``) and the buffered micro-step (which
    buffers the cohort's per-client deltas instead, DESIGN.md §11).
    Returns ``(client_params, edge_params)``.

    At most ``quota · M`` clients are ever admitted (a static bound), so
    the whole stage runs COMPACT (DESIGN.md §13): the admitted clients
    are gathered ONCE into a fixed K = min(N, quota·M) lane buffer
    before the scan, every edge iteration trains/aggregates/broadcasts
    on the (K, …) stack — minibatch indices pre-drawn by the fold_in
    lattice, model updates as (K, B, D)-batched GEMMs per
    ``spec.train_impl`` — and the result scatters back ONCE after the
    scan.  Unadmitted clients keep their params (exactly the old dense
    semantics); per-iteration work is O(quota·M) with no O(N) key
    splits, gathers or aggregation einsums left inside the scan.
    """
    counts = bundle.counts
    n = cfg.n_clients
    k_sel = min(n, quota_for(cfg, spec) * cfg.n_edges)
    selected = jnp.sum(assoc, axis=1) > 0

    # admitted-lane selection, hoisted OUT of the scan (it only depends on
    # ``assoc``): indices padded with n (dropped on the final scatter),
    # clamped for the gathers.  Pad lanes repeat client n−1's data and
    # draws — they train garbage that carries ZERO aggregation weight
    # (``lane_ok``) and never scatters back.
    sel_idx = jnp.nonzero(selected, size=k_sel, fill_value=n)[0]
    safe = jnp.minimum(sel_idx, n - 1)
    lane_ok = (sel_idx < n).astype(assoc.dtype)                # (K,)
    sel_x, sel_y = bundle.x[safe], bundle.y[safe]
    sel_counts = counts[safe]
    sel_assoc = assoc[safe] * lane_ok[:, None]                 # (K, M)

    # every τ₂·τ₁ minibatch of the round from ONE batched PRNG draw
    idx = _batch_index_lattice(key, cfg.tau2, cfg.tau1, safe, sel_counts,
                               cfg.local_batch)
    fit = _cohort_fit(model, cfg.lr, _train_impl_for(spec))

    # admitted lanes start from the global model
    edge_params = aggregation.replicate(state.global_params, cfg.n_edges)
    lane_params = jax.tree.map(lambda l: l[safe], state.client_params)
    lane_params = aggregation.broadcast_to_clients(
        None, sel_assoc, edge_params, lane_params)

    def edge_iter(carry, idx_t):
        lane_p, _ = carry
        lane_p = fit(lane_p, sel_x, sel_y, idx_t)
        edge_p = aggregation.edge_aggregate(lane_p, sel_assoc, sel_counts)
        lane_p = aggregation.broadcast_to_clients(None, sel_assoc, edge_p,
                                                  lane_p)
        return (lane_p, edge_p), None

    (lane_params, edge_params), _ = jax.lax.scan(
        edge_iter, (lane_params, edge_params), idx)
    # pad lanes target index n -> dropped; real lanes overwrite
    client_params = jax.tree.map(
        lambda old, new: old.at[sel_idx].set(new, mode="drop"),
        state.client_params, lane_params)
    return client_params, edge_params


def _train(cfg, spec: EngineSpec, model: MLPClassifier, key,
           state: RoundState, bundle: RoundBundle, assoc, z
           ) -> Tuple[Params, Params]:
    """``_train_cohort`` followed by the semi-synchronous cloud
    aggregation (Eq. 17) — the sync engine's training stage."""
    client_params, edge_params = _train_cohort(cfg, spec, model, key,
                                               state, bundle, assoc)
    counts = bundle.counts
    edge_data = jnp.sum(assoc * counts[:, None], axis=0)      # (M,)
    z_eff = z * (edge_data > 0).astype(z.dtype)
    agg = aggregation.cloud_aggregate(edge_params, z_eff, edge_data)
    # keep the old global model when no selected edge has data (branchless
    # version of the eager `if` — Eq. 17 degenerate case)
    has_data = jnp.sum(z_eff * edge_data) > 0
    global_params = jax.tree.map(
        lambda a, g: jnp.where(has_data, a, g), agg, state.global_params)
    return global_params, client_params


def _train_faulty(cfg, spec: EngineSpec, model: MLPClassifier, key,
                  state: RoundState, bundle: RoundBundle, assoc, z, gains,
                  edge_up, k_crash, k_loss, k_poison
                  ) -> Tuple[Params, Params, Tuple[jnp.ndarray, ...]]:
    """The sync training stage under faults (DESIGN.md §12.2).

    Training is unchanged (``_train_cohort``), but the cloud epilogue
    moves to DELTA space: each selected client's update is its trained
    model minus the global it pulled.  The transmitted copy then runs the
    fault gauntlet — mid-round crash (compute billed, delta lost),
    SINR-tied uplink loss (sync has no buffer to retry from: a lost
    upload is simply dropped this round), optional poisoning, and the
    quarantine guard — and only the surviving, guard-cleaned deltas reach
    ``faulted_cloud_aggregate``.  Client LOCAL params are never poisoned:
    poisoning models a corrupted transmission, not corrupted training.

    Returns ``(global', client_params, (ok, crashed, lost, n_rej))`` —
    ``ok`` is the surviving-client mask the staleness update consumes.
    """
    fsp = spec.faults
    client_params, _ = _train_cohort(cfg, spec, model, key, state, bundle,
                                     assoc)
    selected = jnp.sum(assoc, axis=1) > 0
    crashed = fault_inject.draw_crashes(fsp, k_crash, selected)
    lost = fault_inject.draw_losses(fsp, k_loss, gains, edge_up,
                                    selected & ~crashed)
    delivered = selected & ~crashed & ~lost
    deltas = jax.tree.map(lambda c, g: c - g[None], client_params,
                          state.global_params)
    deltas, _ = fault_inject.poison_deltas(fsp, k_poison, deltas, delivered)
    clean, ok, n_rej = fault_guard.quarantine(deltas, delivered,
                                              fsp.quarantine_clip)
    assoc_eff = assoc * ok.astype(assoc.dtype)[:, None]
    global_params = aggregation.faulted_cloud_aggregate(
        state.global_params, clean, assoc_eff, bundle.counts, z)
    return global_params, client_params, (ok, crashed, lost, n_rej)


# ---------------------------------------------------------------------------
# The round step + compiled drivers
# ---------------------------------------------------------------------------

def round_keys(spec: EngineSpec, key) -> Tuple[jnp.ndarray, ...]:
    """THE round's PRNG layout: (carry, scenario?, fade, assoc, alloc, train).

    The scenario key exists only on dynamic paths — the static path keeps
    the PR-1 5-way split bit-for-bit (golden parity depends on it).  Both
    ``round_step`` and the wrapper's association snapshot derive their keys
    from here, so the layout lives in exactly one place.
    """
    if spec.scenario != "static":
        return jax.random.split(key, 6)
    key, k_fade, k_assoc, k_alloc, k_train = jax.random.split(key, 5)
    return key, None, k_fade, k_assoc, k_alloc, k_train


def _buffered_step(cfg, spec: EngineSpec, state: RoundState,
                   bundle: RoundBundle,
                   actor_params: Optional[Params] = None
                   ) -> Tuple[RoundState, RoundMetrics]:
    """One buffered MICRO-step (DESIGN.md §11) — the semi-async engine's
    scan body.  Same shape contract as the sync ``round_step``: it returns
    ``(state', RoundMetrics)`` (or the telemetry pair), but the step
    semantics are event-driven:

    1. gate the market to the idle clients of the current TiFL speed tier
       and run the UNCHANGED fuzzy/candidate/association/allocation
       pipeline on that cohort;
    2. train the admitted cohort (``_train_cohort``) and park its
       per-client model deltas as in-flight with Eq. 13/15 virtual finish
       times;
    3. advance the virtual clock to the next completion event (or the
       timeout deadline), land every finished update in the FedBuff
       buffer with staleness weight w(a)=a^{-1/2} · D_n;
    4. fire the cloud merge when the buffer holds ``buffer_fill`` updates
       OR ``timeout_s`` elapsed since the last merge;
    5. every ``retier_every`` micro-steps, recompute quantile speed tiers
       from the per-client duration EMA (TiFL).

    ``metrics.total_time_s`` is the virtual-clock advance dt (not a
    barrier max), ``metrics.z`` broadcasts the trigger bit, and
    ``metrics.round`` counts micro-steps.
    """
    model = MLPClassifier(cfg.input_dim, cfg.hidden, cfg.n_classes)
    buf: BufferState = state.buffer
    n = cfg.n_clients
    f32, i32 = jnp.float32, jnp.int32
    n_tiers = max(1, int(spec.n_tiers))

    # 0. scenario transition + fading — identical preamble to the sync
    #    round (same round_keys layout, so the per-step PRNG stream is
    #    comparable across engines).
    dynamic = spec.scenario != "static"
    key, k_scen, k_fade, k_assoc, k_alloc, k_train = round_keys(spec,
                                                                state.key)
    if dynamic:
        scen = scenarios.advance(cfg, spec.scenario, k_scen, state.scenario)
        dist, avail = scen.dist, scen.avail
    else:
        scen = state.scenario
        dist, avail = bundle.dist, jnp.ones((n,), f32)
    gains = noma.evolve_gains(k_fade, state.gains, dist,
                              path_loss_exponent=cfg.path_loss_exponent,
                              rho=spec.fading_rho)

    # 0b. fault layer (DESIGN.md §12): the fault stream folds off the fade
    #     key (the no-fault PRNG layout is untouched); edge churn advances
    #     the live-edge mask, and the ASSOCIATION view of the distance
    #     field pushes dead edges out of coverage so the unchanged
    #     pipeline routes the orphaned clients to the survivors.
    fsp = spec.faults
    if fsp is not None:
        k_edge, k_loss, k_crash, k_poison = jax.random.split(
            fault_inject.fault_key(k_fade), 4)
        edge_up = fault_inject.advance_edges(fsp, k_edge,
                                             state.faults.edge_up)
        dist_assoc = fault_inject.masked_dist(dist, edge_up)
    else:
        edge_up = None
        dist_assoc = dist

    # 1. TiFL cohort gate: only idle clients of the scheduled tier enter
    #    the association market this micro-step, so every cohort is
    #    speed-coherent and the buffer drains in waves instead of one
    #    straggler-paced front.
    cur_tier = jnp.mod(buf.step, n_tiers)
    eligible = ((~buf.in_flight) & (buf.tier == cur_tier)).astype(f32) \
        * avail
    with _stage("associate"):
        cand = _build_candidates(cfg, spec, dist, eligible, edge_up)
        sweeps = None
        if cand is not None:
            out = _associate(cfg, spec, k_assoc, gains, dist,
                             bundle.counts, state.staleness, eligible,
                             cand, with_sweeps=spec.telemetry,
                             seed=state.warm)
            assigned = out
            if spec.telemetry:
                assigned, sweeps = out
            assoc = candidates.assigned_one_hot(
                assigned, cfg.n_edges).astype(f32)
        else:
            assigned = None
            assoc = _associate(cfg, spec, k_assoc, gains, dist_assoc,
                               bundle.counts, state.staleness, eligible,
                               with_sweeps=spec.telemetry, seed=state.warm)
            if spec.telemetry:
                assoc, sweeps = assoc
            assoc = assoc.astype(f32) * eligible[:, None]
    new_warm = _next_warm(spec, assoc, assigned)
    with _stage("allocate"):
        p, f = _allocate(cfg, spec, k_alloc, assoc, gains, bundle.counts,
                         actor_params, scen if dynamic else None, dist,
                         assigned)
        if dynamic:
            p = jnp.minimum(p, scen.p_max_w)
            f = jnp.minimum(f, scen.f_max_hz)

    # 2. per-client Eq. 13/15 surface at z=1 — the buffered engine never
    #    schedules edges (no barrier to prune); it reads the per-client
    #    time/energy columns for finish times and the cohort bill.
    with _stage("schedule"):
        rc_all = cost.round_cost(cfg, power_w=p, f_hz=f, gains=gains,
                                 assoc=assoc, z=jnp.ones((cfg.n_edges,)),
                                 n_samples=bundle.counts,
                                 noma_enabled=spec.noma_enabled,
                                 capacitance=scen.kappa if dynamic else None,
                                 sic_impl=spec.sic_impl,
                                 sic_max_per_edge=quota_for(cfg, spec),
                                 assigned=assigned)
    admitted = jnp.sum(assoc, axis=1) > 0                    # (N,) bool
    if fsp is not None:
        # mid-round crash: the cohort bill still charges the admitted
        # client (the energy was spent) but its update never takes flight.
        crashed = fault_inject.draw_crashes(fsp, k_crash, admitted)
        flying = admitted & ~crashed
    else:
        crashed = None
        flying = admitted

    # 3. train the cohort from the CURRENT global model and park its
    #    deltas in flight.  The admitted client's update is its trained
    #    edge model minus the global it pulled (anchored NOW, while the
    #    pull version is current) — it lands in the buffer later, at its
    #    virtual finish time, possibly several merges stale.
    with _stage("train"):
        client_params, _ = _train_cohort(cfg, spec, model, k_train, state,
                                         bundle, assoc)

    def _mask(m, leaf):
        return m.reshape((-1,) + (1,) * (leaf.ndim - 1))

    pending = jax.tree.map(
        lambda pd, c, g: jnp.where(_mask(flying, c), c - g[None], pd),
        buf.pending_delta, client_params, state.global_params)
    if fsp is not None:
        # poisoning corrupts the TRANSMITTED copy (the in-flight delta),
        # never the client's local params; a new attempt resets the
        # upload's retry ledger.
        pending, _ = fault_inject.poison_deltas(fsp, k_poison, pending,
                                                flying)
        attempts0 = jnp.where(flying, 0, state.faults.attempts)
    # modelled wall duration: τ₂ edge iterations + the edge→cloud hop
    dur = cfg.tau2 * rc_all.client_time_s \
        + cfg.edge_model_size_bits / cfg.edge_rate_bps
    finish = jnp.where(flying, buf.clock_s + dur, buf.finish_s)
    in_flight = buf.in_flight | flying
    pulled = jnp.where(flying, buf.version, buf.pulled_ver)
    obs = jnp.where(flying,
                    jnp.where(buf.obs_s > 0.0,
                              0.5 * buf.obs_s + 0.5 * dur, dur),
                    buf.obs_s)

    # 4. event-driven clock: jump to the earliest in-flight completion or
    #    the timeout deadline, whichever is sooner (never backwards).
    inf = jnp.asarray(jnp.finfo(jnp.float32).max, f32)
    next_fin = jnp.min(jnp.where(in_flight, finish, inf))
    deadline = buf.last_agg_s + jnp.asarray(spec.timeout_s, f32)
    target = jnp.where(jnp.any(in_flight),
                       jnp.minimum(next_fin, deadline), deadline)
    clock = jnp.maximum(buf.clock_s, target)
    dt = clock - buf.clock_s

    # 5. land every completed update with its staleness weight
    eps = jnp.asarray(1e-5, f32)
    landed = in_flight & (finish <= clock + eps)
    if fsp is not None:
        # 5b. uplink loss + retry/backoff (DESIGN.md §12.2): a completed
        #     upload is lost with its SINR-tied probability; a lost upload
        #     with attempts left re-enters flight at an exponentially
        #     backed-off finish time, otherwise it is dropped and counted.
        #     Delivered updates then pass the quarantine guard, and ONLY
        #     the guard-cleaned tree reaches the accumulator (the raw
        #     pending delta stays in the carry for any retry to re-send).
        landed_raw = landed
        lost = fault_inject.draw_losses(fsp, k_loss, gains, edge_up,
                                        landed_raw)
        can_retry = lost & (attempts0 < int(fsp.max_attempts))
        dropped = lost & ~can_retry
        delivered = landed_raw & ~lost
        finish = jnp.where(can_retry,
                           clock + fault_inject.backoff_s(fsp, attempts0),
                           finish)
        attempts = jnp.where(can_retry, attempts0 + 1, attempts0)
        clean, okd, n_rej = fault_guard.quarantine(
            pending, delivered, fsp.quarantine_clip)
        landed = okd
        land_tree = clean
    else:
        land_tree = pending
    age = staleness.buffer_age(buf.version, pulled)
    w = jnp.where(landed,
                  staleness.buffer_weight(age) * bundle.counts, 0.0)
    delta_sum, weight_sum = aggregation.buffer_accumulate(
        buf.delta_sum, buf.weight_sum, land_tree, w)
    fill = buf.fill + jnp.sum(landed, dtype=i32)
    if fsp is not None:
        in_flight = (in_flight & ~landed_raw) | can_retry
    else:
        in_flight = in_flight & ~landed

    # 6. fill-or-timeout trigger → staleness-weighted buffered merge.
    #    ``applied`` (merge actually changed the model) gates the version
    #    bump and the cloud-hop energy; ``fired`` alone resets the timer,
    #    so an empty timeout does not freeze the clock.  Under faults the
    #    merge additionally waits for ``min_participation`` buffered
    #    updates (a churn-starved buffer keeps accumulating across timeout
    #    resets); at the default 1 the guard is value-identical to the
    #    guard-less trigger (fill == 0 ⇒ the buffer is empty).
    fill_target = buffer_fill_for(cfg, spec)
    timed_out = clock >= deadline - eps
    fired = (fill >= fill_target) | timed_out
    if fsp is not None:
        do_merge = fired & (fill >= max(1, int(fsp.min_participation)))
    else:
        do_merge = fired
    applied = do_merge & (weight_sum > 0.0)
    global_params = aggregation.buffer_apply(
        state.global_params, delta_sum, weight_sum, spec.buffer_lr,
        do_merge)
    delta_sum = jax.tree.map(
        lambda d: jnp.where(do_merge, jnp.zeros_like(d), d), delta_sum)
    weight_sum = jnp.where(do_merge, 0.0, weight_sum)
    fill_after = jnp.where(do_merge, 0, fill)
    version = buf.version + applied.astype(i32)
    last_agg = jnp.where(fired, clock, buf.last_agg_s)

    # 7. TiFL retier cadence: quantile tiers over the duration EMA
    #    (rank · n_tiers // N ∈ [0, n_tiers)); unmeasured clients sort
    #    first, i.e. optimistically fast.
    step1 = buf.step + 1
    do_retier = jnp.mod(step1, max(1, int(spec.retier_every))) == 0
    rank = jnp.argsort(jnp.argsort(obs))
    tier = jnp.where(do_retier,
                     ((rank * n_tiers) // n).astype(i32), buf.tier)

    # 8. Eq. 20 per micro-step: landing in the buffer is this engine's
    #    "orchestrated" event — landed clients reset to 1, everyone else
    #    saturating-increments, so a drained client re-enters fresh.
    new_stale = staleness.update_staleness(state.staleness, landed)

    rc = cost.cohort_cost(cfg, rc_all, admitted, dt, applied)
    round_idx = state.round_idx + 1
    with _stage("eval"):
        accuracy = model.accuracy(global_params, bundle.test_x,
                                  bundle.test_y)
        loss = model.loss(global_params, (bundle.test_x, bundle.test_y))
    metrics = RoundMetrics(
        round=round_idx,
        accuracy=accuracy,
        loss=loss,
        avg_staleness=jnp.mean(new_stale.astype(f32)),
        total_time_s=dt,
        total_energy_j=rc.total_energy_j,
        cost=rc.cost,
        n_associated=jnp.sum(admitted.astype(i32)),
        n_available=jnp.sum((eligible > 0).astype(i32)),
        z=applied.astype(f32) * jnp.ones((cfg.n_edges,)))
    new_buf = BufferState(
        pending_delta=pending, finish_s=finish, in_flight=in_flight,
        pulled_ver=pulled, obs_s=obs, tier=tier, delta_sum=delta_sum,
        weight_sum=weight_sum, fill=fill_after, version=version,
        clock_s=clock, last_agg_s=last_agg, step=step1)
    new_faults = None
    fault_tr = None
    if fsp is not None:
        flt: FaultState = state.faults
        n_retry = jnp.sum(can_retry, dtype=i32)
        n_drop = jnp.sum(dropped, dtype=i32) + jnp.sum(crashed, dtype=i32)
        n_crash = jnp.sum(crashed, dtype=i32)
        new_faults = FaultState(
            edge_up=edge_up, attempts=attempts,
            n_retries=flt.n_retries + n_retry,
            n_dropped=flt.n_dropped + n_drop,
            n_quarantined=flt.n_quarantined + n_rej,
            n_crashed=flt.n_crashed + n_crash)
        fault_tr = (jnp.sum((edge_up <= 0).astype(i32)),
                    fault_inject.orphan_count(dist, edge_up,
                                              coverage_radius(cfg), avail),
                    n_retry, n_drop, n_rej)
    new_state = RoundState(global_params, client_params, gains, new_stale,
                           key, round_idx, scen, new_buf, new_faults,
                           new_warm)
    if spec.telemetry:
        cause = jnp.where(fired,
                          jnp.where(fill >= fill_target, 1, 2),
                          0).astype(i32)
        tr = telemetry.round_trace(
            cfg, spec, round_idx=round_idx, rc_all=rc_all,
            z=metrics.z, assoc=assoc, power_w=p, f_hz=f,
            counts=bundle.counts, staleness=new_stale,
            capacitance=scen.kappa if dynamic else None,
            sweeps=sweeps, sched=None, cand=cand, assigned=assigned,
            dist=dist, avail=avail if dynamic else None,
            coverage_radius_m=coverage_radius(cfg),
            buffer=(fill, cause, cur_tier,
                    jnp.sum((eligible > 0).astype(i32))),
            faults=fault_tr)
        return new_state, (metrics, tr)
    return new_state, metrics


def round_step(cfg, spec: EngineSpec, state: RoundState,
               bundle: RoundBundle, actor_params: Optional[Params] = None
               ) -> Tuple[RoundState, RoundMetrics]:
    """One pure global round; jit/scan/vmap to taste.

    Returns ``(state', RoundMetrics)`` — or, with ``spec.telemetry``,
    ``(state', (RoundMetrics, telemetry.RoundTrace))``; ``split_output``
    normalises the two shapes for generic callers.

    With ``spec.engine_mode="buffered"`` the step is a semi-async
    MICRO-step (``_buffered_step``); "sync" (the default) is the paper's
    semi-synchronous barrier round, bit-for-bit the pre-buffer program
    (``ensure_carry`` keeps the buffer and fault state structurally
    absent)."""
    state = ensure_carry(cfg, spec, state)
    if spec.engine_mode == "buffered":
        return _buffered_step(cfg, spec, state, bundle, actor_params)
    model = MLPClassifier(cfg.input_dim, cfg.hidden, cfg.n_classes)

    # 0. scenario transition (DESIGN.md §6).  The static kind keeps the
    #    PR-1 key-split and data flow bit-for-bit (no scenario key is
    #    consumed, distances come from the bundle) — the parity tests
    #    pin this against golden trajectories.
    dynamic = spec.scenario != "static"
    key, k_scen, k_fade, k_assoc, k_alloc, k_train = round_keys(spec,
                                                                state.key)
    if dynamic:
        scen = scenarios.advance(cfg, spec.scenario, k_scen, state.scenario)
        dist, avail = scen.dist, scen.avail
    else:
        scen = state.scenario
        dist, avail = bundle.dist, None

    # 1. channel fading (distances may have just moved)
    gains = noma.evolve_gains(k_fade, state.gains, dist,
                              path_loss_exponent=cfg.path_loss_exponent,
                              rho=spec.fading_rho)
    # 1b. fault layer (DESIGN.md §12): fold the fault stream off the fade
    #     key (no split consumed from the round layout), advance the edge
    #     churn, and push dead edges out of the ASSOCIATION view of the
    #     distance field — the unchanged pipeline routes their orphaned
    #     clients to the surviving frontier.  Gains, allocation and the
    #     Eq. 23a bill keep the PHYSICAL distances.
    fsp = spec.faults
    if fsp is not None:
        k_edge, k_loss, k_crash, k_poison = jax.random.split(
            fault_inject.fault_key(k_fade), 4)
        edge_up = fault_inject.advance_edges(fsp, k_edge,
                                             state.faults.edge_up)
        dist_assoc = fault_inject.masked_dist(dist, edge_up)
    else:
        edge_up = None
        dist_assoc = dist
    # 2. fuzzy scoring + association (pure JAX — no host loop);
    #    unavailable clients are out of coverage this round.  With
    #    ``spec.candidates_k`` set, the (N, K) frontier is built once here
    #    and scoring/resolution/billing all run on it (DESIGN.md §9);
    #    the (N, M) one-hot is reconstructed only for the training/
    #    aggregation stage's cheap masked reductions.
    sweeps = None
    with _stage("associate"):
        cand = _build_candidates(cfg, spec, dist, avail, edge_up)
        if cand is not None:
            out = _associate(cfg, spec, k_assoc, gains, dist,
                             bundle.counts, state.staleness, avail, cand,
                             with_sweeps=spec.telemetry, seed=state.warm)
            assigned = out
            if spec.telemetry:
                assigned, sweeps = out
            assoc = candidates.assigned_one_hot(
                assigned, cfg.n_edges).astype(jnp.float32)
            # ``cand.valid`` already excludes dropped clients — no avail mask
        else:
            assigned = None
            assoc = _associate(cfg, spec, k_assoc, gains, dist_assoc,
                               bundle.counts, state.staleness, avail,
                               with_sweeps=spec.telemetry, seed=state.warm)
            if spec.telemetry:
                assoc, sweeps = assoc
            assoc = assoc.astype(jnp.float32)
            if dynamic:
                # explicit Eq. 11/17/23a mask: even a policy that ignored
                # ``avail`` cannot train on, aggregate or bill a dropped
                # client
                assoc = assoc * avail[:, None]
    new_warm = _next_warm(spec, assoc, assigned)
    # 3. resource allocation, clamped to the device class caps
    with _stage("allocate"):
        p, f = _allocate(cfg, spec, k_alloc, assoc, gains, bundle.counts,
                         actor_params, scen if dynamic else None, dist,
                         assigned)
        if dynamic:
            p = jnp.minimum(p, scen.p_max_w)
            f = jnp.minimum(f, scen.f_max_hz)
    # 4. ONE cost evaluation at z=1, reused by the scheduler and the final
    #    masked round cost (Eqs. 18-19 depend on z only through a mask)
    with _stage("schedule"):
        rc_all = cost.round_cost(cfg, power_w=p, f_hz=f, gains=gains,
                                 assoc=assoc, z=jnp.ones((cfg.n_edges,)),
                                 n_samples=bundle.counts,
                                 noma_enabled=spec.noma_enabled,
                                 capacitance=scen.kappa if dynamic else None,
                                 sic_impl=spec.sic_impl,
                                 sic_max_per_edge=quota_for(cfg, spec),
                                 assigned=assigned)
        if spec.telemetry:
            z, sched = _schedule_traced(cfg, spec, rc_all)
        else:
            z = _schedule(cfg, spec, rc_all)
        if fsp is not None:
            # a dead edge cannot be scheduled: association already routed
            # around it, this removes it from the Eq. 18/19 bill too
            z = z * (edge_up > 0).astype(z.dtype)
        rc = cost.apply_schedule(cfg, rc_all, z)
    # 5. τ₂·τ₁ training + hierarchical aggregation
    with _stage("train"):
        if fsp is not None:
            global_params, client_params, fev = _train_faulty(
                cfg, spec, model, k_train, state, bundle, assoc, z, gains,
                edge_up, k_crash, k_loss, k_poison)
            ok_clients, crashed, lost, n_rej = fev
        else:
            global_params, client_params = _train(cfg, spec, model,
                                                  k_train, state, bundle,
                                                  assoc, z)
    # 6. staleness (Eq. 20): reset only for clients whose edge was selected
    #    (and, under faults, whose update actually survived to aggregation)
    selected = jnp.sum(assoc, axis=1) > 0
    orchestrated = ok_clients if fsp is not None else selected
    effective = orchestrated & (z > 0)[jnp.argmax(assoc, axis=1)]
    new_stale = staleness.update_staleness(state.staleness, effective)

    round_idx = state.round_idx + 1
    n_avail = (jnp.sum(avail > 0, dtype=jnp.int32) if dynamic
               else jnp.asarray(cfg.n_clients, jnp.int32))
    with _stage("eval"):
        accuracy = model.accuracy(global_params, bundle.test_x,
                                  bundle.test_y)
        loss = model.loss(global_params, (bundle.test_x, bundle.test_y))
    metrics = RoundMetrics(
        round=round_idx,
        accuracy=accuracy,
        loss=loss,
        avg_staleness=jnp.mean(new_stale.astype(jnp.float32)),
        total_time_s=rc.total_time_s,
        total_energy_j=rc.total_energy_j,
        cost=rc.cost,
        n_associated=jnp.sum(selected.astype(jnp.int32)),
        n_available=n_avail,
        z=z)
    new_faults = None
    fault_tr = None
    if fsp is not None:
        flt: FaultState = state.faults
        i32 = jnp.int32
        n_drop = (jnp.sum(lost, dtype=i32)
                  + jnp.sum(crashed, dtype=i32))
        new_faults = FaultState(
            edge_up=edge_up, attempts=flt.attempts,
            n_retries=flt.n_retries,      # sync has no buffer to retry from
            n_dropped=flt.n_dropped + n_drop,
            n_quarantined=flt.n_quarantined + n_rej,
            n_crashed=flt.n_crashed + jnp.sum(crashed, dtype=i32))
        fault_tr = (jnp.sum((edge_up <= 0).astype(i32)),
                    fault_inject.orphan_count(dist, edge_up,
                                              coverage_radius(cfg), avail),
                    jnp.zeros((), i32), n_drop, n_rej)
    new_state = RoundState(global_params, client_params, gains, new_stale,
                           key, round_idx, scen, None, new_faults, new_warm)
    if spec.telemetry:
        tr = telemetry.round_trace(
            cfg, spec, round_idx=round_idx, rc_all=rc_all, z=z,
            assoc=assoc, power_w=p, f_hz=f, counts=bundle.counts,
            staleness=new_stale,
            capacitance=scen.kappa if dynamic else None,
            sweeps=sweeps, sched=sched, cand=cand, assigned=assigned,
            dist=dist, avail=avail,
            coverage_radius_m=coverage_radius(cfg), faults=fault_tr)
        return new_state, (metrics, tr)
    return new_state, metrics


round_step_jit = jax.jit(round_step, static_argnums=(0, 1))


def _scan_rounds(cfg, spec, state, bundle, n_rounds, actor_params):
    # normalise the carry BEFORE the scan so its pytree structure is
    # fixed: buffered runs enter with the aggregation buffer attached,
    # faulted runs with the fault state attached, everything else with
    # both structurally absent (a no-op on a plain sync state — golden
    # programs are untouched).
    state = ensure_carry(cfg, spec, state)

    def step(s, _):
        return round_step(cfg, spec, s, bundle, actor_params)

    return jax.lax.scan(step, state, None, length=n_rounds)


@functools.partial(jax.jit, static_argnums=(0, 1, 4))
def run_scanned(cfg, spec: EngineSpec, state: RoundState,
                bundle: RoundBundle, n_rounds: int,
                actor_params: Optional[Params] = None
                ) -> Tuple[RoundState, RoundMetrics]:
    """A whole experiment as ONE XLA program: ``lax.scan`` over rounds.
    Returned metrics leaves have a leading (n_rounds,) axis (with
    ``spec.telemetry`` the per-round output is the (metrics, trace) pair
    — see ``split_output``)."""
    return _scan_rounds(cfg, spec, state, bundle, n_rounds, actor_params)


@functools.partial(jax.jit, static_argnums=(0, 1, 4))
def run_fleet(cfg, spec: EngineSpec, states: RoundState,
              bundles: RoundBundle, n_rounds: int,
              actor_params: Optional[Params] = None
              ) -> Tuple[RoundState, RoundMetrics]:
    """``vmap`` of the scanned driver over a fleet of independent
    simulations (stacked states/bundles from ``stack_fleet``).  Metrics
    leaves gain a leading (n_seeds, n_rounds, ...) shape."""
    return jax.vmap(
        lambda s, b: _scan_rounds(cfg, spec, s, b, n_rounds, actor_params)
    )(states, bundles)


@functools.partial(jax.jit, static_argnums=(0, 1, 4))
def run_fleet_actors(cfg, spec: EngineSpec, states: RoundState,
                     bundles: RoundBundle, n_rounds: int,
                     actor_params: Params
                     ) -> Tuple[RoundState, RoundMetrics]:
    """``run_fleet`` with a PER-SIMULATION actor: ``actor_params`` leaves
    carry a leading fleet axis (one trained actor per stacked cell), so a
    sweep can bill every ddpg cell with the actor trained on ITS OWN
    world while still running the whole group as one vmapped program."""
    return jax.vmap(
        lambda s, b, a: _scan_rounds(cfg, spec, s, b, n_rounds, a)
    )(states, bundles, actor_params)


# ---------------------------------------------------------------------------
# Fleet-axis sharding (DESIGN.md §8.3): the stacked simulations of a fleet
# are embarrassingly parallel, so a 1-D device mesh over the LEADING fleet
# axis scales `run_fleet` across devices with zero cross-device collectives
# (GSPMD partitions the vmap; every lane's program is untouched).
# ---------------------------------------------------------------------------

def fleet_mesh(devices=None) -> "jax.sharding.Mesh":
    """1-D ``("fleet",)`` mesh over ``devices`` (default: all of them).
    On CPU, spawn placeholder devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=K`` *before* jax
    imports (see tests/test_fleet_sharding.py)."""
    devices = jax.devices() if devices is None else list(devices)
    return jax.sharding.Mesh(np.asarray(devices), ("fleet",))


def shard_fleet(tree, mesh: "jax.sharding.Mesh"):
    """Place a stacked pytree with its leading axis split over the mesh."""
    sharding = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("fleet"))
    return jax.device_put(tree, sharding)


def run_fleet_sharded(cfg, spec: EngineSpec, states: RoundState,
                      bundles: RoundBundle, n_rounds: int,
                      actor_params: Optional[Params] = None, *,
                      mesh: "jax.sharding.Mesh | None" = None,
                      per_sim_actors: bool = False
                      ) -> Tuple[RoundState, RoundMetrics]:
    """``run_fleet`` (or ``run_fleet_actors`` when ``per_sim_actors``)
    with the fleet axis sharded over ``mesh`` (default: all devices).

    A fleet whose size is not a multiple of the device count is padded by
    replicating the last simulation (the pad lanes compute and are then
    sliced off — wasted work only on the ragged remainder).  Per-lane
    results are identical to the unsharded drivers: partitioning an
    embarrassingly-parallel vmap axis changes placement, not math
    (asserted by the multi-device parity test)."""
    mesh = fleet_mesh() if mesh is None else mesh
    n_dev = int(mesh.devices.size)
    fleet = jax.tree.leaves(states)[0].shape[0]
    pad = (-fleet) % n_dev

    def _pad(leaf):
        reps = jnp.repeat(leaf[-1:], pad, axis=0)
        return jnp.concatenate([leaf, reps], axis=0)

    if pad:
        states = jax.tree.map(_pad, states)
        bundles = jax.tree.map(_pad, bundles)
        if per_sim_actors:
            actor_params = jax.tree.map(_pad, actor_params)
    states, bundles = shard_fleet((states, bundles), mesh)
    if per_sim_actors:
        actor_params = shard_fleet(actor_params, mesh)
        out, ms = run_fleet_actors(cfg, spec, states, bundles, n_rounds,
                                   actor_params)
    else:
        out, ms = run_fleet(cfg, spec, states, bundles, n_rounds,
                            actor_params)
    if pad:
        out = jax.tree.map(lambda l: l[:fleet], out)
        ms = jax.tree.map(lambda l: l[:fleet], ms)
    return out, ms


# ---------------------------------------------------------------------------
# Client-axis sharding (DESIGN.md §9.3): split N over a 1-D ("clients",)
# mesh for N ≫ 10⁴ single-simulation scale.  Unlike the fleet axis, the
# client axis is NOT embarrassingly parallel — association, aggregation and
# the Eq. 23 bill all reduce over clients — but on the candidate layout
# every per-client stage (candidate build, fuzzy frontier scoring, local
# SGD, the resolver's elementwise sweep work) is row-local over N, and the
# cross-client terms are exactly the per-edge/global reductions GSPMD
# lowers to collectives of (M,)- or scalar-sized partials.  We device_put
# the N-leading leaves P("clients") and let GSPMD partition the jitted
# round program; nothing in round_step needs to change.
# ---------------------------------------------------------------------------

def client_mesh(devices=None) -> "jax.sharding.Mesh":
    """1-D ``("clients",)`` mesh over ``devices`` (default: all of them).
    On CPU, spawn placeholder devices with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=K`` *before* jax
    imports (see tests/test_client_sharding.py)."""
    devices = jax.devices() if devices is None else list(devices)
    return jax.sharding.Mesh(np.asarray(devices), ("clients",))


def _client_shardings(state: RoundState, bundle: RoundBundle,
                      mesh: "jax.sharding.Mesh"):
    """Per-leaf placement: N-leading leaves split over ``("clients",)``,
    everything else (global model, PRNG key, edge positions, test set)
    replicated."""
    P = jax.sharding.PartitionSpec
    cl = jax.sharding.NamedSharding(mesh, P("clients"))
    rep = jax.sharding.NamedSharding(mesh, P())
    scen_sh = ScenarioState(
        pos=cl, waypoint=cl, speed=cl, avail=cl, p_drop=cl, p_return=cl,
        f_max_hz=cl, p_max_w=cl, kappa=cl, edges=rep, dist=cl)
    buf_sh = None
    if state.buffer is not None:
        buf: BufferState = state.buffer
        # per-client leaves split over ("clients",); the global-shaped
        # delta accumulator and the scalar trigger state replicated —
        # exactly the global-model layout, so the buffered merge lowers
        # to the same all-reduce shape as the sync cloud aggregation.
        buf_sh = BufferState(
            pending_delta=jax.tree.map(lambda _: cl, buf.pending_delta),
            finish_s=cl, in_flight=cl, pulled_ver=cl, obs_s=cl, tier=cl,
            delta_sum=jax.tree.map(lambda _: rep, buf.delta_sum),
            weight_sum=rep, fill=rep, version=rep, clock_s=rep,
            last_agg_s=rep, step=rep)
    flt_sh = None
    if state.faults is not None:
        # the retry ledger is per-client; the (M,) edge mask and the
        # scalar counters are replicated like the rest of the edge state
        flt_sh = FaultState(edge_up=rep, attempts=cl, n_retries=rep,
                            n_dropped=rep, n_quarantined=rep,
                            n_crashed=rep)
    state_sh = RoundState(
        global_params=jax.tree.map(lambda _: rep, state.global_params),
        client_params=jax.tree.map(lambda _: cl, state.client_params),
        gains=cl, staleness=cl, key=rep, round_idx=rep, scenario=scen_sh,
        buffer=buf_sh, faults=flt_sh,
        warm=cl if state.warm is not None else None)
    bundle_sh = RoundBundle(dist=cl, x=cl, y=cl, counts=cl,
                            test_x=rep, test_y=rep)
    return state_sh, bundle_sh


def shard_clients(state: RoundState, bundle: RoundBundle,
                  mesh: "jax.sharding.Mesh | None" = None
                  ) -> Tuple[RoundState, RoundBundle]:
    """Place one simulation with its client axis split over ``mesh``.
    Requires ``cfg.n_clients`` divisible by the device count — pad a
    ragged N with ``pad_clients`` first."""
    mesh = client_mesh() if mesh is None else mesh
    state_sh, bundle_sh = _client_shardings(state, bundle, mesh)
    return (jax.device_put(state, state_sh),
            jax.device_put(bundle, bundle_sh))


def pad_clients(cfg, state: RoundState, bundle: RoundBundle, multiple: int):
    """Pad N up to a multiple of ``multiple`` with INERT clients: parked
    far outside every coverage disk (static distances and, under
    mobility, positions — speed 0 keeps them parked), unavailable with a
    sticky dropout chain, zero data counts.  They can never associate, so
    they never train into an aggregate, never earn a rate and never bill
    a joule (invariants pinned in tests/test_client_sharding.py).

    Returns ``(cfg', state', bundle')`` with ``cfg.n_clients`` grown —
    note a padded world is a DIFFERENT experiment from the unpadded one
    (the per-round PRNG fans out over N, and per-round aggregates like
    ``avg_staleness`` average over the padded axis); the parity guarantee
    is sharded == unsharded on the SAME padded world.  A ddpg actor's
    observation dim is 2N/3N — train it on the padded shape."""
    n = cfg.n_clients
    pad = (-n) % int(multiple)
    if pad == 0:
        return cfg, state, bundle
    far = cfg.area_side_m * 1e3

    def rep_last(leaf):
        return jnp.concatenate([leaf, jnp.repeat(leaf[-1:], pad, axis=0)],
                               axis=0)

    def const(leaf, value):
        tail = jnp.full((pad,) + leaf.shape[1:], value, leaf.dtype)
        return jnp.concatenate([leaf, tail], axis=0)

    scen = state.scenario
    scen = scen._replace(
        pos=const(scen.pos, far), waypoint=const(scen.waypoint, far),
        speed=const(scen.speed, 0.0), avail=const(scen.avail, 0.0),
        p_drop=const(scen.p_drop, 1.0), p_return=const(scen.p_return, 0.0),
        f_max_hz=rep_last(scen.f_max_hz), p_max_w=rep_last(scen.p_max_w),
        kappa=rep_last(scen.kappa), dist=const(scen.dist, far))
    state = state._replace(
        client_params=jax.tree.map(rep_last, state.client_params),
        gains=rep_last(state.gains),
        staleness=const(state.staleness, 0),
        scenario=scen)
    if state.buffer is not None:
        buf = state.buffer
        # padded clients are idle forever: zero pending delta, tier 0 —
        # being unavailable they never associate, so they never land.
        state = state._replace(buffer=buf._replace(
            pending_delta=jax.tree.map(lambda l: const(l, 0.0),
                                       buf.pending_delta),
            finish_s=const(buf.finish_s, 0.0),
            in_flight=const(buf.in_flight, False),
            pulled_ver=const(buf.pulled_ver, 0),
            obs_s=const(buf.obs_s, 0.0),
            tier=const(buf.tier, 0)))
    if state.faults is not None:
        # inert clients never admit, so their retry ledger stays zero
        state = state._replace(faults=state.faults._replace(
            attempts=const(state.faults.attempts, 0)))
    if state.warm is not None:
        # inert clients are never assigned, so their seed stays -1
        state = state._replace(warm=const(state.warm, -1))
    bundle = bundle._replace(
        dist=const(bundle.dist, far), x=rep_last(bundle.x),
        y=rep_last(bundle.y), counts=const(bundle.counts, 0.0))
    return dataclasses.replace(cfg, n_clients=n + pad), state, bundle


def run_scanned_client_sharded(cfg, spec: EngineSpec, state: RoundState,
                               bundle: RoundBundle, n_rounds: int,
                               actor_params: Optional[Params] = None, *,
                               mesh: "jax.sharding.Mesh | None" = None
                               ) -> Tuple[RoundState, RoundMetrics]:
    """``run_scanned`` with the client axis sharded over ``mesh`` (default:
    all devices), padding a ragged N with inert clients first.  Returns
    the padded-world results — slice client-axis leaves to
    ``cfg.n_clients`` yourself if you need the original N view."""
    mesh = client_mesh() if mesh is None else mesh
    cfg, state, bundle = pad_clients(cfg, state, bundle,
                                     int(mesh.devices.size))
    state, bundle = shard_clients(state, bundle, mesh)
    return run_scanned(cfg, spec, state, bundle, n_rounds, actor_params)


def split_output(spec: EngineSpec, out):
    """Normalise a driver's per-round output to ``(metrics, trace)``.

    Telemetry off: ``out`` IS the ``RoundMetrics`` pytree → ``(out, None)``.
    Telemetry on: ``out`` is the ``(RoundMetrics, RoundTrace)`` pair the
    engine emitted → returned as-is.  The split is static (it follows the
    spec flag), so generic callers — the sweep runner, benches, tests —
    handle both engine shapes with one line."""
    if spec.telemetry:
        return out
    return out, None


def metrics_row(metrics: RoundMetrics, i: Optional[int] = None):
    """Host-side view: pull round ``i`` (or a scalar metrics) to floats."""
    pick = (lambda l: l[i]) if i is not None else (lambda l: l)
    return {
        "round": int(pick(metrics.round)),
        "accuracy": float(pick(metrics.accuracy)),
        "loss": float(pick(metrics.loss)),
        "avg_staleness": float(pick(metrics.avg_staleness)),
        "total_time_s": float(pick(metrics.total_time_s)),
        "total_energy_j": float(pick(metrics.total_energy_j)),
        "cost": float(pick(metrics.cost)),
        "n_associated": int(pick(metrics.n_associated)),
        "n_available": int(pick(metrics.n_available)),
        "z": np.asarray(pick(metrics.z)),
    }
