"""NOMA-HFL resource-allocation environment (the MDP of paper §IV-C).

State  S_j = {h_{n,m}^j, D_n} for the associated clients (paper's state space)
Action A_j = {p_n^j, f_n^j}    per associated client, continuous in [0,1]²
Reward R_j = −(λt·T + λe·E)    (Eq. 37)

The channel follows first-order Gauss-Markov fading between slots, giving the
time-varying environment the paper motivates DDPG with.  The whole env is
pure JAX: an episode is a single ``lax.scan``.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import cost, noma


class EnvState(NamedTuple):
    gains: jnp.ndarray       # (N, M) current |h|²
    key: jnp.ndarray
    avail: jnp.ndarray | None = None   # (N,) evolving availability (§6)


# ---------------------------------------------------------------------------
# Pure building blocks — shared by the env below AND the round engine
# (DESIGN.md §2.2), so DDPG training and the simulation observe the world
# through the SAME function instead of the engine reaching into env
# internals.
# ---------------------------------------------------------------------------

def observe(assoc: jnp.ndarray, gains: jnp.ndarray,
            n_samples: jnp.ndarray,
            avail: jnp.ndarray | None = None) -> jnp.ndarray:
    """State S_j: per-client (log-gain to own edge, data share), masked to
    the associated clients and flattened to (2N,).

    In a dynamic scenario (DESIGN.md §6) the observation gains a scenario
    slice: the availability mask, giving (3N,) — the agent sees which
    clients the world dropped this round.
    """
    associated = jnp.sum(assoc, axis=1) > 0
    own_gain = jnp.sum(gains * assoc, axis=1)                   # (N,)
    g = jnp.log10(jnp.maximum(own_gain, 1e-20)) / 10.0 + 1.0
    d = n_samples / jnp.maximum(jnp.max(n_samples), 1.0)
    parts = [jnp.where(associated, g, 0.0),
             jnp.where(associated, d, 0.0)]
    if avail is not None:
        parts.append(avail.astype(g.dtype))
    return jnp.concatenate(parts)


def decode_action(cfg, action: jnp.ndarray, n_clients: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """[0,1]^{2N} -> (p (N,) W, f (N,) Hz) within paper Table II bounds."""
    a = action.reshape(2, n_clients)
    p = cfg.p_min_w + a[0] * (cfg.p_max_w - cfg.p_min_w)
    f = cfg.f_min_hz + a[1] * (cfg.f_max_hz - cfg.f_min_hz)
    return p, f


class NomaHflEnv:
    """Environment over a FIXED association (one scheduling epoch)."""

    def __init__(self, cfg, assoc: jnp.ndarray, z: jnp.ndarray,
                 dist: jnp.ndarray, n_samples: jnp.ndarray,
                 fading_rho: float = 0.9,
                 avail: jnp.ndarray | None = None,
                 kappa: jnp.ndarray | None = None,
                 p_max_w: jnp.ndarray | None = None,
                 f_max_hz: jnp.ndarray | None = None,
                 noma_enabled: bool = True,
                 p_drop: jnp.ndarray | None = None,
                 p_return: jnp.ndarray | None = None):
        self.cfg = cfg
        self.assoc = assoc                   # (N, M) one-hot
        self.z = z                           # (M,)
        self.dist = dist                     # (N, M)
        self.n_samples = n_samples           # (N,)
        self.rho = fading_rho
        self.noma_enabled = noma_enabled
        # scenario slices (DESIGN.md §6): the env must charge the SAME cost
        # the engine will bill at deployment — per-device κ and (p, f) caps
        # — and, with (p_drop, p_return), evolve the availability chain
        # between slots so the actor trains on a VARYING third obs block
        self.kappa = kappa                   # (N,) or None
        self.p_max_w = p_max_w               # (N,) or None
        self.f_max_hz = f_max_hz             # (N,) or None
        self.p_drop = p_drop                 # (N,) or None
        self.p_return = p_return             # (N,) or None
        self.n_clients = assoc.shape[0]
        has_avail = avail is not None or p_drop is not None
        self.avail0 = (avail if avail is not None else
                       jnp.ones((self.n_clients,), jnp.float32)
                       ) if has_avail else None
        self.associated = jnp.sum(assoc, axis=1) > 0
        # state: per-client (gain to own edge, data size)[, availability]
        self.state_dim = (2 + has_avail) * self.n_clients
        self.action_dim = 2 * self.n_clients

    # -- helpers ---------------------------------------------------------------

    def _masked_assoc(self, avail: jnp.ndarray | None) -> jnp.ndarray:
        """The engine's §6 contract: a dropped client is out of the
        association — for the observation AND the bill."""
        return self.assoc if avail is None else self.assoc * avail[:, None]

    def _observe(self, gains: jnp.ndarray,
                 avail: jnp.ndarray | None) -> jnp.ndarray:
        return observe(self._masked_assoc(avail), gains, self.n_samples,
                       avail)

    def decode_action(self, action: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        p, f = decode_action(self.cfg, action, self.n_clients)
        # device-class caps, mirroring the engine's clamp in round_step
        if self.p_max_w is not None:
            p = jnp.minimum(p, self.p_max_w)
        if self.f_max_hz is not None:
            f = jnp.minimum(f, self.f_max_hz)
        return p, f

    # -- gym-like API ------------------------------------------------------------

    def reset(self, key) -> Tuple[EnvState, jnp.ndarray]:
        k1, k2 = jax.random.split(key)
        gains = noma.rayleigh_gains(
            k1, self.dist, path_loss_exponent=self.cfg.path_loss_exponent)
        state = EnvState(gains, k2, self.avail0)
        return state, self._observe(gains, state.avail)

    def step(self, state: EnvState, action: jnp.ndarray
             ) -> Tuple[EnvState, jnp.ndarray, jnp.ndarray, cost.RoundCost]:
        p, f = self.decode_action(action)
        # bill the availability the agent observed when acting
        assoc = self._masked_assoc(state.avail)
        rc = cost.round_cost(self.cfg, power_w=p, f_hz=f, gains=state.gains,
                             assoc=assoc, z=self.z,
                             n_samples=self.n_samples,
                             noma_enabled=self.noma_enabled,
                             capacitance=self.kappa)
        reward = -rc.cost                                        # Eq. 37
        if self.p_drop is not None:
            k1, k2, k3 = jax.random.split(state.key, 3)
            u = jax.random.uniform(k3, state.avail.shape)
            avail = jnp.where(state.avail > 0, u >= self.p_drop,
                              u < self.p_return).astype(jnp.float32)
        else:
            k1, k2 = jax.random.split(state.key)
            avail = state.avail
        gains = noma.evolve_gains(
            k1, state.gains, self.dist,
            path_loss_exponent=self.cfg.path_loss_exponent, rho=self.rho)
        new_state = EnvState(gains, k2, avail)
        return new_state, self._observe(gains, avail), reward, rc


# ---------------------------------------------------------------------------
# Baseline allocators (paper §V-D benchmarks)
# ---------------------------------------------------------------------------

def rra_action(key, n_clients: int) -> jnp.ndarray:
    """Random resource allocation."""
    return jax.random.uniform(key, (2 * n_clients,))


def _grid_best(e: "NomaHflEnv", gains: jnp.ndarray, fixed_axis: int,
               fixed_frac: float = 0.5, n_grid: int = 16,
               avail: jnp.ndarray | None = None) -> jnp.ndarray:
    """Grid-optimise the free (shared) fraction while the other axis is
    fixed — the paper's FPA/FCA benchmarks optimise their free variable
    'in the same way as DDPG-RA' (§V-D); a 1-D grid is the stand-in.
    Pass the slot's ``avail`` (EnvState.avail) in dropout scenarios so the
    baseline optimises the masked bill ``step()`` actually charges."""
    n = e.n_clients
    fracs = jnp.linspace(0.0, 1.0, n_grid)
    assoc = e.assoc if avail is None else e.assoc * avail[:, None]

    def cost_of(frac):
        a = jnp.full((2, n), fixed_frac).at[1 - fixed_axis].set(frac) \
            .reshape(-1)
        p, f = e.decode_action(a)
        # optimise the SAME bill step() charges (NOMA switch + device κ +
        # availability mask)
        rc = cost.round_cost(e.cfg, power_w=p, f_hz=f, gains=gains,
                             assoc=assoc, z=e.z, n_samples=e.n_samples,
                             noma_enabled=e.noma_enabled,
                             capacitance=e.kappa)
        return rc.cost

    costs = jax.vmap(cost_of)(fracs)
    best = fracs[jnp.argmin(costs)]
    a = jnp.full((2, n), fixed_frac).at[1 - fixed_axis].set(best)
    return a.reshape(-1)


def fpa_best_action(e: "NomaHflEnv", gains: jnp.ndarray,
                    avail: jnp.ndarray | None = None) -> jnp.ndarray:
    """Fixed power at p_max (the conventional FPA choice [18]);
    grid-optimised shared CPU frequency."""
    return _grid_best(e, gains, fixed_axis=0, fixed_frac=1.0, avail=avail)


def fca_best_action(e: "NomaHflEnv", gains: jnp.ndarray,
                    avail: jnp.ndarray | None = None) -> jnp.ndarray:
    """Fixed CPU frequency at f_max (the conventional FCA choice [19]);
    grid-optimised shared power."""
    return _grid_best(e, gains, fixed_axis=1, fixed_frac=1.0, avail=avail)


def fpa_action(n_clients: int, f_frac: jnp.ndarray) -> jnp.ndarray:
    """Fixed power (midpoint), computation frequency from ``f_frac``."""
    return jnp.concatenate([jnp.full((n_clients,), 0.5), f_frac])


def fca_action(n_clients: int, p_frac: jnp.ndarray) -> jnp.ndarray:
    """Fixed computation (midpoint), power from ``p_frac``."""
    return jnp.concatenate([p_frac, jnp.full((n_clients,), 0.5)])
