"""NOMA-HFL resource-allocation environment (the MDP of paper §IV-C).

State  S_j = {h_{n,m}^j, D_n} for the associated clients (paper's state space)
Action A_j = {p_n^j, f_n^j}    per associated client, continuous in [0,1]²
Reward R_j = −(λt·T + λe·E)    (Eq. 37)

The channel follows first-order Gauss-Markov fading between slots, giving the
time-varying environment the paper motivates DDPG with.  The whole env is
pure JAX: an episode is a single ``lax.scan``.
"""
from __future__ import annotations

from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core import cost, noma


class EnvState(NamedTuple):
    gains: jnp.ndarray       # (N, M) current |h|²
    key: jnp.ndarray
    avail: jnp.ndarray | None = None   # (N,) evolving availability (§6)


class EnvParams(NamedTuple):
    """Everything the MDP needs besides the evolving ``EnvState`` — traced
    arrays only (``None`` leaves switch code paths at trace time), so one
    compiled episode serves every parameterisation of the same shape.

    The DDPG trainer closes over an ``EnvParams`` and scans
    ``env_step``; the ``NomaHflEnv`` class below is a thin wrapper holding
    one of these (DESIGN.md §7).
    """
    assoc: jnp.ndarray                 # (N, M) one-hot association
    z: jnp.ndarray                     # (M,) edge-selection mask
    dist: jnp.ndarray                  # (N, M) client-edge distances
    n_samples: jnp.ndarray             # (N,) D_n
    fading_rho: jnp.ndarray            # () Gauss-Markov fading coefficient
    avail0: jnp.ndarray | None         # (N,) initial availability (or None)
    kappa: jnp.ndarray | None          # (N,) per-device κ (§6)
    p_max_w: jnp.ndarray | None        # (N,) per-device power cap
    f_max_hz: jnp.ndarray | None       # (N,) per-device frequency cap
    p_drop: jnp.ndarray | None         # (N,) P(up -> down) between slots
    p_return: jnp.ndarray | None       # (N,) P(down -> up) between slots


# ---------------------------------------------------------------------------
# Pure building blocks — shared by the env below AND the round engine
# (DESIGN.md §2.2), so DDPG training and the simulation observe the world
# through the SAME function instead of the engine reaching into env
# internals.
# ---------------------------------------------------------------------------

def observe(assoc: jnp.ndarray, gains: jnp.ndarray,
            n_samples: jnp.ndarray,
            avail: jnp.ndarray | None = None) -> jnp.ndarray:
    """State S_j: per-client (log-gain to own edge, data share), masked to
    the associated clients and flattened to (2N,).

    In a dynamic scenario (DESIGN.md §6) the observation gains a scenario
    slice: the availability mask, giving (3N,) — the agent sees which
    clients the world dropped this round.
    """
    associated = jnp.sum(assoc, axis=1) > 0
    own_gain = jnp.sum(gains * assoc, axis=1)                   # (N,)
    return _observe_from(associated, own_gain, n_samples, avail)


def observe_assigned(assigned: jnp.ndarray, own_gain: jnp.ndarray,
                     n_samples: jnp.ndarray,
                     avail: jnp.ndarray | None = None) -> jnp.ndarray:
    """``observe`` from the COMPACT association (DESIGN.md §9): the
    assigned-edge vector (N,) and the pre-gathered own-edge gains replace
    the (N, M) one-hot product.  Gathering one gain and multiplying by an
    exact 1.0 is the same float the dense masked sum produces, so the two
    observations are bit-identical — the DDPG actor cannot tell which
    layout the engine ran."""
    return _observe_from(assigned >= 0, own_gain, n_samples, avail)


def _observe_from(associated: jnp.ndarray, own_gain: jnp.ndarray,
                  n_samples: jnp.ndarray,
                  avail: jnp.ndarray | None) -> jnp.ndarray:
    g = jnp.log10(jnp.maximum(own_gain, 1e-20)) / 10.0 + 1.0
    d = n_samples / jnp.maximum(jnp.max(n_samples), 1.0)
    parts = [jnp.where(associated, g, 0.0),
             jnp.where(associated, d, 0.0)]
    if avail is not None:
        parts.append(avail.astype(g.dtype))
    return jnp.concatenate(parts)


def decode_action(cfg, action: jnp.ndarray, n_clients: int
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """[0,1]^{2N} -> (p (N,) W, f (N,) Hz) within paper Table II bounds."""
    a = action.reshape(2, n_clients)
    p = cfg.p_min_w + a[0] * (cfg.p_max_w - cfg.p_min_w)
    f = cfg.f_min_hz + a[1] * (cfg.f_max_hz - cfg.f_min_hz)
    return p, f


def make_env_params(cfg, assoc: jnp.ndarray, z: jnp.ndarray,
                    dist: jnp.ndarray, n_samples: jnp.ndarray, *,
                    fading_rho: float = 0.9,
                    avail: jnp.ndarray | None = None,
                    kappa: jnp.ndarray | None = None,
                    p_max_w: jnp.ndarray | None = None,
                    f_max_hz: jnp.ndarray | None = None,
                    p_drop: jnp.ndarray | None = None,
                    p_return: jnp.ndarray | None = None) -> EnvParams:
    """Normalise the scenario slices into an ``EnvParams`` pytree.

    An availability block exists iff the caller provides an initial mask or
    a dropout chain — that choice fixes the observation dimension (2N vs
    3N) at trace time, exactly like the engine's static/dynamic switch.
    """
    del cfg
    n = assoc.shape[0]
    has_avail = avail is not None or p_drop is not None
    avail0 = (avail if avail is not None
              else jnp.ones((n,), jnp.float32)) if has_avail else None
    return EnvParams(assoc=assoc, z=z, dist=dist, n_samples=n_samples,
                     fading_rho=jnp.asarray(fading_rho, jnp.float32),
                     avail0=avail0, kappa=kappa, p_max_w=p_max_w,
                     f_max_hz=f_max_hz, p_drop=p_drop, p_return=p_return)


def env_dims(params: EnvParams) -> Tuple[int, int]:
    """(state_dim, action_dim) of the MDP an ``EnvParams`` defines."""
    n = params.assoc.shape[0]
    return (2 + (params.avail0 is not None)) * n, 2 * n


def _masked_assoc(params: EnvParams,
                  avail: jnp.ndarray | None) -> jnp.ndarray:
    """The engine's §6 contract: a dropped client is out of the
    association — for the observation AND the bill."""
    return params.assoc if avail is None else params.assoc * avail[:, None]


def env_observe(params: EnvParams, gains: jnp.ndarray,
                avail: jnp.ndarray | None) -> jnp.ndarray:
    return observe(_masked_assoc(params, avail), gains, params.n_samples,
                   avail)


def env_decode_action(cfg, params: EnvParams, action: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Action -> (p, f), clamped to the per-device scenario caps (mirrors
    the engine's clamp in ``round_step``)."""
    p, f = decode_action(cfg, action, params.assoc.shape[0])
    if params.p_max_w is not None:
        p = jnp.minimum(p, params.p_max_w)
    if params.f_max_hz is not None:
        f = jnp.minimum(f, params.f_max_hz)
    return p, f


def env_reset(cfg, params: EnvParams, key) -> Tuple[EnvState, jnp.ndarray]:
    k1, k2 = jax.random.split(key)
    gains = noma.rayleigh_gains(
        k1, params.dist, path_loss_exponent=cfg.path_loss_exponent)
    state = EnvState(gains, k2, params.avail0)
    return state, env_observe(params, gains, state.avail)


def env_step(cfg, params: EnvParams, state: EnvState, action: jnp.ndarray,
             *, noma_enabled: bool = True
             ) -> Tuple[EnvState, jnp.ndarray, jnp.ndarray, cost.RoundCost]:
    """One MDP slot, fully pure: bill the availability the agent observed
    when acting, then evolve the channel (and the dropout chain) for the
    next observation.  ``lax.scan`` over this function IS an episode."""
    p, f = env_decode_action(cfg, params, action)
    assoc = _masked_assoc(params, state.avail)
    rc = cost.round_cost(cfg, power_w=p, f_hz=f, gains=state.gains,
                         assoc=assoc, z=params.z,
                         n_samples=params.n_samples,
                         noma_enabled=noma_enabled,
                         capacitance=params.kappa)
    reward = -rc.cost                                            # Eq. 37
    if params.p_drop is not None:
        k1, k2, k3 = jax.random.split(state.key, 3)
        u = jax.random.uniform(k3, state.avail.shape)
        avail = jnp.where(state.avail > 0, u >= params.p_drop,
                          u < params.p_return).astype(jnp.float32)
    else:
        k1, k2 = jax.random.split(state.key)
        avail = state.avail
    gains = noma.evolve_gains(
        k1, state.gains, params.dist,
        path_loss_exponent=cfg.path_loss_exponent, rho=params.fading_rho)
    new_state = EnvState(gains, k2, avail)
    return new_state, env_observe(params, gains, avail), reward, rc


class NomaHflEnv:
    """Environment over a FIXED association (one scheduling epoch).

    A stateful-looking wrapper over the pure ``env_reset`` / ``env_step``
    above: it owns an ``EnvParams`` and nothing else, so the class and the
    functional API are interchangeable by construction."""

    def __init__(self, cfg, assoc: jnp.ndarray, z: jnp.ndarray,
                 dist: jnp.ndarray, n_samples: jnp.ndarray,
                 fading_rho: float = 0.9,
                 avail: jnp.ndarray | None = None,
                 kappa: jnp.ndarray | None = None,
                 p_max_w: jnp.ndarray | None = None,
                 f_max_hz: jnp.ndarray | None = None,
                 noma_enabled: bool = True,
                 p_drop: jnp.ndarray | None = None,
                 p_return: jnp.ndarray | None = None):
        self.cfg = cfg
        self.noma_enabled = noma_enabled
        # scenario slices (DESIGN.md §6): the env must charge the SAME cost
        # the engine will bill at deployment — per-device κ and (p, f) caps
        # — and, with (p_drop, p_return), evolve the availability chain
        # between slots so the actor trains on a VARYING third obs block
        self.params = make_env_params(cfg, assoc, z, dist, n_samples,
                                      fading_rho=fading_rho, avail=avail,
                                      kappa=kappa, p_max_w=p_max_w,
                                      f_max_hz=f_max_hz, p_drop=p_drop,
                                      p_return=p_return)
        self.n_clients = assoc.shape[0]
        self.associated = jnp.sum(assoc, axis=1) > 0
        # state: per-client (gain to own edge, data size)[, availability]
        self.state_dim, self.action_dim = env_dims(self.params)

    # -- params views ----------------------------------------------------------

    @property
    def assoc(self) -> jnp.ndarray:
        return self.params.assoc

    @property
    def z(self) -> jnp.ndarray:
        return self.params.z

    @property
    def dist(self) -> jnp.ndarray:
        return self.params.dist

    @property
    def n_samples(self) -> jnp.ndarray:
        return self.params.n_samples

    @property
    def kappa(self) -> jnp.ndarray | None:
        return self.params.kappa

    @property
    def avail0(self) -> jnp.ndarray | None:
        return self.params.avail0

    def decode_action(self, action: jnp.ndarray
                      ) -> Tuple[jnp.ndarray, jnp.ndarray]:
        return env_decode_action(self.cfg, self.params, action)

    # -- gym-like API ------------------------------------------------------------

    def reset(self, key) -> Tuple[EnvState, jnp.ndarray]:
        return env_reset(self.cfg, self.params, key)

    def step(self, state: EnvState, action: jnp.ndarray
             ) -> Tuple[EnvState, jnp.ndarray, jnp.ndarray, cost.RoundCost]:
        return env_step(self.cfg, self.params, state, action,
                        noma_enabled=self.noma_enabled)


# ---------------------------------------------------------------------------
# Baseline allocators (paper §V-D benchmarks)
# ---------------------------------------------------------------------------

def rra_action(key, n_clients: int) -> jnp.ndarray:
    """Random resource allocation."""
    return jax.random.uniform(key, (2 * n_clients,))


def grid_best_action(cfg, params: EnvParams, gains: jnp.ndarray, *,
                     fixed_axis: int, fixed_frac: float = 0.5,
                     n_grid: int = 16, noma_enabled: bool = True,
                     avail: jnp.ndarray | None = None) -> jnp.ndarray:
    """Grid-optimise the free (shared) action fraction while the other
    axis is fixed — the paper's FPA/FCA benchmarks optimise their free
    variable 'in the same way as DDPG-RA' (§V-D); a 1-D grid is the
    stand-in.  THE single implementation of that search: the env
    baselines below and the engine's fpa/fca allocators both call it, so
    the optimised surface is always the billed one (NOMA switch +
    device κ + caps + availability mask) and cannot drift between the
    two again.  Returns the (2N,) action."""
    n = params.assoc.shape[0]
    fracs = jnp.linspace(0.0, 1.0, n_grid)
    assoc = _masked_assoc(params, avail)

    def action_of(frac):
        return jnp.full((2, n), fixed_frac).at[1 - fixed_axis].set(frac) \
            .reshape(-1)

    def cost_of(frac):
        p, f = env_decode_action(cfg, params, action_of(frac))
        rc = cost.round_cost(cfg, power_w=p, f_hz=f, gains=gains,
                             assoc=assoc, z=params.z,
                             n_samples=params.n_samples,
                             noma_enabled=noma_enabled,
                             capacitance=params.kappa)
        return rc.cost

    best = fracs[jnp.argmin(jax.vmap(cost_of)(fracs))]
    return action_of(best)


def _grid_best(e: "NomaHflEnv", gains: jnp.ndarray, fixed_axis: int,
               fixed_frac: float = 0.5, n_grid: int = 16,
               avail: jnp.ndarray | None = None) -> jnp.ndarray:
    """``grid_best_action`` over an env instance.  Pass the slot's
    ``avail`` (EnvState.avail) in dropout scenarios so the baseline
    optimises the masked bill ``step()`` actually charges."""
    return grid_best_action(e.cfg, e.params, gains, fixed_axis=fixed_axis,
                            fixed_frac=fixed_frac, n_grid=n_grid,
                            noma_enabled=e.noma_enabled, avail=avail)


def fpa_best_action(e: "NomaHflEnv", gains: jnp.ndarray,
                    avail: jnp.ndarray | None = None) -> jnp.ndarray:
    """Fixed power at p_max (the conventional FPA choice [18]);
    grid-optimised shared CPU frequency."""
    return _grid_best(e, gains, fixed_axis=0, fixed_frac=1.0, avail=avail)


def fca_best_action(e: "NomaHflEnv", gains: jnp.ndarray,
                    avail: jnp.ndarray | None = None) -> jnp.ndarray:
    """Fixed CPU frequency at f_max (the conventional FCA choice [19]);
    grid-optimised shared power."""
    return _grid_best(e, gains, fixed_axis=1, fixed_frac=1.0, avail=avail)


def fpa_action(n_clients: int, f_frac: jnp.ndarray) -> jnp.ndarray:
    """Fixed power (midpoint), computation frequency from ``f_frac``."""
    return jnp.concatenate([jnp.full((n_clients,), 0.5), f_frac])


def fca_action(n_clients: int, p_frac: jnp.ndarray) -> jnp.ndarray:
    """Fixed computation (midpoint), power from ``p_frac``."""
    return jnp.concatenate([p_frac, jnp.full((n_clients,), 0.5)])
