"""Time/energy cost model for one HFL global round (paper Eqs. 3-5, 9-19).

Vectorised over all clients and edge servers.  The client-edge association is
a one-hot matrix ``assoc`` (N, M) with at most one 1 per row; ``z`` (M,) is
the semi-synchronous edge-selection mask.  Everything is differentiable in
(p, f) — which is what the DDPG agent exploits — and jittable.
"""
from __future__ import annotations

from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.core import noma


class RoundCost(NamedTuple):
    total_time_s: jnp.ndarray        # T  (Eq. 18)
    total_energy_j: jnp.ndarray      # E  (Eq. 19)
    cost: jnp.ndarray                # λt·T + λe·E  (Eq. 23a)
    per_edge_time_s: jnp.ndarray     # (M,) T_m^cloud + T^edge_{N_m}
    per_edge_energy_j: jnp.ndarray   # (M,) E_m^cloud + E^edge_{N_m}
    client_time_s: jnp.ndarray       # (N,) per-edge-iteration t_cmp + t_com
    rates_bps: jnp.ndarray           # (N,) NOMA uplink rates
    client_energy_j: jnp.ndarray     # (N,) per-edge-iteration e_cmp + e_com


def local_compute(cfg, f_hz: jnp.ndarray, n_samples: jnp.ndarray,
                  capacitance: jnp.ndarray | None = None):
    """Eqs. 4-5: per-client local training time and energy for τ₁ iterations.

    ``capacitance`` (N,) overrides the homogeneous cfg.capacitance with the
    per-device effective κ of a hetero_devices scenario (DESIGN.md §6).
    """
    tau1 = cfg.tau1
    kappa = cfg.capacitance if capacitance is None else capacitance
    t_cmp = tau1 * cfg.cycles_per_sample * n_samples / f_hz
    e_cmp = tau1 * (kappa / 2.0) * (f_hz ** 2) \
        * cfg.cycles_per_sample * n_samples
    return t_cmp, e_cmp


# Below this client count the O(N²) pairwise SIC is cheaper than a sort
# and — more importantly — is the formulation the golden trajectories were
# recorded with, so "auto" keeps small problems bit-for-bit stable.
_SORTED_SIC_MIN_N = 64


def uplink_assigned(cfg, power_w: jnp.ndarray, own_gain: jnp.ndarray,
                    assigned: jnp.ndarray, *, n_edges: int,
                    max_per_edge: int, noma_enabled: bool = True):
    """``uplink`` over the COMPACT association (DESIGN.md §9): (N,) power,
    (N,) gain to the assigned edge, (N,) assigned edge index (−1 =
    unmatched) — the billed Eq. 23 surface without the (N, M) rate matrix.

    NOMA rates come from ``noma.sic_rates_assigned`` (bit-identical to the
    dense sorted/top-k SIC read at the associated pairs); the OMA branch
    reads its per-edge occupancy off one exact integer scatter-add.
    Returns (t_com (N,), e_com (N,), rates (N,)).
    """
    noise = noma.noise_power_w(cfg.noise_dbm_per_hz, cfg.bandwidth_hz)
    matched = assigned >= 0
    if noma_enabled:
        rates = noma.sic_rates_assigned(
            power_w, own_gain, assigned, n_edges=n_edges,
            max_per_edge=max_per_edge, bandwidth_hz=cfg.bandwidth_hz,
            noise_w=noise)
    else:
        ones = matched.astype(jnp.float32)
        k_m = jnp.maximum(jnp.zeros((n_edges,)).at[
            jnp.maximum(assigned, 0)].add(ones), 1.0)            # (M,)
        share = jnp.where(matched, 1.0 / k_m[jnp.maximum(assigned, 0)], 0.0)
        band = cfg.bandwidth_hz * share
        snr = power_w * jnp.where(matched, own_gain, 0.0) \
            / jnp.maximum(noise * share, 1e-30)
        rates = band * jnp.log2(1.0 + snr)
    safe_rates = jnp.where(matched, jnp.maximum(rates, 1.0), 1.0)
    t_com = jnp.where(matched, cfg.model_size_bits / safe_rates, 0.0)
    e_com = power_w * t_com
    return t_com, e_com, rates


def uplink(cfg, power_w: jnp.ndarray, gains: jnp.ndarray,
           assoc: jnp.ndarray, *, noma_enabled: bool = True,
           sic_impl: str = "auto", sic_max_per_edge: int | None = None):
    """Eqs. 7-10 per edge server: NOMA rates, then t_com / e_com per client.

    gains: (N, M) channel |h|² to every edge; assoc: (N, M) one-hot.
    ``noma_enabled=False`` models the OMA benchmark: each edge splits its
    band B equally among its K_m clients (no interference, 1/K_m bandwidth).
    ``sic_impl`` selects the SIC formulation (all equal up to float
    summation order): "pairwise" (O(N²M), bit-stable reference),
    "sorted" (O(NM log N), the at-scale default), "pallas" (the fused
    ``kernels.hfl_ops.sic_rates`` kernel) or "auto" (sorted from
    N ≥ 64, pairwise below — bit-identical where goldens are pinned).
    ``sic_max_per_edge`` is a static per-edge admission bound that lets
    the sorted path top-k instead of full-sort (the engine passes its
    quota); it must be ≥ the true per-edge occupancy.
    Returns (t_com (N,), e_com (N,), rates (N,)).
    """
    noise = noma.noise_power_w(cfg.noise_dbm_per_hz, cfg.bandwidth_hz)

    if noma_enabled:
        impl = sic_impl
        if impl == "auto":
            impl = ("sorted" if assoc.shape[0] >= _SORTED_SIC_MIN_N
                    else "pairwise")
        if impl == "pairwise":
            def per_edge(m):
                mask = assoc[:, m] > 0
                return noma.achievable_rates(power_w, gains[:, m],
                                             bandwidth_hz=cfg.bandwidth_hz,
                                             noise_w=noise, mask=mask)

            rates_nm = jax.vmap(per_edge)(
                jnp.arange(assoc.shape[1])).T                    # (N, M)
        elif impl == "sorted":
            rates_nm = noma.sic_rates_matrix(
                power_w, gains, assoc > 0,
                bandwidth_hz=cfg.bandwidth_hz, noise_w=noise,
                max_per_edge=sic_max_per_edge)
        elif impl == "pallas":
            from repro.kernels import hfl_ops    # cycle-free lazy import
            rates_nm = hfl_ops.sic_rates(
                power_w, gains, assoc > 0,
                bandwidth_hz=cfg.bandwidth_hz, noise_w=noise)
        else:
            raise ValueError(f"unknown sic_impl {sic_impl!r}")
        rates = jnp.sum(rates_nm * assoc, axis=1)                # (N,)
    else:
        k_m = jnp.maximum(jnp.sum(assoc, axis=0), 1.0)               # (M,)
        share = jnp.sum(assoc / k_m[None, :], axis=1)                # (N,)
        own_gain = jnp.sum(gains * assoc, axis=1)
        band = cfg.bandwidth_hz * share
        snr = power_w * own_gain / jnp.maximum(noise * share, 1e-30)
        rates = band * jnp.log2(1.0 + snr)
    associated = jnp.sum(assoc, axis=1) > 0
    safe_rates = jnp.where(associated, jnp.maximum(rates, 1.0), 1.0)
    t_com = jnp.where(associated, cfg.model_size_bits / safe_rates, 0.0)
    e_com = power_w * t_com
    return t_com, e_com, rates


def apply_schedule(cfg, rc: RoundCost, z: jnp.ndarray) -> RoundCost:
    """Re-mask a ``round_cost`` evaluated at z = 1 with the actual edge
    selection.  The per-client and per-edge terms don't depend on z, so the
    scheduler needs only ONE cost evaluation: Eqs. 18-19 + 23a are a cheap
    masked reduction over the cached per-edge totals.
    """
    total_time = jnp.max(z * rc.per_edge_time_s)
    total_energy = jnp.sum(z * rc.per_edge_energy_j)
    c = cfg.lambda_t * total_time + cfg.lambda_e * total_energy
    return RoundCost(total_time, total_energy, c, rc.per_edge_time_s,
                     rc.per_edge_energy_j, rc.client_time_s, rc.rates_bps,
                     rc.client_energy_j)


def cohort_cost(cfg, rc: RoundCost, cohort: jnp.ndarray, dt_s: jnp.ndarray,
                fired: jnp.ndarray) -> RoundCost:
    """The buffered engine's per-MICRO-step bill (DESIGN.md §11).

    With the semi-synchronous barrier gone there is no per-round max over
    edges: a micro-step's time charge is the VIRTUAL-clock advance ``dt_s``
    (to the next completion event or the timeout edge), its energy charge
    is the admitted ``cohort``'s τ₂-scaled local+uplink energy (the same
    per-client Eq. 5/10 terms the barrier bill sums) plus one Eq. 16
    edge→cloud hop whenever the fill-or-timeout trigger ``fired`` — the
    buffered merge is one cloud exchange.  Summed over micro-steps the two
    engines charge the same per-client work terms; only the barrier's
    straggler time is gone, which is the point.
    """
    tau2 = cfg.tau2
    e_cloud = cfg.edge_power_w * cfg.edge_model_size_bits / cfg.edge_rate_bps
    energy = tau2 * jnp.sum(cohort.astype(jnp.float32)
                            * rc.client_energy_j) \
        + fired.astype(jnp.float32) * e_cloud
    c = cfg.lambda_t * dt_s + cfg.lambda_e * energy
    return RoundCost(dt_s, energy, c, rc.per_edge_time_s,
                     rc.per_edge_energy_j, rc.client_time_s, rc.rates_bps,
                     rc.client_energy_j)


def round_cost(cfg, *, power_w: jnp.ndarray, f_hz: jnp.ndarray,
               gains: jnp.ndarray, assoc: jnp.ndarray, z: jnp.ndarray,
               n_samples: jnp.ndarray, noma_enabled: bool = True,
               capacitance: jnp.ndarray | None = None,
               sic_impl: str = "auto",
               sic_max_per_edge: int | None = None,
               assigned: jnp.ndarray | None = None) -> RoundCost:
    """Full Eq. 23a cost for one global round.

    ``assigned`` (N,) — the compact assigned-edge vector of the candidate
    path (DESIGN.md §9).  When given, the uplink stage runs entirely on
    (N,)/(M, k) tensors via ``uplink_assigned`` (``sic_impl`` is moot —
    the compact SIC is the sorted/top-k formulation; ``sic_max_per_edge``
    must then be the admission quota); the cheap per-edge masked
    reductions below still use the one-hot ``assoc``, keeping their float
    summation order — and hence the bill — identical to the dense path.
    """
    t_cmp, e_cmp = local_compute(cfg, f_hz, n_samples, capacitance)
    if assigned is not None:
        if sic_max_per_edge is None:
            raise ValueError("round_cost(assigned=...) needs the static "
                             "sic_max_per_edge admission bound")
        from repro.core import candidates as _cand
        t_com, e_com, rates = uplink_assigned(
            cfg, power_w, _cand.own_edge_gather(assigned, gains), assigned,
            n_edges=assoc.shape[1], max_per_edge=sic_max_per_edge,
            noma_enabled=noma_enabled)
    else:
        t_com, e_com, rates = uplink(cfg, power_w, gains, assoc,
                                     noma_enabled=noma_enabled,
                                     sic_impl=sic_impl,
                                     sic_max_per_edge=sic_max_per_edge)
    associated = jnp.sum(assoc, axis=1) > 0
    client_time = jnp.where(associated, t_cmp + t_com, 0.0)
    client_energy = jnp.where(associated, e_cmp + e_com, 0.0)

    tau2 = cfg.tau2
    # Eq. 13: synchronous edge round = slowest associated client, × τ₂ iters.
    per_edge_time = tau2 * jnp.max(
        jnp.where(assoc > 0, client_time[:, None], 0.0), axis=0)    # (M,)
    # Eq. 14
    per_edge_energy = tau2 * jnp.sum(
        jnp.where(assoc > 0, client_energy[:, None], 0.0), axis=0)  # (M,)

    # Eqs. 15-16: OFDMA edge->cloud
    t_cloud = cfg.edge_model_size_bits / cfg.edge_rate_bps
    e_cloud = cfg.edge_power_w * t_cloud

    edge_total_time = per_edge_time + t_cloud
    edge_total_energy = per_edge_energy + e_cloud

    # Eqs. 18-19 with the semi-sync mask z
    total_time = jnp.max(z * edge_total_time)
    total_energy = jnp.sum(z * edge_total_energy)
    cost = cfg.lambda_t * total_time + cfg.lambda_e * total_energy
    return RoundCost(total_time, total_energy, cost, edge_total_time,
                     edge_total_energy, client_time, rates, client_energy)
