"""The (N, K) candidate edge frontier (DESIGN.md §9).

The paper's client orchestration is geometrically local: Eq. 11 coverage
means a client can only ever associate with the handful of edge servers
whose coverage disk it sits in, yet the dense round engine scores, ranks
and bills all (N, M) client-edge pairs.  A ``CandidateSet`` is the pruned
frontier that every round stage consumes instead:

* ``idx``   (N, K) int32 — each client's K nearest edge indices, row-sorted
  by the STRICT client preference order (distance ascending, edge index
  breaking exact ties).  That ordering is load-bearing: the candidate
  resolver's first-minimum ``argmin`` over slots IS the (distance, edge)
  lexicographic tie-break of the dense resolvers (DESIGN.md §8.1), so no
  per-slot distance comparison logic is ever needed.
* ``valid`` (N, K) bool — in coverage (dist ≤ radius) and, in a dynamic
  scenario, available this round.  Coverage is a distance threshold, so
  the in-coverage edges are exactly a prefix-by-validity of the
  distance-sorted row: K ≥ the maximum in-coverage degree ⇒ the candidate
  set loses nothing and every candidate stage is bit-identical to dense
  (the §9 parity contract, pinned by tests/test_candidates.py).
* ``dist``  (N, K) float — the gathered distances (the client-preference
  keys), so downstream stages never index the dense (N, M) field.

``K`` is static (``EngineSpec.candidates_k``); the set is rebuilt once per
round from the scenario's distance field — O(N·M) for the top-k, after
which fuzzy scoring, the association sweeps and the SIC/cost surface all
run on (N, K) or (N,) tensors.  Everything here is row-local over
clients, so the build shards cleanly over a ``("clients",)`` mesh.
"""
from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class CandidateSet(NamedTuple):
    """Per-client pruned edge frontier (a pytree; vmap/scan/shard-safe)."""
    idx: jnp.ndarray     # (N, K) int32 edge indices, (dist, edge)-sorted
    valid: jnp.ndarray   # (N, K) bool — in-coverage (and available)
    dist: jnp.ndarray    # (N, K) gathered client-edge distances


def build_candidates(dist: jnp.ndarray, k: int, *,
                     coverage_radius_m: float,
                     avail: Optional[jnp.ndarray] = None,
                     edge_up: Optional[jnp.ndarray] = None) -> CandidateSet:
    """Top-``k`` nearest edges per client from the (N, M) distance field.

    ``lax.top_k`` of the negated distances returns ascending distance with
    exact ties preferring the LOWER edge index — precisely the strict
    client preference order the resolvers need.  ``avail`` (N,) masks a
    dropped client's whole row invalid (the §6 contract: it is out of
    every edge's coverage this round).  ``edge_up`` (M,) marks dead edges
    (fault-layer churn) invalid in every row while keeping ``dist``
    physical — dead edges still rank by true distance, they just cannot
    be selected, so the frontier re-forms around the survivors.
    """
    n, m = dist.shape
    k = min(int(k), m)
    neg, idx = jax.lax.top_k(-dist, k)                       # (N, K)
    dk = -neg
    valid = dk <= coverage_radius_m
    if avail is not None:
        valid = valid & (avail > 0)[:, None]
    if edge_up is not None:
        valid = valid & (jnp.take(edge_up, idx) > 0)
    return CandidateSet(idx=idx.astype(jnp.int32), valid=valid, dist=dk)


def gather(cand: CandidateSet, field: jnp.ndarray) -> jnp.ndarray:
    """Gather an (N, M) per-pair field down to the (N, K) frontier."""
    return jnp.take_along_axis(field, cand.idx, axis=1)


def assigned_one_hot(assigned: jnp.ndarray, n_edges: int) -> jnp.ndarray:
    """(N,) assigned-edge vector (−1 = unmatched) -> (N, M) one-hot int32,
    the dense association layout the training/aggregation stages consume."""
    col = jnp.arange(n_edges, dtype=assigned.dtype)
    return ((assigned[:, None] == col[None, :]) &
            (assigned[:, None] >= 0)).astype(jnp.int32)


def own_edge_gather(assigned: jnp.ndarray, field: jnp.ndarray) -> jnp.ndarray:
    """(N,) values of an (N, M) field at each client's assigned edge
    (0.0 for unmatched clients) — bit-identical to the dense
    ``sum(field * one_hot, axis=1)`` (one nonzero · 1.0 plus exact zeros),
    without touching the pruned pairs."""
    safe = jnp.maximum(assigned, 0)
    got = jnp.take_along_axis(field, safe[:, None], axis=1)[:, 0]
    return jnp.where(assigned >= 0, got, 0.0)


def max_coverage_degree(dist, coverage_radius_m: float,
                        avail=None) -> int:
    """Host-side helper: the smallest K that loses nothing (the §9 parity
    bound).  Use at init/test time — NOT inside jit (returns a python int)."""
    import numpy as np
    cov = np.asarray(dist) <= coverage_radius_m
    if avail is not None:
        cov = cov & (np.asarray(avail) > 0)[:, None]
    return int(cov.sum(axis=1).max()) if cov.size else 0
