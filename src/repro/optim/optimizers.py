"""Pytree optimizers in the init/update style (no external deps).

Each factory returns an ``Optimizer`` with
  ``init(params) -> opt_state`` and
  ``update(grads, opt_state, params, step) -> (new_params, new_opt_state)``.

``step`` is a scalar int array so schedules stay jittable.  Moment dtype is
configurable (``opt_dtype``) — the ≥300B configs keep Adam moments in bf16 to
fit the dry-run memory budget (DESIGN.md §5).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

Params = Any
Schedule = Callable[[jnp.ndarray], jnp.ndarray]


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Params], Params]
    update: Callable[..., Any]


def _as_schedule(lr) -> Schedule:
    if callable(lr):
        return lr
    return lambda step: jnp.asarray(lr, jnp.float32)


def global_norm(tree: Params) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree: Params, max_norm: float) -> Params:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda l: (l * scale.astype(l.dtype)), tree)


def sgd(lr) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return {}

    def update(grads, state, params, step):
        eta = sched(step)
        new = jax.tree.map(lambda p, g: p - (eta * g).astype(p.dtype),
                           params, grads)
        return new, state

    return Optimizer(init, update)


def momentum(lr, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    sched = _as_schedule(lr)

    def init(params):
        return {"m": jax.tree.map(jnp.zeros_like, params)}

    def update(grads, state, params, step):
        eta = sched(step)
        m = jax.tree.map(lambda m_, g: beta * m_ + g, state["m"], grads)
        if nesterov:
            upd = jax.tree.map(lambda m_, g: beta * m_ + g, m, grads)
        else:
            upd = m
        new = jax.tree.map(lambda p, u: p - (eta * u).astype(p.dtype),
                           params, upd)
        return new, {"m": m}

    return Optimizer(init, update)


def _adam_core(lr, b1, b2, eps, weight_decay, opt_dtype) -> Optimizer:
    sched = _as_schedule(lr)
    dt = jnp.dtype(opt_dtype)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, dt)
        return {"m": jax.tree.map(zeros, params),
                "v": jax.tree.map(zeros, params)}

    def update(grads, state, params, step):
        eta = sched(step)
        t = step.astype(jnp.float32) + 1.0
        m = jax.tree.map(lambda m_, g: (b1 * m_.astype(jnp.float32)
                                        + (1 - b1) * g.astype(jnp.float32)
                                        ).astype(dt), state["m"], grads)
        v = jax.tree.map(lambda v_, g: (b2 * v_.astype(jnp.float32)
                                        + (1 - b2) * jnp.square(
                                            g.astype(jnp.float32))
                                        ).astype(dt), state["v"], grads)
        bc1 = 1.0 - b1 ** t
        bc2 = 1.0 - b2 ** t

        def step_fn(p, m_, v_):
            mhat = m_.astype(jnp.float32) / bc1
            vhat = v_.astype(jnp.float32) / bc2
            upd = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                upd = upd + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - eta * upd).astype(p.dtype)

        new = jax.tree.map(step_fn, params, m, v)
        return new, {"m": m, "v": v}

    return Optimizer(init, update)


def adam(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         opt_dtype: str = "float32") -> Optimizer:
    return _adam_core(lr, b1, b2, eps, 0.0, opt_dtype)


def adamw(lr, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01, opt_dtype: str = "float32") -> Optimizer:
    return _adam_core(lr, b1, b2, eps, weight_decay, opt_dtype)
