"""Optimizers (pure-pytree, optax-style init/update pairs)."""
from repro.optim.optimizers import (Optimizer, sgd, momentum, adam, adamw,
                                    clip_by_global_norm, global_norm)
from repro.optim.schedules import (constant, cosine_decay, linear_warmup,
                                   warmup_cosine)

__all__ = ["Optimizer", "sgd", "momentum", "adam", "adamw",
           "clip_by_global_norm", "global_norm", "constant", "cosine_decay",
           "linear_warmup", "warmup_cosine"]
