"""Learning-rate schedules as pure functions of a scalar step array."""
from __future__ import annotations

import jax.numpy as jnp


def constant(lr: float):
    return lambda step: jnp.asarray(lr, jnp.float32)


def linear_warmup(lr: float, warmup_steps: int):
    def sched(step):
        frac = jnp.minimum(step.astype(jnp.float32) + 1.0, warmup_steps) \
            / max(warmup_steps, 1)
        return lr * frac
    return sched


def cosine_decay(lr: float, decay_steps: int, final_frac: float = 0.1):
    def sched(step):
        t = jnp.clip(step.astype(jnp.float32) / max(decay_steps, 1), 0.0, 1.0)
        cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
        return lr * (final_frac + (1.0 - final_frac) * cos)
    return sched


def warmup_cosine(lr: float, warmup_steps: int, decay_steps: int,
                  final_frac: float = 0.1):
    wu = linear_warmup(lr, warmup_steps)
    cd = cosine_decay(lr, decay_steps, final_frac)

    def sched(step):
        return jnp.where(step < warmup_steps, wu(step),
                         cd(step - warmup_steps))
    return sched
