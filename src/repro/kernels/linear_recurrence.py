"""Diagonal linear-recurrence Pallas-TPU kernel (RG-LRU / SSM scans).

Computes h_t = exp(log_a_t)·h_{t-1} + x_t along the time axis.  TPU
adaptation (DESIGN.md §3): the recurrence is *diagonal*, so channels are
embarrassingly parallel — we tile channels across the lane dimension
(block_c a multiple of 128) and the grid's parallel axes, and sweep time in
VMEM-resident blocks:

* grid = (B, nC, nT) with the time axis innermost ("arbitrary"): the carry
  h lives in a (1, block_c) VMEM scratch across the nT sweep.
* Inside a block the time loop is a `fori_loop` over block_t rows — a
  vector op per step on (block_c,) lanes, the idiomatic TPU shape for a
  scan that XLA would otherwise serialise badly.
* HBM traffic is exactly 2 reads + 1 write per element — the kernel is
  memory-bound by construction, matching the roofline analysis.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams


def _linrec_kernel(log_a_ref, x_ref, o_ref, h_ref, *, block_t: int):
    it = pl.program_id(2)

    @pl.when(it == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    def step(t, h):
        a = jnp.exp(log_a_ref[0, t, :].astype(jnp.float32))
        x = x_ref[0, t, :].astype(jnp.float32)
        h = a * h + x
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, block_t, step, h_ref[0, :])
    h_ref[0, :] = h


def linear_recurrence(log_a: jnp.ndarray, x: jnp.ndarray, *,
                      block_t: int = 256, block_c: int = 128,
                      interpret: bool = False) -> jnp.ndarray:
    """log_a, x: (B, S, C) -> h (B, S, C) fp32 carry, output in x.dtype."""
    b, s, c = x.shape
    block_t = min(block_t, s)
    block_c = min(block_c, c)
    assert s % block_t == 0 and c % block_c == 0, (s, c, block_t, block_c)
    grid = (b, c // block_c, s // block_t)

    kernel = functools.partial(_linrec_kernel, block_t=block_t)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_t, block_c),
                         lambda b_, ic, it: (b_, it, ic)),
            pl.BlockSpec((1, block_t, block_c),
                         lambda b_, ic, it: (b_, it, ic)),
        ],
        out_specs=pl.BlockSpec((1, block_t, block_c),
                               lambda b_, ic, it: (b_, it, ic)),
        out_shape=jax.ShapeDtypeStruct((b, s, c), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, block_c), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(log_a, x)
