"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

These are deliberately naive — full score matrices, `associative_scan` — so
a kernel bug cannot hide behind a shared implementation trick.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -2.0e38


def attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True, window: int = 0) -> jnp.ndarray:
    """q (B, H, S, D); k/v (B, KV, S, D) -> (B, H, S, D).  GQA broadcast."""
    b, h, s, d = q.shape
    kv = k.shape[1]
    group = h // kv
    qg = q.reshape(b, kv, group, s, d)
    logits = jnp.einsum("bkgqd,bksd->bkgqs", qg * d ** -0.5,
                        k.astype(q.dtype)).astype(jnp.float32)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    allowed = jnp.ones((s, s), bool)
    if causal:
        allowed &= kp <= qp
    if window:
        allowed &= kp > qp - window
    logits = jnp.where(allowed, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgqs,bksd->bkgqd", probs, v)
    return out.reshape(b, h, s, d)


def linear_recurrence_ref(log_a: jnp.ndarray, x: jnp.ndarray,
                          h0: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """h_t = exp(log_a_t)·h_{t-1} + x_t along axis 1.  (B, S, C) fp32."""
    x = x.astype(jnp.float32)
    log_a = log_a.astype(jnp.float32)
    if h0 is not None:
        x = x.at[:, 0].add(jnp.exp(log_a[:, 0]) * h0.astype(jnp.float32))

    def combine(left, right):
        la, xa = left
        lb, xb = right
        return la + lb, jnp.exp(lb) * xa + xb

    _, h = jax.lax.associative_scan(combine, (log_a, x), axis=1)
    return h
