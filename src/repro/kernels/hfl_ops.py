"""Pallas kernels for the HFL round hot path (DESIGN.md §8.2).

Two fused kernels, both following the ``kernels/ops.py`` contract —
interpret mode on CPU (this container), compiled on a real TPU target,
with pure-jnp references (``repro.core.fuzzy.score_matrix`` and the
pairwise ``repro.core.noma.sic_sinr``) that the parity tests pin:

* ``score_matrix`` — the fuzzy competency scoring of §III as ONE kernel
  per row block: triangular memberships, the 27-rule Mamdani table and
  centre-of-gravity defuzzification are fused over a block of (client,
  edge) rows, so neither the (N, M, 27) rule-strength tensor nor the
  (N, M, 201, 5) clipped-output tensor ever exists in HBM — VMEM holds
  one (201, 5, block) slab at a time.
* ``sic_rates`` — all M edges' NOMA SIC rates in ONE ``pallas_call``:
  grid (M, N/bI, N/bJ) with the j-axis innermost; each (edge, i-block)
  accumulates its cumulative interference Σ_{weaker j} p_j·|h_j|² across
  the j sweep in VMEM scratch, so the (N, N) "who is decoded after whom"
  comparison matrix is never materialised (the jnp pairwise form writes
  it out per edge — 2 GB of temps at 4096×32).  The weaker-than order is
  the same (received power, client index) order as ``noma.sic_sinr`` and
  the sorted ``noma.sic_rates_matrix``, so all three agree up to float
  summation order.

Both are wired into ``engine.round_step`` behind ``EngineSpec`` toggles
(``pallas_score`` / ``sic_impl="pallas"``); the jnp paths stay the
default on CPU where interpret mode would only add overhead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import fuzzy
from repro.kernels._compat import CompilerParams as _CompilerParams


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


# ---------------------------------------------------------------------------
# Fused fuzzy scoring
# ---------------------------------------------------------------------------

# Static rule geometry.  Pallas kernels may not capture ARRAY constants,
# so triangles unroll to python-scalar literals at trace time and the CoG
# grid rides in as a replicated input block.
_RULES_FLAT = [int(r) for r in np.asarray(fuzzy.RULES).reshape(-1)]
_IN_TRIS = np.asarray(fuzzy._IN_TRIS).tolist()    # 3 × (a, b, c)
_OUT_TRIS = np.asarray(fuzzy._OUT_TRIS).tolist()  # 5 × (a, b, c)
_GRID = np.asarray(fuzzy._COG_GRID, np.float32)   # (201,)


def _tri_scalar(v: jnp.ndarray, abc) -> jnp.ndarray:
    """Membership of values ``v`` in ONE (a, b, c) triangle (scalar args
    inline as literals — no captured constants)."""
    a, b, c = abc
    up = (v - a) / max(b - a, 1e-9)
    down = (c - v) / max(c - b, 1e-9)
    return jnp.clip(jnp.minimum(up, down), 0.0, 1.0)


def _score_kernel(cq_ref, dq_ref, ms_ref, grid_ref, out_ref):
    cq, dq, ms = cq_ref[0], dq_ref[0], ms_ref[0]               # (R,)
    m_cq = [_tri_scalar(cq, t) for t in _IN_TRIS]              # 3 × (R,)
    m_dq = [_tri_scalar(dq, t) for t in _IN_TRIS]
    m_ms = [_tri_scalar(ms, t) for t in _IN_TRIS]
    # Max–Min inference, unrolled over the static 27-rule table and folded
    # straight into the 5 output-set strengths — the (R, 27) rule tensor
    # never exists, even in VMEM
    deg = [jnp.minimum(jnp.minimum(m_cq[i], m_dq[j]), m_ms[k])
           for i in range(3) for j in range(3) for k in range(3)]
    strengths = []
    for s in range(5):
        terms = [deg[r] for r in range(27) if _RULES_FLAT[r] == s]
        acc = terms[0]
        for t in terms[1:]:
            acc = jnp.maximum(acc, t)
        strengths.append(acc)
    strengths = jnp.stack(strengths)                           # (5, R)
    # Mamdani clip + aggregate + CoG over the 201-point output grid
    g = grid_ref[0]                                            # (G,)
    mu = jnp.stack([_tri_scalar(g, t) for t in _OUT_TRIS])     # (5, G)
    clipped = jnp.minimum(mu[:, :, None], strengths[:, None, :])
    agg = jnp.max(clipped, axis=0)                             # (G, R)
    num = jnp.sum(g[:, None] * agg, axis=0)
    den = jnp.maximum(jnp.sum(agg, axis=0), 1e-9)
    out_ref[0] = num / den


def _score_rows(cq: jnp.ndarray, dq: jnp.ndarray, ms: jnp.ndarray,
                block_r: int, interp: bool) -> jnp.ndarray:
    """The fused fuzzy pipeline over flat rows: (R,) cq/dq/ms -> (R,)
    NO* scores.  Shared by the dense (N·M) and candidate (N·K) callers —
    the kernel is row-shape-agnostic, only the gather differs."""
    rows = cq.shape[0]
    block_r = min(block_r, max(rows, 1))
    padded = -(-rows // block_r) * block_r
    flat = [jnp.pad(v, (0, padded - rows)).reshape(1, padded).astype(
        jnp.float32) for v in (cq, dq, ms)]
    spec = pl.BlockSpec((1, block_r), lambda i: (0, i))
    grid_spec = pl.BlockSpec((1, _GRID.size), lambda i: (0, 0))
    out = pl.pallas_call(
        _score_kernel,
        grid=(padded // block_r,),
        in_specs=[spec, spec, spec, grid_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((1, padded), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interp,
    )(*flat, jnp.asarray(_GRID).reshape(1, -1))
    return out[0, :rows]


@functools.partial(jax.jit,
                   static_argnames=("data_max", "block_r", "interpret"))
def score_matrix(gains: jnp.ndarray, counts: jnp.ndarray,
                 staleness: jnp.ndarray, *, data_max: float,
                 block_r: int = 512,
                 interpret: bool | None = None) -> jnp.ndarray:
    """Drop-in for ``fuzzy.score_matrix`` — (N, M) competency scores.

    The Eq. 21 normalisation (global dB min/max reductions) runs as plain
    XLA; the per-row fuzzy pipeline runs as the fused kernel over the
    flattened (N·M,) rows.
    """
    interp = _on_cpu() if interpret is None else interpret
    cq, dq, ms = fuzzy.normalized_inputs(gains, counts, staleness,
                                         data_max=data_max)
    n, m = cq.shape
    flat = _score_rows(cq.reshape(-1),
                       jnp.broadcast_to(dq[:, None], (n, m)).reshape(-1),
                       jnp.broadcast_to(ms[:, None], (n, m)).reshape(-1),
                       block_r, interp)
    return flat.reshape(n, m)


@functools.partial(jax.jit,
                   static_argnames=("data_max", "block_r", "interpret"))
def score_candidates(gains: jnp.ndarray, cand_idx: jnp.ndarray,
                     counts: jnp.ndarray, staleness: jnp.ndarray, *,
                     data_max: float, block_r: int = 512,
                     interpret: bool | None = None) -> jnp.ndarray:
    """Gathered-candidate variant of ``score_matrix`` (DESIGN.md §9):
    drop-in for ``fuzzy.score_candidates`` — (N, K) competency scores for
    the candidate frontier ``cand_idx`` only.

    Same global Eq. 21 normalisation as the dense kernel (so each score
    is bit-compatible with the dense matrix entry at the same pair), but
    the fused Mamdani/CoG kernel sweeps N·K flattened rows instead of
    N·M — the pruned pairs never reach the kernel grid.
    """
    interp = _on_cpu() if interpret is None else interpret
    cq, dq, ms = fuzzy.normalized_inputs(gains, counts, staleness,
                                         data_max=data_max)
    n, k = cand_idx.shape
    cq_k = jnp.take_along_axis(cq, cand_idx, axis=1)
    flat = _score_rows(cq_k.reshape(-1),
                       jnp.broadcast_to(dq[:, None], (n, k)).reshape(-1),
                       jnp.broadcast_to(ms[:, None], (n, k)).reshape(-1),
                       block_r, interp)
    return flat.reshape(n, k)


# ---------------------------------------------------------------------------
# Fused NOMA SIC rates
# ---------------------------------------------------------------------------

def _sic_kernel(pi_ref, gi_ref, mi_ref, pj_ref, gj_ref, mj_ref, out_ref,
                intf_ref, *, block_i: int, block_j: int, noise_w: float,
                bandwidth_hz: float):
    ii = pl.program_id(1)
    ij = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(ij == 0)
    def _init():
        intf_ref[...] = jnp.zeros_like(intf_ref)

    rx_i = pi_ref[0] * gi_ref[:, 0] * mi_ref[:, 0]             # (bI,)
    rx_j = pj_ref[0] * gj_ref[:, 0] * mj_ref[:, 0]             # (bJ,)
    i_pos = ii * block_i + jax.lax.broadcasted_iota(
        jnp.int32, (block_i, block_j), 0)
    j_pos = ij * block_j + jax.lax.broadcasted_iota(
        jnp.int32, (block_i, block_j), 1)
    # decoded after me ⇔ strictly weaker received power, index tie-break —
    # the exact ``noma.sic_sinr`` order
    weaker = (rx_j[None, :] < rx_i[:, None]) | \
        ((rx_j[None, :] == rx_i[:, None]) & (j_pos > i_pos))
    intf_ref[...] += jnp.sum(jnp.where(weaker, rx_j[None, :], 0.0), axis=1)

    @pl.when(ij == nj - 1)
    def _finish():
        sinr = rx_i / (intf_ref[...] + noise_w)
        out_ref[:, 0] = bandwidth_hz * jnp.log2(1.0 + sinr) * mi_ref[:, 0]


@functools.partial(jax.jit, static_argnames=("bandwidth_hz", "noise_w",
                                             "block_n", "interpret"))
def sic_rates(power_w: jnp.ndarray, gains: jnp.ndarray, mask: jnp.ndarray,
              *, bandwidth_hz: float, noise_w: float, block_n: int = 256,
              interpret: bool | None = None) -> jnp.ndarray:
    """(N,) power, (N, M) gains, (N, M) mask -> (N, M) SIC rates; masked
    entries are zero.  One ``pallas_call`` covers every edge."""
    interp = _on_cpu() if interpret is None else interpret
    n, m = gains.shape
    block_n = min(block_n, n)
    padded = -(-n // block_n) * block_n
    pad = padded - n
    p = jnp.pad(power_w.astype(jnp.float32), (0, pad)).reshape(1, padded)
    g = jnp.pad(gains.astype(jnp.float32), ((0, pad), (0, 0)))
    mk = jnp.pad(mask.astype(jnp.float32), ((0, pad), (0, 0)))
    nb = padded // block_n

    kernel = functools.partial(_sic_kernel, block_i=block_n,
                               block_j=block_n, noise_w=noise_w,
                               bandwidth_hz=bandwidth_hz)
    p_i = pl.BlockSpec((1, block_n), lambda e, i, j: (0, i))
    p_j = pl.BlockSpec((1, block_n), lambda e, i, j: (0, j))
    col_i = pl.BlockSpec((block_n, 1), lambda e, i, j: (i, e))
    col_j = pl.BlockSpec((block_n, 1), lambda e, i, j: (j, e))
    out = pl.pallas_call(
        kernel,
        grid=(m, nb, nb),
        in_specs=[p_i, col_i, col_i, p_j, col_j, col_j],
        out_specs=pl.BlockSpec((block_n, 1), lambda e, i, j: (i, e)),
        out_shape=jax.ShapeDtypeStruct((padded, m), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_n,), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interp,
    )(p, g, mk, p, g, mk)
    return out[:n]
