"""Pallas kernels for the HFL round hot path (DESIGN.md §8.2, §13.3).

Fused kernels following the ``kernels/ops.py`` contract — interpret mode
on CPU (this container), compiled on a real TPU target, with pure-jnp
references (``repro.core.fuzzy.score_matrix``, the pairwise
``repro.core.noma.sic_sinr`` and the engine's batched cohort step) that
the parity tests pin:

* ``score_matrix`` — the fuzzy competency scoring of §III as ONE kernel
  per row block: triangular memberships, the 27-rule Mamdani table and
  centre-of-gravity defuzzification are fused over a block of (client,
  edge) rows, so neither the (N, M, 27) rule-strength tensor nor the
  (N, M, 201, 5) clipped-output tensor ever exists in HBM — VMEM holds
  one (201, 5, block) slab at a time.
* ``sic_rates`` — all M edges' NOMA SIC rates in ONE ``pallas_call``:
  grid (M, N/bI, N/bJ) with the j-axis innermost; each (edge, i-block)
  accumulates its cumulative interference Σ_{weaker j} p_j·|h_j|² across
  the j sweep in VMEM scratch, so the (N, N) "who is decoded after whom"
  comparison matrix is never materialised (the jnp pairwise form writes
  it out per edge — 2 GB of temps at 4096×32).  The weaker-than order is
  the same (received power, client index) order as ``noma.sic_sinr`` and
  the sorted ``noma.sic_rates_matrix``, so all three agree up to float
  summation order.
* ``local_sgd_step`` — the fused Eq. 11 local-SGD stage (DESIGN.md §13.3):
  grid (K,), one admitted client per program, the client's whole MLP
  (w1/b1/w2/b2/w3/b3) plus its τ₁ pre-gathered minibatches resident in
  VMEM across ALL τ₁ inner steps — forward, softmax-CE backward and the
  SGD update are hand-fused, so no per-step activation or gradient ever
  round-trips HBM.  Agrees with the engine's batched jnp path up to the
  softmax/logsumexp op-ordering (tolerance parity, like the SIC kernel's
  summation-order contract).

All are wired into ``engine.round_step`` behind ``EngineSpec`` toggles
(``pallas_score`` / ``sic_impl="pallas"`` / ``train_impl="pallas"``); the
jnp paths stay the default on CPU where interpret mode would only add
overhead.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core import fuzzy
from repro.kernels._compat import CompilerParams as _CompilerParams


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


# ---------------------------------------------------------------------------
# Fused fuzzy scoring
# ---------------------------------------------------------------------------

# Static rule geometry.  Pallas kernels may not capture ARRAY constants,
# so triangles unroll to python-scalar literals at trace time and the CoG
# grid rides in as a replicated input block.
_RULES_FLAT = [int(r) for r in np.asarray(fuzzy.RULES).reshape(-1)]
_IN_TRIS = np.asarray(fuzzy._IN_TRIS).tolist()    # 3 × (a, b, c)
_OUT_TRIS = np.asarray(fuzzy._OUT_TRIS).tolist()  # 5 × (a, b, c)
_GRID = np.asarray(fuzzy._COG_GRID, np.float32)   # (201,)


def _tri_scalar(v: jnp.ndarray, abc) -> jnp.ndarray:
    """Membership of values ``v`` in ONE (a, b, c) triangle (scalar args
    inline as literals — no captured constants)."""
    a, b, c = abc
    up = (v - a) / max(b - a, 1e-9)
    down = (c - v) / max(c - b, 1e-9)
    return jnp.clip(jnp.minimum(up, down), 0.0, 1.0)


def _score_kernel(cq_ref, dq_ref, ms_ref, grid_ref, out_ref):
    cq, dq, ms = cq_ref[0], dq_ref[0], ms_ref[0]               # (R,)
    m_cq = [_tri_scalar(cq, t) for t in _IN_TRIS]              # 3 × (R,)
    m_dq = [_tri_scalar(dq, t) for t in _IN_TRIS]
    m_ms = [_tri_scalar(ms, t) for t in _IN_TRIS]
    # Max–Min inference, unrolled over the static 27-rule table and folded
    # straight into the 5 output-set strengths — the (R, 27) rule tensor
    # never exists, even in VMEM
    deg = [jnp.minimum(jnp.minimum(m_cq[i], m_dq[j]), m_ms[k])
           for i in range(3) for j in range(3) for k in range(3)]
    strengths = []
    for s in range(5):
        terms = [deg[r] for r in range(27) if _RULES_FLAT[r] == s]
        acc = terms[0]
        for t in terms[1:]:
            acc = jnp.maximum(acc, t)
        strengths.append(acc)
    strengths = jnp.stack(strengths)                           # (5, R)
    # Mamdani clip + aggregate + CoG over the 201-point output grid
    g = grid_ref[0]                                            # (G,)
    mu = jnp.stack([_tri_scalar(g, t) for t in _OUT_TRIS])     # (5, G)
    clipped = jnp.minimum(mu[:, :, None], strengths[:, None, :])
    agg = jnp.max(clipped, axis=0)                             # (G, R)
    num = jnp.sum(g[:, None] * agg, axis=0)
    den = jnp.maximum(jnp.sum(agg, axis=0), 1e-9)
    out_ref[0] = num / den


def _score_rows(cq: jnp.ndarray, dq: jnp.ndarray, ms: jnp.ndarray,
                block_r: int, interp: bool) -> jnp.ndarray:
    """The fused fuzzy pipeline over flat rows: (R,) cq/dq/ms -> (R,)
    NO* scores.  Shared by the dense (N·M) and candidate (N·K) callers —
    the kernel is row-shape-agnostic, only the gather differs."""
    rows = cq.shape[0]
    block_r = min(block_r, max(rows, 1))
    padded = -(-rows // block_r) * block_r
    flat = [jnp.pad(v, (0, padded - rows)).reshape(1, padded).astype(
        jnp.float32) for v in (cq, dq, ms)]
    spec = pl.BlockSpec((1, block_r), lambda i: (0, i))
    grid_spec = pl.BlockSpec((1, _GRID.size), lambda i: (0, 0))
    out = pl.pallas_call(
        _score_kernel,
        grid=(padded // block_r,),
        in_specs=[spec, spec, spec, grid_spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((1, padded), jnp.float32),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interp,
    )(*flat, jnp.asarray(_GRID).reshape(1, -1))
    return out[0, :rows]


@functools.partial(jax.jit,
                   static_argnames=("data_max", "block_r", "interpret"))
def score_matrix(gains: jnp.ndarray, counts: jnp.ndarray,
                 staleness: jnp.ndarray, *, data_max: float,
                 block_r: int = 512,
                 interpret: bool | None = None) -> jnp.ndarray:
    """Drop-in for ``fuzzy.score_matrix`` — (N, M) competency scores.

    The Eq. 21 normalisation (global dB min/max reductions) runs as plain
    XLA; the per-row fuzzy pipeline runs as the fused kernel over the
    flattened (N·M,) rows.
    """
    interp = _on_cpu() if interpret is None else interpret
    cq, dq, ms = fuzzy.normalized_inputs(gains, counts, staleness,
                                         data_max=data_max)
    n, m = cq.shape
    flat = _score_rows(cq.reshape(-1),
                       jnp.broadcast_to(dq[:, None], (n, m)).reshape(-1),
                       jnp.broadcast_to(ms[:, None], (n, m)).reshape(-1),
                       block_r, interp)
    return flat.reshape(n, m)


@functools.partial(jax.jit,
                   static_argnames=("data_max", "block_r", "interpret"))
def score_candidates(gains: jnp.ndarray, cand_idx: jnp.ndarray,
                     counts: jnp.ndarray, staleness: jnp.ndarray, *,
                     data_max: float, block_r: int = 512,
                     interpret: bool | None = None) -> jnp.ndarray:
    """Gathered-candidate variant of ``score_matrix`` (DESIGN.md §9):
    drop-in for ``fuzzy.score_candidates`` — (N, K) competency scores for
    the candidate frontier ``cand_idx`` only.

    Same global Eq. 21 normalisation as the dense kernel (so each score
    is bit-compatible with the dense matrix entry at the same pair), but
    the fused Mamdani/CoG kernel sweeps N·K flattened rows instead of
    N·M — the pruned pairs never reach the kernel grid.
    """
    interp = _on_cpu() if interpret is None else interpret
    cq, dq, ms = fuzzy.normalized_inputs(gains, counts, staleness,
                                         data_max=data_max)
    n, k = cand_idx.shape
    cq_k = jnp.take_along_axis(cq, cand_idx, axis=1)
    flat = _score_rows(cq_k.reshape(-1),
                       jnp.broadcast_to(dq[:, None], (n, k)).reshape(-1),
                       jnp.broadcast_to(ms[:, None], (n, k)).reshape(-1),
                       block_r, interp)
    return flat.reshape(n, k)


# ---------------------------------------------------------------------------
# Fused NOMA SIC rates
# ---------------------------------------------------------------------------

def _sic_kernel(pi_ref, gi_ref, mi_ref, pj_ref, gj_ref, mj_ref, out_ref,
                intf_ref, *, block_i: int, block_j: int, noise_w: float,
                bandwidth_hz: float):
    ii = pl.program_id(1)
    ij = pl.program_id(2)
    nj = pl.num_programs(2)

    @pl.when(ij == 0)
    def _init():
        intf_ref[...] = jnp.zeros_like(intf_ref)

    rx_i = pi_ref[0] * gi_ref[:, 0] * mi_ref[:, 0]             # (bI,)
    rx_j = pj_ref[0] * gj_ref[:, 0] * mj_ref[:, 0]             # (bJ,)
    i_pos = ii * block_i + jax.lax.broadcasted_iota(
        jnp.int32, (block_i, block_j), 0)
    j_pos = ij * block_j + jax.lax.broadcasted_iota(
        jnp.int32, (block_i, block_j), 1)
    # decoded after me ⇔ strictly weaker received power, index tie-break —
    # the exact ``noma.sic_sinr`` order
    weaker = (rx_j[None, :] < rx_i[:, None]) | \
        ((rx_j[None, :] == rx_i[:, None]) & (j_pos > i_pos))
    intf_ref[...] += jnp.sum(jnp.where(weaker, rx_j[None, :], 0.0), axis=1)

    @pl.when(ij == nj - 1)
    def _finish():
        sinr = rx_i / (intf_ref[...] + noise_w)
        out_ref[:, 0] = bandwidth_hz * jnp.log2(1.0 + sinr) * mi_ref[:, 0]


@functools.partial(jax.jit, static_argnames=("bandwidth_hz", "noise_w",
                                             "block_n", "interpret"))
def sic_rates(power_w: jnp.ndarray, gains: jnp.ndarray, mask: jnp.ndarray,
              *, bandwidth_hz: float, noise_w: float, block_n: int = 256,
              interpret: bool | None = None) -> jnp.ndarray:
    """(N,) power, (N, M) gains, (N, M) mask -> (N, M) SIC rates; masked
    entries are zero.  One ``pallas_call`` covers every edge."""
    interp = _on_cpu() if interpret is None else interpret
    n, m = gains.shape
    block_n = min(block_n, n)
    padded = -(-n // block_n) * block_n
    pad = padded - n
    p = jnp.pad(power_w.astype(jnp.float32), (0, pad)).reshape(1, padded)
    g = jnp.pad(gains.astype(jnp.float32), ((0, pad), (0, 0)))
    mk = jnp.pad(mask.astype(jnp.float32), ((0, pad), (0, 0)))
    nb = padded // block_n

    kernel = functools.partial(_sic_kernel, block_i=block_n,
                               block_j=block_n, noise_w=noise_w,
                               bandwidth_hz=bandwidth_hz)
    p_i = pl.BlockSpec((1, block_n), lambda e, i, j: (0, i))
    p_j = pl.BlockSpec((1, block_n), lambda e, i, j: (0, j))
    col_i = pl.BlockSpec((block_n, 1), lambda e, i, j: (i, e))
    col_j = pl.BlockSpec((block_n, 1), lambda e, i, j: (j, e))
    out = pl.pallas_call(
        kernel,
        grid=(m, nb, nb),
        in_specs=[p_i, col_i, col_i, p_j, col_j, col_j],
        out_specs=pl.BlockSpec((block_n, 1), lambda e, i, j: (i, e)),
        out_shape=jax.ShapeDtypeStruct((padded, m), jnp.float32),
        scratch_shapes=[pltpu.VMEM((block_n,), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interp,
    )(p, g, mk, p, g, mk)
    return out[:n]


# ---------------------------------------------------------------------------
# Fused local SGD (DESIGN.md §13.3)
# ---------------------------------------------------------------------------

_PARAM_KEYS = ("w1", "b1", "w2", "b2", "w3", "b3")


def _sgd_kernel(w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref,
                bx_ref, by_ref,
                ow1_ref, ob1_ref, ow2_ref, ob2_ref, ow3_ref, ob3_ref,
                *, tau1: int, lr: float, batch: int):
    """One client's τ₁ Eq. 11 SGD steps, entirely in VMEM.

    The τ₁ loop is a python unroll (τ₁ is a static config constant, 1–4
    in every config), so params and activations stay register/VMEM
    resident across steps — nothing writes back until the final update.
    Backward is the hand CE/ReLU chain: dlogits = (softmax − onehot)/B,
    then two transposed GEMMs per layer.
    """
    w1, b1 = w1_ref[0], b1_ref[0]
    w2, b2 = w2_ref[0], b2_ref[0]
    w3, b3 = w3_ref[0], b3_ref[0]
    inv_b = 1.0 / float(batch)
    for t in range(tau1):
        x = bx_ref[t, 0]                                       # (B, D)
        y = by_ref[t, 0]                                       # (B,)
        h1p = jnp.dot(x, w1) + b1
        h1 = jnp.maximum(h1p, 0.0)
        h2p = jnp.dot(h1, w2) + b2
        h2 = jnp.maximum(h2p, 0.0)
        logits = jnp.dot(h2, w3) + b3                          # (B, V)
        zmax = jnp.max(logits, axis=-1, keepdims=True)
        ez = jnp.exp(logits - zmax)
        probs = ez / jnp.sum(ez, axis=-1, keepdims=True)
        onehot = (y[:, None] == jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, 1)).astype(jnp.float32)
        dl = (probs - onehot) * inv_b                          # (B, V)
        dw3 = jnp.dot(h2.T, dl)
        db3 = jnp.sum(dl, axis=0)
        dh2 = jnp.dot(dl, w3.T) * (h2p > 0.0)
        dw2 = jnp.dot(h1.T, dh2)
        db2 = jnp.sum(dh2, axis=0)
        dh1 = jnp.dot(dh2, w2.T) * (h1p > 0.0)
        dw1 = jnp.dot(x.T, dh1)
        db1 = jnp.sum(dh1, axis=0)
        w1 = w1 - lr * dw1
        b1 = b1 - lr * db1
        w2 = w2 - lr * dw2
        b2 = b2 - lr * db2
        w3 = w3 - lr * dw3
        b3 = b3 - lr * db3
    ow1_ref[0], ob1_ref[0] = w1, b1
    ow2_ref[0], ob2_ref[0] = w2, b2
    ow3_ref[0], ob3_ref[0] = w3, b3


@functools.partial(jax.jit, static_argnames=("lr", "interpret"))
def local_sgd_step(params, bx: jnp.ndarray, by: jnp.ndarray, *, lr: float,
                   interpret: bool | None = None):
    """The fused cohort local-SGD stage: τ₁ minibatch-SGD steps for every
    lane of the stacked K-client cohort in ONE ``pallas_call``.

    params: the engine's stacked MLP pytree, leaves (K, …) over
    ``("w1", "b1", "w2", "b2", "w3", "b3")``; bx (τ₁, K, B, D) pre-gathered
    minibatches; by (τ₁, K, B) int labels.  Returns the updated pytree.
    The grid is (K,) — one client block per program; its six param leaves
    plus all τ₁ minibatches fit VMEM at the MNIST-scale shapes (≪ 1 MB),
    so the whole τ₁ chain runs without touching HBM.
    """
    interp = _on_cpu() if interpret is None else interpret
    tau1, k, b, _ = bx.shape
    leaves = [params[n].astype(jnp.float32) for n in _PARAM_KEYS]

    def block(leaf):
        shape = (1,) + leaf.shape[1:]
        return pl.BlockSpec(shape, lambda i, nd=leaf.ndim: (i,) + (0,) *
                            (nd - 1))

    p_specs = [block(l) for l in leaves]
    bx_spec = pl.BlockSpec((tau1, 1, b, bx.shape[3]),
                           lambda i: (0, i, 0, 0))
    by_spec = pl.BlockSpec((tau1, 1, b), lambda i: (0, i, 0))
    kernel = functools.partial(_sgd_kernel, tau1=tau1, lr=lr, batch=b)
    out = pl.pallas_call(
        kernel,
        grid=(k,),
        in_specs=p_specs + [bx_spec, by_spec],
        out_specs=[block(l) for l in leaves],
        out_shape=[jax.ShapeDtypeStruct(l.shape, jnp.float32)
                   for l in leaves],
        compiler_params=_CompilerParams(dimension_semantics=("parallel",)),
        interpret=interp,
    )(*leaves, bx.astype(jnp.float32), by.astype(jnp.int32))
    return dict(zip(_PARAM_KEYS, out))
