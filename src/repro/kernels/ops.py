"""Jit'd public wrappers around the Pallas kernels.

The model code uses (B, S, H, D) activations; the kernels use head-major
(B, H, S, D).  On CPU (this container) the wrappers run the kernels in
interpret mode automatically; on TPU they compile for real.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as _fa
from repro.kernels import linear_recurrence as _lr
from repro.kernels import ref as _ref


def _on_cpu() -> bool:
    return jax.default_backend() == "cpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0, block_q: int = 128,
                    block_k: int = 256,
                    interpret: bool | None = None) -> jnp.ndarray:
    """q (B, S, H, D), k/v (B, S, KV, D) -> (B, S, H, D).

    Differentiable: the forward runs the Pallas kernel; the backward is a
    recompute against the jnp oracle (`custom_vjp`) — the same O(S·D) HBM
    class as a dedicated flash backward kernel, traded for simplicity.
    """
    interp = _on_cpu() if interpret is None else interpret

    def oracle(qt, kt, vt):
        return _ref.attention_ref(qt, kt, vt, causal=causal, window=window)

    @jax.custom_vjp
    def fa(qt, kt, vt):
        return _fa.flash_attention(qt, kt, vt, causal=causal, window=window,
                                   block_q=block_q, block_k=block_k,
                                   interpret=interp)

    def fwd(qt, kt, vt):
        return fa(qt, kt, vt), (qt, kt, vt)

    def bwd(res, g):
        _, vjp = jax.vjp(oracle, *res)
        return vjp(g)

    fa.defvjp(fwd, bwd)
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)
    return fa(qt, kt, vt).transpose(0, 2, 1, 3)


@functools.partial(jax.jit, static_argnames=("block_t", "block_c",
                                             "interpret"))
def linear_recurrence(log_a: jnp.ndarray, x: jnp.ndarray, *,
                      block_t: int = 256, block_c: int = 128,
                      interpret: bool | None = None) -> jnp.ndarray:
    """log_a, x (B, S, C) -> (B, S, C) fp32."""
    interp = _on_cpu() if interpret is None else interpret
    return _lr.linear_recurrence(log_a, x, block_t=block_t, block_c=block_c,
                                 interpret=interp)
