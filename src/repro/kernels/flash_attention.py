"""Flash attention Pallas-TPU kernel (causal / sliding-window, GQA).

TPU adaptation of the paper-era flash algorithm (DESIGN.md §3: the HFL paper
itself has no kernel — this serves the substrate's big-model hot spot):

* grid = (B, H, nQ, nK) with the K-block axis innermost ("arbitrary"
  dimension semantics): the online-softmax state for one (b, h, q-block)
  lives in VMEM scratch across the nK sweep, so the (S, S) score matrix
  never exists and HBM traffic is O(S·D) per head.
* BlockSpecs tile Q/O as (1, 1, block_q, D) and K/V as (1, 1, block_k, D)
  in VMEM; the K/V index map folds the GQA group so Q head h reads KV head
  h // (H // KV) — MQA/GQA need no materialised head broadcast.
* block_q/block_k default to 128/256 — multiples of the 128-lane MXU tile
  for D ∈ {64, 128, 256}.
* Causal masking is positional inside the block; fully-above-diagonal
  K-blocks short-circuit (``@pl.when``) so the causal sweep does ~half the
  work, and sliding-window masking likewise skips blocks left of the window.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._compat import CompilerParams as _CompilerParams

NEG_INF = -1.0e38


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 block_q: int, block_k: int, seq_len: int, causal: bool,
                 window: int, scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_start = iq * block_q
    k_start = ik * block_k

    # Block-level skip: causal blocks entirely above the diagonal and
    # sliding-window blocks entirely left of the window contribute nothing.
    run = True
    if causal:
        run = jnp.logical_and(run, k_start <= q_start + block_q - 1)
    if window:
        run = jnp.logical_and(run, k_start + block_k - 1 > q_start - window)

    @pl.when(run)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                  # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)

        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
        allowed = k_pos < seq_len
        if causal:
            allowed = jnp.logical_and(allowed, k_pos <= q_pos)
        if window:
            allowed = jnp.logical_and(allowed, k_pos > q_pos - window)
        s = jnp.where(allowed, s, NEG_INF)

        m_prev = m_ref[:, 0]                                 # (bq,)
        m_cur = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_cur[:, None])
        p = jnp.where(allowed, p, 0.0)
        alpha = jnp.exp(m_prev - m_cur)
        l_ref[:, 0] = l_ref[:, 0] * alpha + jnp.sum(p, axis=1)
        v = v_ref[0, 0].astype(jnp.float32)                  # (bk, D)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv
        m_ref[:, 0] = m_cur

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_ref[:, 0], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 256,
                    interpret: bool = False) -> jnp.ndarray:
    """q (B, H, S, D), k/v (B, KV, S, D) -> (B, H, S, D).

    S must be a multiple of max(block_q, block_k); D should be a multiple
    of 128 on real TPUs (any D works in interpret mode).
    """
    b, h, s, d = q.shape
    kv = k.shape[1]
    group = h // kv
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    nq = s // block_q
    nk = s // block_k
    grid = (b, h, nq, nk)

    kernel = functools.partial(
        _attn_kernel, block_q=block_q, block_k=block_k, seq_len=s,
        causal=causal, window=window, scale=d ** -0.5)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d),
                         lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, iq, ik, group=group:
                         (b_, h_ // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda b_, h_, iq, ik, group=group:
                         (b_, h_ // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d),
                               lambda b_, h_, iq, ik: (b_, h_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(q, k, v)
