"""Version shims for jax.experimental.pallas across the jax versions this
repo meets (the container pins jax 0.4.x; TPU targets run newer)."""
from __future__ import annotations

from jax.experimental.pallas import tpu as pltpu

# jax<0.5 spells it TPUCompilerParams; keep both working.
CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")
