"""RecurrentGemma-9B — Griffin hybrid: RG-LRU + local attention, 1:2.

[arXiv:2402.19427] 38L d_model=4096 16H (GQA kv=1 ⇒ MQA) d_ff=12288
vocab=256000.  Pattern unit: (rec, rec, swa) with sliding window 2048, i.e.
one local-attention layer per two recurrent layers.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    arch_type="hybrid",
    source="arXiv:2402.19427",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_head=256,
    d_ff=12288,
    vocab_size=256_000,
    block_pattern=("rec", "rec", "swa"),
    ffn_pattern=("dense", "dense", "dense"),
    window=2048,
    rnn_width=4096,
    activation="gelu",
    embed_scale=True,
    tie_embeddings=True,
    rope_theta=10_000.0,
    supports_long_context=True,
    long_context_note="RG-LRU recurrence + bounded local-attention window",
)
