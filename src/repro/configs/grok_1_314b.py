"""Grok-1 314B — MoE, 8 experts top-2.

[hf:xai-org/grok-1] 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072.
Every layer is MoE (8 experts, top-2).  Expert-tensor hybrid sharding:
8 experts < 16 model shards, so d_ff shards over `model` and experts stay a
replicated leading dim.  bf16 params + bf16 Adam moments to fit one v5e pod.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    arch_type="moe",
    source="hf:xai-org/grok-1",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_head=128,
    d_ff=0,
    vocab_size=131_072,
    block_pattern=("attn",),
    ffn_pattern=("moe",),
    moe_experts=8,
    moe_top_k=2,
    moe_d_ff=32_768,
    tie_embeddings=False,
    param_dtype_str="bfloat16",
    opt_dtype_str="bfloat16",
    supports_long_context=False,
    long_context_note="pure full attention; 500k decode skipped",
)
