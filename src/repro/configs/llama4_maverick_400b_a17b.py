"""Llama-4 Maverick 400B-A17B — MoE 128 experts top-1, iRoPE attention.

[hf:meta-llama/Llama-4-Scout-17B-16E (family card)] 48L d_model=5120 40H
(GQA kv=8) d_ff=8192 vocab=202048.  MoE layers interleave with dense layers
(every other layer routed), and attention follows the llama4 iRoPE pattern:
3 chunked-local layers (RoPE, chunk 8192) per 1 global layer (NoPE).  The
global layers make decode O(seq) — not quadratic — so long_500k runs.
bf16 params + bf16 Adam moments to fit one v5e pod.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    arch_type="moe",
    source="hf:meta-llama/Llama-4-Scout-17B-16E",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    d_head=128,
    d_ff=8192,
    vocab_size=202_048,
    block_pattern=("chunked", "chunked", "chunked", "attn"),
    ffn_pattern=("dense", "moe", "dense", "moe"),
    attn_chunk=8192,
    attn_seq_shard=True,   # 40H doesn't divide model=16: context parallelism
    rope_on_global=False,
    moe_experts=128,
    moe_top_k=1,
    moe_d_ff=8192,
    tie_embeddings=False,
    param_dtype_str="bfloat16",
    opt_dtype_str="bfloat16",
    supports_long_context=True,
    long_context_note="chunked-local layers bounded; global layers O(seq) "
                      "at decode with NoPE",
)
