"""Qwen3-8B — dense GQA with per-head q/k RMSNorm.

[hf:Qwen/Qwen3-8B] 36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936.
qk_norm is the Qwen3 signature.  The faithful config is full attention
(long_500k skipped); ``qwen3_8b_sw`` registers the beyond-paper
sliding-window serve variant that enables long_500k.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-8b",
    arch_type="dense",
    source="hf:Qwen/Qwen3-8B",
    n_layers=36,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_head=128,
    d_ff=12_288,
    vocab_size=151_936,
    block_pattern=("attn",),
    ffn_pattern=("dense",),
    qk_norm=True,
    tie_embeddings=False,
    rope_theta=1_000_000.0,
    supports_long_context=False,
    long_context_note="faithful config is full attention; see qwen3-8b-sw4k",
)
