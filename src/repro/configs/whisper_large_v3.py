"""Whisper-large-v3 — encoder-decoder audio backbone.

[arXiv:2212.04356] 32L d_model=1280 20H (kv=20 ⇒ MHA) d_ff=5120 vocab=51866.
Enc-dec: 32 encoder + 32 decoder layers (whisper-large has 32+32).  The
mel-spectrogram + conv frontend is a STUB: ``input_specs`` provides 1500
precomputed frame embeddings.  LayerNorm, non-gated GELU MLP with biases,
QKV bias — the whisper signature.  long_500k skipped: full-attention decoder
(real whisper context is 448 tokens; decode_32k lowers the backbone as
assigned).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-large-v3",
    arch_type="audio",
    source="arXiv:2212.04356",
    n_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_head=64,
    d_ff=5120,
    vocab_size=51_866,
    attn_seq_shard=True,   # 56H/20H don't divide model=16: context parallelism
    block_pattern=("attn",),
    ffn_pattern=("dense",),
    encoder_layers=32,
    stub_frames=1500,
    norm="layernorm",
    activation="gelu",
    gated_mlp=False,
    mlp_bias=True,
    qkv_bias=True,
    tie_embeddings=True,
    supports_long_context=False,
    long_context_note="full-attention decoder; 500k decode skipped",
)
