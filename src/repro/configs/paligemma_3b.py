"""PaliGemma-3B — VLM: SigLIP vision encoder + gemma decoder.

[arXiv:2407.07726] 18L d_model=2048 8H (GQA kv=1 ⇒ MQA) d_ff=16384
vocab=257216.  The SigLIP encoder + projector is a STUB per the assignment:
``input_specs`` provides 256 precomputed patch embeddings that are prepended
to the text tokens and attended with a prefix-LM mask (bidirectional over the
multimodal prefix, causal afterwards) as in the paper.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    arch_type="vlm",
    source="arXiv:2407.07726",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    d_head=256,
    d_ff=16_384,
    vocab_size=257_216,
    block_pattern=("attn",),
    ffn_pattern=("dense",),
    prefix_tokens=256,
    activation="gelu",
    embed_scale=True,
    tie_embeddings=True,
    supports_long_context=False,
    long_context_note="pure full attention; 500k decode skipped",
)
