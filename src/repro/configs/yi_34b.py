"""Yi-34B — llama-architecture dense GQA.

[arXiv:2403.04652] 60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="yi-34b",
    arch_type="dense",
    source="arXiv:2403.04652",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_head=128,
    d_ff=20_480,
    vocab_size=64_000,
    attn_seq_shard=True,   # 56H/20H don't divide model=16: context parallelism
    block_pattern=("attn",),
    ffn_pattern=("dense",),
    tie_embeddings=False,
    rope_theta=5_000_000.0,
    supports_long_context=False,
    long_context_note="pure full attention; 500k decode skipped",
)
