"""StableLM-2-1.6B — dense, full MHA.

[hf:stabilityai/stablelm-2-1_6b] 24L d_model=2048 32H (kv=32 ⇒ MHA)
d_ff=5632 vocab=100352.  LayerNorm (stablelm-2 uses LayerNorm), gated silu MLP.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-1.6b",
    arch_type="dense",
    source="hf:stabilityai/stablelm-2-1_6b",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_head=64,
    d_ff=5632,
    vocab_size=100_352,
    block_pattern=("attn",),
    ffn_pattern=("dense",),
    norm="layernorm",
    tie_embeddings=True,
    supports_long_context=False,
    long_context_note="pure full attention; 500k decode skipped",
)
