"""Config registry: ``get_config("<arch-id>")`` -> ArchConfig.

Every assigned architecture id maps to its module; ``qwen3-8b-sw4k`` is the
beyond-paper sliding-window serve variant and ``hfl-mnist`` is the paper's
own experiment config (a different dataclass — the HFL simulation).
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.configs.base import (ArchConfig, InputShape, INPUT_SHAPES,
                                input_specs, shape_applicable)

_REGISTRY: Dict[str, str] = {
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "grok-1-314b": "repro.configs.grok_1_314b",
    "paligemma-3b": "repro.configs.paligemma_3b",
    "xlstm-125m": "repro.configs.xlstm_125m",
    "stablelm-1.6b": "repro.configs.stablelm_1_6b",
    "qwen1.5-110b": "repro.configs.qwen1_5_110b",
    "qwen3-8b": "repro.configs.qwen3_8b",
    "qwen3-8b-sw4k": "repro.configs.qwen3_8b_sw4k",
    "llama4-maverick-400b-a17b": "repro.configs.llama4_maverick_400b_a17b",
    "yi-34b": "repro.configs.yi_34b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "hfl-mnist": "repro.configs.hfl_mnist",
}

# The 10 assigned architectures (order of the assignment table).
ASSIGNED: List[str] = [
    "recurrentgemma-9b", "grok-1-314b", "paligemma-3b", "xlstm-125m",
    "stablelm-1.6b", "qwen1.5-110b", "qwen3-8b",
    "llama4-maverick-400b-a17b", "yi-34b", "whisper-large-v3",
]


def get_config(name: str):
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return importlib.import_module(_REGISTRY[name]).CONFIG


def list_archs() -> List[str]:
    return list(_REGISTRY)
