"""Qwen1.5-110B — dense GQA with QKV bias.

[hf:Qwen/Qwen1.5-0.5B (family card)] 80L d_model=8192 64H (GQA kv=8)
d_ff=49152 vocab=152064.  The QKV bias is the Qwen1.5 family signature.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen1.5-110b",
    arch_type="dense",
    source="hf:Qwen/Qwen1.5-0.5B",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_head=128,
    d_ff=49_152,
    vocab_size=152_064,
    block_pattern=("attn",),
    ffn_pattern=("dense",),
    qkv_bias=True,
    tie_embeddings=False,
    rope_theta=1_000_000.0,
    supports_long_context=False,
    long_context_note="pure full attention; 500k decode skipped",
)
