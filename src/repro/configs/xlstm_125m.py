"""xLSTM-125M — sLSTM + mLSTM blocks, alternating 1:1.

[arXiv:2405.04517] 12L d_model=768 4H d_ff=0 vocab=50304.  d_ff=0 means the
feed-forward capacity lives inside the blocks (mLSTM pf=2 up-projection,
sLSTM pf=4/3 post-projection), per the paper.  Fully recurrent ⇒ O(1) decode
state, so long_500k runs.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-125m",
    arch_type="ssm",
    source="arXiv:2405.04517",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_head=192,
    d_ff=0,
    vocab_size=50_304,
    block_pattern=("mlstm", "slstm"),
    ffn_pattern=("none", "none"),
    tie_embeddings=True,
    supports_long_context=True,
    long_context_note="recurrent state only — O(1) memory per step",
)
