"""Analytic parameter counting per ArchConfig (mirrors the init pytrees).

Used for MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE) in the roofline
analysis, and sanity-checked against actual init shapes in tests.
"""
from __future__ import annotations


def _attn_params(cfg) -> int:
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    n = d * h * dh + 2 * d * kv * dh + h * dh * d
    if cfg.qkv_bias:
        n += (h + 2 * kv) * dh
    if cfg.qk_norm:
        n += 2 * dh
    return n


def _mlp_params(cfg) -> int:
    d, ff = cfg.d_model, cfg.d_ff
    n = d * ff * (3 if cfg.gated_mlp else 2)
    if cfg.mlp_bias:
        n += ff + d
    return n


def _moe_params(cfg, active_only: bool) -> int:
    d, ff, e, k = cfg.d_model, cfg.moe_d_ff, cfg.moe_experts, cfg.moe_top_k
    n_router = d * e
    n_experts = (k if active_only else e) * 3 * d * ff
    return n_router + n_experts


def _rec_params(cfg) -> int:
    d = cfg.d_model
    dr = cfg.rnn_width or d
    return (2 * d * dr            # w_in, w_gate_branch
            + 4 * dr + dr         # conv
            + 2 * dr * dr + 2 * dr  # w_a/b_a, w_x/b_x
            + dr                  # lambda
            + dr * d)             # w_out


def _slstm_params(cfg) -> int:
    d = cfg.d_model
    nh = cfg.n_heads
    dh = d // nh
    dff = int(4.0 / 3.0 * d)
    return (4 * d + d             # conv
            + d * 4 * d + 4 * d   # gates
            + 4 * nh * dh * dh    # recurrent block-diag
            + d                   # norm
            + 2 * d * dff + dff * d)


def _mlstm_params(cfg) -> int:
    d = cfg.d_model
    di = 2 * d
    nh = cfg.n_heads
    dh = di // nh
    return (2 * d * di            # up projections
            + 4 * di + di         # conv
            + 3 * di * nh * dh    # q, k, v
            + 2 * (di * nh + nh)  # gates
            + nh * dh             # norm
            + di * d)


def _norm_params(cfg) -> int:
    return cfg.d_model * (2 if cfg.norm == "layernorm" else 1)


def count_params(cfg, active_only: bool = False) -> int:
    d = cfg.d_model
    total = cfg.vocab_size * d * (1 if cfg.tie_embeddings else 2)
    total += _norm_params(cfg)  # final norm

    mixer = {"attn": _attn_params, "swa": _attn_params,
             "chunked": _attn_params, "rec": _rec_params,
             "slstm": _slstm_params, "mlstm": _mlstm_params}
    unit = len(cfg.block_pattern)
    for i in range(cfg.n_layers):
        kind = cfg.block_pattern[i % unit]
        ffn = cfg.ffn_pattern[i % unit]
        total += mixer[kind](cfg) + _norm_params(cfg)
        if ffn == "dense":
            total += _mlp_params(cfg) + _norm_params(cfg)
        elif ffn == "moe":
            total += _moe_params(cfg, active_only) + _norm_params(cfg)

    # enc-dec: encoder layers + per-decoder-layer cross attention + norms
    if cfg.encoder_layers:
        enc_layer = _attn_params(cfg) + _mlp_params(cfg) + 2 * _norm_params(cfg)
        total += cfg.encoder_layers * enc_layer + _norm_params(cfg)
        total += cfg.n_layers * (_attn_params(cfg) + _norm_params(cfg))
    return total
