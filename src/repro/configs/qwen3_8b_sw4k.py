"""Qwen3-8B sliding-window serve variant (beyond-paper).

Same weights-shape family as qwen3-8b but every layer uses a 4096-token
sliding window, which bounds the decode KV cache and makes long_500k
tractable.  This is the dense-arch sliding-window variant the assignment
allows for long-context decode.
"""
from repro.configs.qwen3_8b import CONFIG as _BASE

CONFIG = _BASE.replace(
    name="qwen3-8b-sw4k",
    block_pattern=("swa",),
    window=4096,
    supports_long_context=True,
    long_context_note="sliding-window variant: KV cache bounded at 4096",
)
