"""Architecture/config system.

``ArchConfig`` is a frozen dataclass (hashable → usable as a static jit arg)
describing one architecture.  ``input_specs`` builds ShapeDtypeStruct
stand-ins for every model input of an (arch × input-shape) combination —
weak-type-correct, shardable, and never allocating device memory, which is
what the multi-pod dry-run lowers against.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Assigned input shapes
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES: Dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# ArchConfig
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    arch_type: str                       # dense|moe|ssm|hybrid|vlm|audio
    source: str                          # citation from the assignment table
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    # layer pattern (repeating unit); ffn_pattern must match its length
    block_pattern: Tuple[str, ...] = ("attn",)
    ffn_pattern: Tuple[str, ...] = ("dense",)
    # attention details
    d_head: int = 0                      # 0 -> d_model // n_heads
    window: int = 0                      # sliding-window width ("swa" layers)
    attn_chunk: int = 0                  # chunk size ("chunked" layers)
    qk_norm: bool = False
    qkv_bias: bool = False
    attn_seq_shard: bool = False         # context parallelism when heads
                                         # don't divide the model axis
    rope_theta: float = 10_000.0
    rope_on_global: bool = True          # False => NoPE on "attn" layers (llama4)
    # MoE
    moe_experts: int = 0
    moe_top_k: int = 0
    moe_d_ff: int = 0
    moe_capacity_factor: float = 1.25
    moe_aux_weight: float = 0.01
    # recurrent
    rnn_width: int = 0                   # 0 -> d_model
    # misc
    norm: str = "rmsnorm"
    activation: str = "silu"
    gated_mlp: bool = True
    mlp_bias: bool = False
    tie_embeddings: bool = True
    embed_scale: bool = False            # gemma-style sqrt(d) embed scaling
    # enc-dec / multimodal stubs
    encoder_layers: int = 0
    prefix_tokens: int = 0               # VLM patch embeddings per example
    stub_frames: int = 0                 # audio encoder frames per example
    # numerics / memory policy
    param_dtype_str: str = "float32"
    compute_dtype_str: str = "bfloat16"
    opt_dtype_str: str = "float32"       # Adam moment dtype (bf16 for ≥300B)
    kv_cache_dtype_str: str = ""         # "" -> compute dtype; "float8_e4m3fn"
                                         # halves decode cache bytes (§Perf)
    remat: bool = True
    grad_accum: int = 1                  # microbatch count in train_step
    scan_layers: bool = True             # False => unrolled HLO (roofline
                                         # accounting mode: while-loop bodies
                                         # are cost-counted once by XLA)
    # long-context capability (drives long_500k run/skip)
    supports_long_context: bool = False
    long_context_note: str = ""

    def __post_init__(self):
        if self.d_head == 0:
            object.__setattr__(self, "d_head", self.d_model // self.n_heads)
        assert len(self.block_pattern) == len(self.ffn_pattern), self.name
        assert self.n_heads % max(self.n_kv_heads, 1) == 0, self.name

    # dtypes kept as strings for hashability
    @property
    def param_dtype(self):
        return jnp.dtype(self.param_dtype_str)

    @property
    def compute_dtype(self):
        return jnp.dtype(self.compute_dtype_str)

    @property
    def opt_dtype(self):
        return jnp.dtype(self.opt_dtype_str)

    @property
    def kv_cache_dtype(self):
        return jnp.dtype(self.kv_cache_dtype_str or self.compute_dtype_str)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    def reduced(self) -> "ArchConfig":
        """Smoke-test variant: ≤2 pattern units, d_model≤256, ≤4 experts."""
        unit = len(self.block_pattern)
        d_model = min(self.d_model, 256)
        n_heads = min(self.n_heads, 4)
        n_kv = min(self.n_kv_heads, n_heads)
        kw: Dict[str, Any] = dict(
            name=self.name + "-reduced",
            n_layers=min(self.n_layers, max(2, unit)),
            d_model=d_model,
            n_heads=n_heads,
            n_kv_heads=max(1, n_kv if n_heads % n_kv == 0 else 1),
            d_head=d_model // n_heads,
            d_ff=min(self.d_ff, 512) if self.d_ff else 0,
            vocab_size=min(self.vocab_size, 512),
            window=min(self.window, 32) if self.window else 0,
            attn_chunk=min(self.attn_chunk, 32) if self.attn_chunk else 0,
            rnn_width=min(self.rnn_width, d_model) if self.rnn_width else 0,
            encoder_layers=min(self.encoder_layers, 2),
            prefix_tokens=min(self.prefix_tokens, 8),
            stub_frames=min(self.stub_frames, 16),
            remat=False,
            param_dtype_str="float32",
            compute_dtype_str="float32",
        )
        if self.moe_experts:
            kw.update(moe_experts=min(self.moe_experts, 4),
                      moe_top_k=min(self.moe_top_k, 2),
                      moe_d_ff=min(self.moe_d_ff, 256))
        return self.replace(**kw)

    # -- parameter/FLOP accounting (roofline §) --------------------------------

    def param_count(self) -> int:
        """Analytic total parameter count (matches the init pytree)."""
        import numpy as np
        from repro.configs._counting import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.configs._counting import count_params
        return count_params(self, active_only=True)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins, no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def input_specs(cfg: ArchConfig, shape: InputShape) -> Dict[str, Any]:
    """Model inputs for one (arch × input shape) as ShapeDtypeStructs.

    train/prefill: {"tokens", "labels"?, "embeddings"?}
    decode:        {"token", "cache", "index"}
    """
    b, s = shape.global_batch, shape.seq_len
    if shape.kind in ("train", "prefill"):
        specs: Dict[str, Any] = {}
        text_len = s
        if cfg.prefix_tokens:                       # VLM: patches use positions
            text_len = s - cfg.prefix_tokens
            specs["embeddings"] = _sds((b, cfg.prefix_tokens, cfg.d_model),
                                       cfg.compute_dtype)
        if cfg.stub_frames:                         # audio: encoder frames
            specs["embeddings"] = _sds((b, cfg.stub_frames, cfg.d_model),
                                       cfg.compute_dtype)
        specs["tokens"] = _sds((b, text_len), jnp.int32)
        if shape.kind == "train":
            specs["labels"] = _sds((b, text_len), jnp.int32)
        return specs

    # decode
    from repro.models import build_model
    model = build_model(cfg)
    if cfg.encoder_layers:
        cache_shape = jax.eval_shape(
            functools.partial(model.init_cache, b, s, cfg.stub_frames))
    else:
        cache_shape = jax.eval_shape(functools.partial(model.init_cache, b, s))
    return {
        "token": _sds((b, 1), jnp.int32),
        "cache": cache_shape,
        "index": _sds((), jnp.int32),
    }


def shape_applicable(cfg: ArchConfig, shape: InputShape) -> Tuple[bool, str]:
    """Whether an (arch × shape) pair runs, and the skip reason if not."""
    if shape.name == "long_500k" and not cfg.supports_long_context:
        return False, cfg.long_context_note or \
            "pure full-attention architecture: 500k context is quadratic"
    return True, ""
