"""The paper's own experiment config: HFL over NOMA on MNIST-scale data.

64 clients, 4 edge servers, N_m = 4 clients admitted per edge server per
round (paper §V), MLP classifier, synthetic MNIST-like data (offline
container), IID or Dirichlet non-IID partitions.
"""
import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class HFLConfig:
    name: str = "hfl-mnist"
    # topology (paper §V)
    n_clients: int = 64
    n_edges: int = 4
    clients_per_edge: int = 4          # N_m
    area_side_m: float = 500.0
    semi_sync_fraction: float = 0.5    # M_c / M edge servers per cloud round
    # learning
    input_dim: int = 784
    hidden: int = 128
    n_classes: int = 10
    lr: float = 0.01                   # η (paper Table II)
    local_batch: int = 32
    local_accuracy_theta: float = 0.5  # θ
    edge_accuracy_xi: float = 0.5      # ξ
    mu_const: float = 2.0              # μ in τ₁ = μ log(1/θ)
    delta_const: float = 2.0           # δ in τ₂ = δ log(1/ξ)/(1-θ)
    # wireless (paper Table II)
    bandwidth_hz: float = 1e6
    carrier_hz: float = 1e9
    noise_dbm_per_hz: float = -174.0
    path_loss_exponent: float = 3.76
    p_min_w: float = 0.01
    p_max_w: float = 0.1
    cycles_per_sample: float = 1e7     # c_n
    capacitance: float = 1e-28         # β_n
    f_min_hz: float = 1e9
    f_max_hz: float = 10e9
    model_size_bits: float = 1e6       # d_n = 1 Mbit
    edge_model_size_bits: float = 1e6  # d_m
    edge_rate_bps: float = 20e6        # R_m (OFDMA edge->cloud)
    edge_power_w: float = 1.0          # p_m
    lambda_t: float = 0.5
    lambda_e: float = 0.5
    # data heterogeneity
    min_samples: int = 200
    max_samples: int = 1200
    dirichlet_alpha: float = 0.5
    data_noise: float = 0.9            # synthetic class-template noise

    @property
    def tau1(self) -> int:
        import math
        return max(1, round(self.mu_const * math.log(1.0 / self.local_accuracy_theta)))

    @property
    def tau2(self) -> int:
        import math
        return max(1, round(self.delta_const * math.log(1.0 / self.edge_accuracy_xi)
                            / (1.0 - self.local_accuracy_theta)))


CONFIG = HFLConfig()
