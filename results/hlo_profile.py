import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# Dry-run "profiler": compile one (arch x shape) and print the largest
# collective ops + largest tensors from the post-SPMD HLO.
import argparse
import re
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES, get_config, input_specs
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_prefill_step, make_serve_step, \
    make_train_step
from repro.launch.roofline import _shape_bytes, _group_size
from repro.sharding import input_shardings, param_shardings

ap = argparse.ArgumentParser()
ap.add_argument("--arch", required=True)
ap.add_argument("--shape", required=True)
ap.add_argument("--unroll", action="store_true")
ap.add_argument("--top", type=int, default=15)
args = ap.parse_args()

cfg = get_config(args.arch)
if args.unroll:
    cfg = cfg.replace(scan_layers=False)
shape = INPUT_SHAPES[args.shape]
mesh = make_production_mesh()
specs = input_specs(cfg, shape)
in_sh = input_shardings(specs, mesh, shape.global_batch)

with mesh:
    if shape.kind == "train":
        step_fn, model, _ = make_train_step(cfg)
        p_shapes = jax.eval_shape(model.init, jax.random.key(0))
        p_sh = param_shardings(p_shapes, mesh)
        o_sh = {"m": p_sh, "v": p_sh}
        fn = jax.jit(step_fn, in_shardings=(p_sh, o_sh, None, in_sh),
                     out_shardings=(p_sh, o_sh, None, None))
        compiled = fn.lower(p_shapes, {"m": p_shapes, "v": p_shapes},
                            jax.ShapeDtypeStruct((), jnp.int32),
                            specs).compile()
    elif shape.kind == "prefill":
        step_fn, model = make_prefill_step(cfg)
        p_shapes = jax.eval_shape(model.init, jax.random.key(0))
        p_sh = param_shardings(p_shapes, mesh)
        compiled = jax.jit(step_fn, in_shardings=(p_sh, in_sh)).lower(
            p_shapes, specs).compile()
    else:
        step_fn, model = make_serve_step(cfg)
        p_shapes = jax.eval_shape(model.init, jax.random.key(0))
        p_sh = param_shardings(p_shapes, mesh)
        fn = jax.jit(step_fn, in_shardings=(p_sh, in_sh["token"],
                                            in_sh["cache"], in_sh["index"]),
                     out_shardings=(in_sh["token"], in_sh["cache"]))
        compiled = fn.lower(p_shapes, specs["token"], specs["cache"],
                            specs["index"]).compile()

text = compiled.as_text()
rows = []
for line in text.splitlines():
    m = re.search(r"=\s*((?:\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?))\s+"
                  r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                  r"collective-permute)(-start)?\(", line)
    if not m:
        continue
    nbytes = _shape_bytes(m.group(1))
    g = _group_size(line)
    meta = re.search(r'op_name="([^"]*)"', line)
    rows.append((nbytes, m.group(2), g, (meta.group(1) if meta else "")[-110:]))
rows.sort(reverse=True)
print(f"== top {args.top} collectives (result bytes, kind, group) ==")
for nbytes, kind, g, name in rows[:args.top]:
    print(f"{nbytes/1e9:9.3f} GB  {kind:<19} g={g:<4} {name}")
print(f"total collective ops: {len(rows)}")
ca = compiled.cost_analysis()
print("flops/device:", ca.get("flops"), " bytes/device:",
      ca.get("bytes accessed"))
