"""Dry-run HLO "profiler": compile one target and print the largest ops /
tensors from the post-optimisation HLO, without running anything.

Two targets:

* LLM configs (the original mode) — compile one (arch × shape) on the
  512-placeholder-device production mesh and print the largest
  collectives:

    python results/hlo_profile.py --arch gpt_125m --shape train_4k

* the HFL round engine — compile the jitted ``round_step`` at an N×M
  size and print the largest ops/tensors by result bytes (the
  ``jax.named_scope`` stage names from ``repro.telemetry.spans`` show up
  in the op_name column, so every big tensor is attributable to
  associate/allocate/schedule/train/eval):

    python results/hlo_profile.py --round-engine 1024x16
    python results/hlo_profile.py --round-engine 4096x32 --candidates 8
    python results/hlo_profile.py --round-engine 1024x16 --telemetry

The arg parse happens BEFORE jax imports: the LLM mode needs
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` set first, and
the round-engine mode must NOT see it (a 512-way CPU "mesh" would just
slow the single-program compile down).
"""
import argparse
import os
import re
import sys

sys.path.insert(0, "src")

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default=None, help="LLM mode: config name")
ap.add_argument("--shape", default=None, help="LLM mode: input shape name")
ap.add_argument("--unroll", action="store_true")
ap.add_argument("--top", type=int, default=15)
ap.add_argument("--round-engine", default=None, metavar="NxM",
                help="HFL mode: compile round_step at N clients x M edges "
                     "(e.g. 1024x16) and print its largest ops/tensors")
ap.add_argument("--candidates", type=int, default=None, metavar="K",
                help="HFL mode: (N, K) candidate frontier")
ap.add_argument("--telemetry", action="store_true",
                help="HFL mode: compile with EngineSpec(telemetry=True)")
args = ap.parse_args()

if args.round_engine is None:
    if not (args.arch and args.shape):
        ap.error("either --arch + --shape (LLM mode) or --round-engine NxM")
    # the LLM dry-run wants the placeholder device farm; must be set
    # before jax initialises its backends
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import jax
import jax.numpy as jnp

from repro.launch.roofline import _shape_bytes, _group_size

_SHAPE_RE = (r"((?:\([^)]*\)|[a-z][a-z0-9]*\[[0-9,]*\](?:\{[^}]*\})?))")


def _print_cost(compiled):
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):     # older jax: one dict per device
        ca = ca[0] if ca else {}
    print("flops/device:", ca.get("flops"), " bytes/device:",
          ca.get("bytes accessed"))


def round_engine_main() -> None:
    import dataclasses

    from repro.configs.hfl_mnist import CONFIG
    from repro.core import engine

    try:
        n, m = (int(v) for v in args.round_engine.lower().split("x"))
    except ValueError:
        raise SystemExit("--round-engine expects NxM, e.g. 1024x16")
    cfg = dataclasses.replace(CONFIG, n_clients=n, n_edges=m,
                              clients_per_edge=4, min_samples=60,
                              max_samples=120, hidden=16, input_dim=32,
                              local_batch=16)
    spec = engine.EngineSpec(policy="gcea", scheduler="fastest",
                             candidates_k=args.candidates,
                             telemetry=args.telemetry)
    state, bundle, _ = engine.init_simulation(cfg, seed=0)
    compiled = jax.jit(engine.round_step, static_argnums=(0, 1)).lower(
        cfg, spec, state, bundle).compile()
    text = compiled.as_text()
    # every HLO op with its result shape; rank by result bytes
    pat = re.compile(r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*" + _SHAPE_RE
                     + r"\s+([\w\-]+)")
    rows = []
    for line in text.splitlines():
        mm = pat.match(line)
        if not mm:
            continue
        op = mm.group(2)
        if op in ("parameter", "constant", "tuple", "get-tuple-element"):
            continue
        meta = re.search(r'op_name="([^"]*)"', line)
        rows.append((_shape_bytes(mm.group(1)), op,
                     (meta.group(1) if meta else "")[-90:]))
    rows.sort(key=lambda r: (-r[0], r[1]))
    print(f"== round_step {n}x{m} "
          f"(candidates_k={args.candidates}, telemetry={args.telemetry}): "
          f"top {args.top} ops by result bytes ==")
    for nbytes, op, name in rows[:args.top]:
        print(f"{nbytes/1e6:10.3f} MB  {op:<24} {name}")
    print(f"total ops: {len(rows)}")
    _print_cost(compiled)


def llm_main() -> None:
    from repro.configs import INPUT_SHAPES, get_config, input_specs
    from repro.launch.mesh import make_production_mesh
    from repro.launch.steps import make_prefill_step, make_serve_step, \
        make_train_step
    from repro.sharding import input_shardings, param_shardings

    cfg = get_config(args.arch)
    if args.unroll:
        cfg = cfg.replace(scan_layers=False)
    shape = INPUT_SHAPES[args.shape]
    mesh = make_production_mesh()
    specs = input_specs(cfg, shape)
    in_sh = input_shardings(specs, mesh, shape.global_batch)

    with mesh:
        if shape.kind == "train":
            step_fn, model, _ = make_train_step(cfg)
            p_shapes = jax.eval_shape(model.init, jax.random.key(0))
            p_sh = param_shardings(p_shapes, mesh)
            o_sh = {"m": p_sh, "v": p_sh}
            fn = jax.jit(step_fn, in_shardings=(p_sh, o_sh, None, in_sh),
                         out_shardings=(p_sh, o_sh, None, None))
            compiled = fn.lower(p_shapes, {"m": p_shapes, "v": p_shapes},
                                jax.ShapeDtypeStruct((), jnp.int32),
                                specs).compile()
        elif shape.kind == "prefill":
            step_fn, model = make_prefill_step(cfg)
            p_shapes = jax.eval_shape(model.init, jax.random.key(0))
            p_sh = param_shardings(p_shapes, mesh)
            compiled = jax.jit(step_fn, in_shardings=(p_sh, in_sh)).lower(
                p_shapes, specs).compile()
        else:
            step_fn, model = make_serve_step(cfg)
            p_shapes = jax.eval_shape(model.init, jax.random.key(0))
            p_sh = param_shardings(p_shapes, mesh)
            fn = jax.jit(step_fn,
                         in_shardings=(p_sh, in_sh["token"],
                                       in_sh["cache"], in_sh["index"]),
                         out_shardings=(in_sh["token"], in_sh["cache"]))
            compiled = fn.lower(p_shapes, specs["token"], specs["cache"],
                                specs["index"]).compile()

    text = compiled.as_text()
    rows = []
    for line in text.splitlines():
        m = re.search(r"=\s*" + _SHAPE_RE + r"\s+"
                      r"(all-gather|all-reduce|reduce-scatter|all-to-all|"
                      r"collective-permute)(-start)?\(", line)
        if not m:
            continue
        nbytes = _shape_bytes(m.group(1))
        g = _group_size(line)
        meta = re.search(r'op_name="([^"]*)"', line)
        rows.append((nbytes, m.group(2), g,
                     (meta.group(1) if meta else "")[-110:]))
    rows.sort(reverse=True)
    print(f"== top {args.top} collectives (result bytes, kind, group) ==")
    for nbytes, kind, g, name in rows[:args.top]:
        print(f"{nbytes/1e9:9.3f} GB  {kind:<19} g={g:<4} {name}")
    print(f"total collective ops: {len(rows)}")
    _print_cost(compiled)


if args.round_engine is not None:
    round_engine_main()
else:
    llm_main()
