"""Render EXPERIMENTS.md tables from the dry-run jsonl records, the
paper's Figs. 8-12-style cost/accuracy comparisons from sweep summaries —
as markdown tables (``sweep``) or matplotlib panels (``plot``) — and the
telemetry RoundTrace views (``trace`` / ``traceplot``).

  python results/render_tables.py dryrun  results/dryrun.jsonl
  python results/render_tables.py roofline results/dryrun.jsonl
  python results/render_tables.py sweep   results/sweep_showcase
  python results/render_tables.py sweep   'results/sweep_*'     # glob ok
  python results/render_tables.py plot    results/sweep_showcase [out_dir]
  python results/render_tables.py trace   results/sweep_demo    # *.trace.json
  python results/render_tables.py trace   trace.jsonl           # sink file
  python results/render_tables.py traceplot results/sweep_demo [out_dir]

``sweep`` accepts a sweep directory, its summary.json path, or a glob of
either; each summary renders one table per metric (final accuracy, mean
round cost) with scenarios as rows and scheme columns (policy/allocator/
scheduler/NOMA), mean ± spread over seeds — the Figs. 8-12 protocol view.

``plot`` takes the same inputs and writes one PNG per summary × metric
(accuracy / cost vs round): one panel per scenario, one line per scheme,
mean over seeds with a ±std band — the figure view of the same protocol.
The per-round trajectories come from the per-cell JSON files next to each
summary.json (``run_sweep`` writes both).

``trace`` accepts a ``*.trace.json`` written by the sweep runner, a
``JsonlSink`` file streamed out of a driver, a sweep directory, or a glob
of any of those; each source renders one per-round markdown table of the
Eq. 23a cost decomposition (local / NOMA-uplink / edge→cloud, time and
energy) plus the association (deferred-acceptance sweeps, per-edge load),
scheduler (PDD iterations + residual) and SIC-depth internals.
``traceplot`` writes the same decomposition as a 4-panel PNG per source.
"""
import glob as _glob
import json
import math
import os
import sys
from collections import defaultdict


def load(path):
    try:
        return [json.loads(l) for l in open(path) if l.strip()]
    except FileNotFoundError:
        return []


def dryrun_table(recs):
    hdr = ("| arch | shape | mesh | status | lower s | compile s | "
           "args GB/dev | temp GB/dev | collectives |")
    sep = "|" + "---|" * 9
    rows = [hdr, sep]
    for r in recs:
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | skipped: "
                        f"{r['reason'][:48]} | | | | | |")
            continue
        mem = r.get("memory", {})
        coll = r.get("roofline", {}).get("coll_breakdown", {})
        coll_s = ",".join(f"{k.replace('all-','a')}:{v/1e9:.2f}GB"
                          for k, v in coll.items()) or "none"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} | "
            f"{r.get('lower_s','')} | {r.get('compile_s','')} | "
            f"{mem.get('argument_gb',0):.2f} | {mem.get('temp_gb',0):.1f} | "
            f"{coll_s} |")
    return "\n".join(rows)


def roofline_table(recs):
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL_FLOPS | useful ratio | what moves the dominant term |")
    sep = "|" + "---|" * 9
    rows = [hdr, sep]
    hints = {
        ("collective", "train"): "overlap grad all-reduce with bwd; "
                                 "reduce-scatter instead of all-reduce",
        ("collective", "other"): "re-shard activations to cut all-gathers",
        ("memory", "train"): "microbatching (grad_accum) + bf16 master",
        ("memory", "other"): "shrink/quantise the KV cache; fuse reads",
        ("compute", "train"): "remat policy: save attn outputs",
        ("compute", "other"): "larger decode batch per chip",
    }
    for r in recs:
        if "roofline" not in r:
            continue
        rf = r["roofline"]
        kind = "train" if r["shape"] == "train_4k" else "other"
        hint = hints.get((rf["dominant"], kind), "")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3g} | "
            f"{rf['memory_s']:.3g} | {rf['collective_s']:.3g} | "
            f"**{rf['dominant']}** | {rf['model_flops']:.3g} | "
            f"{rf['useful_ratio']:.3f} | {hint} |")
    return "\n".join(rows)


# ---------------------------------------------------------------------------
# Sweep summaries -> Figs. 8-12 comparison tables
# ---------------------------------------------------------------------------

def _parse_cell_id(cid):
    """scenario__policy__allocator__scheduler__(noma|oma)__sSEED ->
    (scenario, scheme label, seed)."""
    scenario, policy, allocator, scheduler, noma, seed = cid.rsplit("__", 5)
    return scenario, f"{policy}/{allocator}/{scheduler}/{noma}", int(seed[1:])


def _mean_std(vals):
    mean = sum(vals) / len(vals)
    if len(vals) < 2:
        return mean, 0.0
    var = sum((v - mean) ** 2 for v in vals) / (len(vals) - 1)
    return mean, math.sqrt(var)


def _fmt(mean, std, digits=3):
    if std == 0.0:
        return f"{mean:.{digits}f}"
    return f"{mean:.{digits}f} ± {std:.{digits}f}"


def sweep_tables(summary):
    """Markdown tables from one run_sweep summary dict."""
    # rows[metric][scenario][scheme] -> list over seeds
    rows = defaultdict(lambda: defaultdict(lambda: defaultdict(list)))
    for cid, final in summary["final"].items():
        scenario, scheme, _ = _parse_cell_id(cid)
        for metric in ("accuracy", "mean_cost"):
            rows[metric][scenario][scheme].append(float(final[metric]))
    titles = {"accuracy": "Final accuracy",
              "mean_cost": "Mean round cost (Eq. 23a)"}
    out = [f"## sweep `{summary['name']}` — {summary['n_cells']} cells, "
           f"{summary['n_rounds']} rounds, "
           f"{summary['n_compiles']} compiles"]
    scenario_order = summary.get("axes", {}).get("scenarios") or sorted(
        {s for m in rows.values() for s in m})
    for metric, title in titles.items():
        schemes = sorted({s for per in rows[metric].values() for s in per})
        out.append(f"\n### {title}\n")
        out.append("| scenario | " + " | ".join(schemes) + " |")
        out.append("|" + "---|" * (len(schemes) + 1))
        for scenario in scenario_order:
            if scenario not in rows[metric]:
                continue
            cells = []
            for scheme in schemes:
                vals = rows[metric][scenario].get(scheme)
                cells.append(_fmt(*_mean_std(vals)) if vals else "—")
            out.append(f"| {scenario} | " + " | ".join(cells) + " |")
    return "\n".join(out)


def _iter_summaries(path, with_dir=False):
    """Yield summary dicts from a dir / summary.json / glob of either."""
    matches = sorted(_glob.glob(path)) or [path]
    for p in matches:
        if os.path.isdir(p):
            p = os.path.join(p, "summary.json")
        if not os.path.exists(p):
            continue
        with open(p) as fh:
            summary = json.load(fh)
        yield (summary, os.path.dirname(p)) if with_dir else summary


def sweep_report(path):
    parts = [sweep_tables(s) for s in _iter_summaries(path)]
    if not parts:
        raise SystemExit(f"no sweep summary found under {path!r}")
    return "\n\n".join(parts)


# ---------------------------------------------------------------------------
# Sweep summaries -> Figs. 8-12 comparison PLOTS (matplotlib panels)
# ---------------------------------------------------------------------------

_PLOT_METRICS = {"accuracy": "Test accuracy",
                 "cost": "Round cost (Eq. 23a)"}


def _load_trajectories(summary, sweep_dir):
    """rows[metric][scenario][scheme] -> list over seeds of per-round
    lists, read from the per-cell JSON files ``run_sweep`` persisted next
    to the summary."""
    rows = defaultdict(lambda: defaultdict(lambda: defaultdict(list)))
    for cid in summary["final"]:
        cell_path = os.path.join(sweep_dir, f"{cid}.json")
        if not os.path.exists(cell_path):
            continue
        with open(cell_path) as fh:
            metrics = json.load(fh)["metrics"]
        scenario, scheme, _ = _parse_cell_id(cid)
        for metric in _PLOT_METRICS:
            rows[metric][scenario][scheme].append(metrics[metric])
    return rows


def _mean_std_curves(per_seed):
    """list-of-(R,)-lists -> (mean (R,), std (R,)) without numpy."""
    n, r = len(per_seed), len(per_seed[0])
    mean = [sum(s[i] for s in per_seed) / n for i in range(r)]
    if n < 2:
        return mean, [0.0] * r
    std = [math.sqrt(sum((s[i] - mean[i]) ** 2 for s in per_seed)
                     / (n - 1)) for i in range(r)]
    return mean, std


def sweep_plots(summary, sweep_dir, out_dir):
    """One PNG per metric: per-scenario panels, one line per scheme,
    mean over seeds with a ±std band.  Returns the written paths."""
    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    rows = _load_trajectories(summary, sweep_dir)
    scenario_order = summary.get("axes", {}).get("scenarios") or sorted(
        {s for m in rows.values() for s in m})
    written = []
    for metric, title in _PLOT_METRICS.items():
        scenarios = [s for s in scenario_order if s in rows[metric]]
        if not scenarios:
            continue
        schemes = sorted({sch for s in scenarios
                          for sch in rows[metric][s]})
        ncol = min(3, max(len(scenarios), 1))
        nrow = -(-len(scenarios) // ncol)
        fig, axes = plt.subplots(nrow, ncol, squeeze=False, sharex=True,
                                 figsize=(4.2 * ncol, 3.2 * nrow))
        for i, scenario in enumerate(scenarios):
            ax = axes[i // ncol][i % ncol]
            for j, scheme in enumerate(schemes):
                per_seed = rows[metric][scenario].get(scheme)
                if not per_seed:
                    continue
                mean, std = _mean_std_curves(per_seed)
                r = range(1, len(mean) + 1)
                color = f"C{j % 10}"
                ax.plot(r, mean, label=scheme, color=color, lw=1.6)
                if any(std):
                    lo = [m - s for m, s in zip(mean, std)]
                    hi = [m + s for m, s in zip(mean, std)]
                    ax.fill_between(r, lo, hi, color=color, alpha=0.15,
                                    lw=0)
            ax.set_title(scenario, fontsize=10)
            ax.set_xlabel("global round")
            ax.grid(True, alpha=0.3)
        for i in range(len(scenarios), nrow * ncol):
            axes[i // ncol][i % ncol].set_axis_off()
        axes[0][0].set_ylabel(title)
        # collect the legend across ALL panels: a scheme missing from the
        # first scenario must still be identifiable in the others
        by_label = {}
        for row in axes:
            for ax in row:
                for h, l in zip(*ax.get_legend_handles_labels()):
                    by_label.setdefault(l, h)
        fig.legend(by_label.values(), by_label.keys(), loc="lower center",
                   ncol=min(len(schemes), 4), fontsize=8, frameon=False)
        fig.suptitle(f"sweep `{summary['name']}` — {title}", fontsize=12)
        fig.tight_layout(rect=(0, 0.06, 1, 0.97))
        out = os.path.join(out_dir,
                           f"sweep_{summary['name']}_{metric}.png")
        fig.savefig(out, dpi=130)
        plt.close(fig)
        written.append(out)
    return written


# ---------------------------------------------------------------------------
# Telemetry RoundTrace -> per-stage cost-decomposition tables / panels
# ---------------------------------------------------------------------------

def _load_trace(path):
    """A trace source -> {leaf: per-round list}.  Accepts the sweep
    runner's ``*.trace.json`` (trace under a "trace" key) and a JSONL
    sink file (one object per round; re-sorted by round)."""
    if path.endswith(".jsonl"):
        rows = [json.loads(l) for l in open(path) if l.strip()]
        if not rows:
            return {}
        rows.sort(key=lambda r: r.get("round", 0))
        return {k: [r[k] for r in rows] for k in rows[0]}
    with open(path) as fh:
        data = json.load(fh)
    return data.get("trace", data)


def _iter_traces(path):
    """Yield (label, trace dict) from a file / sweep dir / glob."""
    matches = sorted(_glob.glob(path)) or [path]
    for p in matches:
        if os.path.isdir(p):
            for f in sorted(_glob.glob(os.path.join(p, "*.trace.json"))):
                label = os.path.basename(f)[:-len(".trace.json")]
                yield label, _load_trace(f)
            continue
        if os.path.exists(p):
            label = os.path.basename(p)
            for suf in (".trace.json", ".jsonl", ".json"):
                if label.endswith(suf):
                    label = label[:-len(suf)]
                    break
            yield label, _load_trace(p)


def trace_table(label, tr):
    """One per-round markdown table: the Eq. 23a decomposition by term +
    association/scheduler/SIC internals."""
    rounds = tr.get("round", [])
    out = [f"## trace `{label}` — {len(rounds)} rounds", ""]
    out.append("| round | t_local s | t_uplink s | t_cloud s | "
               "e_local J | e_uplink J | e_cloud J | sweeps | "
               "edge load | pdd it | residual | sic |")
    out.append("|" + "---|" * 12)
    for i, r in enumerate(rounds):
        load = tr["edge_load"][i]
        load_s = (f"{min(load)}–{max(load)}" if len(load) > 4
                  else "/".join(str(v) for v in load))
        out.append(
            f"| {r} | {tr['time_local_s'][i]:.4f} | "
            f"{tr['time_uplink_s'][i]:.4f} | {tr['time_cloud_s'][i]:.4f} | "
            f"{tr['energy_local_j'][i]:.4f} | "
            f"{tr['energy_uplink_j'][i]:.4f} | "
            f"{tr['energy_cloud_j'][i]:.4f} | {tr['assoc_sweeps'][i]} | "
            f"{load_s} | {tr['pdd_iters'][i]} | "
            f"{tr['pdd_residual'][i]:.2e} | {tr['sic_depth'][i]} |")
    return "\n".join(out)


def trace_report(path):
    parts = [trace_table(label, tr) for label, tr in _iter_traces(path)
             if tr]
    if not parts:
        raise SystemExit(f"no trace JSON/JSONL found under {path!r}")
    return "\n\n".join(parts)


def trace_plots(path, out_dir=None):
    """One 4-panel PNG per trace source: time decomposition, energy
    decomposition, association sweeps + SIC depth, PDD convergence."""
    import matplotlib
    matplotlib.use("Agg")
    import matplotlib.pyplot as plt

    written = []
    for label, tr in _iter_traces(path):
        if not tr:
            continue
        r = tr["round"]
        fig, axes = plt.subplots(2, 2, figsize=(9, 6.4), sharex=True)
        ax = axes[0][0]
        for k, lab in (("time_local_s", "local compute"),
                       ("time_uplink_s", "NOMA uplink"),
                       ("time_cloud_s", "edge→cloud")):
            ax.plot(r, tr[k], label=lab, lw=1.6)
        ax.set_ylabel("time (s)"); ax.legend(fontsize=7)
        ax = axes[0][1]
        for k, lab in (("energy_local_j", "local compute"),
                       ("energy_uplink_j", "NOMA uplink"),
                       ("energy_cloud_j", "edge→cloud")):
            ax.plot(r, tr[k], label=lab, lw=1.6)
        ax.set_ylabel("energy (J)"); ax.legend(fontsize=7)
        ax = axes[1][0]
        ax.plot(r, tr["assoc_sweeps"], label="DA sweeps", lw=1.6)
        ax.plot(r, tr["sic_depth"], label="SIC depth", lw=1.6)
        ax.set_ylabel("count"); ax.set_xlabel("global round")
        ax.legend(fontsize=7)
        ax = axes[1][1]
        ax.plot(r, tr["pdd_iters"], label="PDD iters", lw=1.6)
        ax2 = ax.twinx()
        ax2.semilogy([x for x in r],
                     [max(v, 1e-12) for v in tr["pdd_residual"]],
                     color="C3", label="residual", lw=1.2)
        ax.set_ylabel("PDD iterations"); ax2.set_ylabel("residual")
        ax.set_xlabel("global round"); ax.legend(fontsize=7, loc="upper left")
        for row in axes:
            for a in row:
                a.grid(True, alpha=0.3)
        fig.suptitle(f"round trace `{label}`", fontsize=11)
        fig.tight_layout(rect=(0, 0, 1, 0.96))
        dest = out_dir or (path if os.path.isdir(path)
                           else os.path.dirname(path) or ".")
        os.makedirs(dest, exist_ok=True)
        out = os.path.join(dest, f"trace_{label}.png")
        fig.savefig(out, dpi=130)
        plt.close(fig)
        written.append(out)
    if not written:
        raise SystemExit(f"no trace JSON/JSONL found under {path!r}")
    return written


def plot_report(path, out_dir=None):
    written = []
    for summary, sweep_dir in _iter_summaries(path, with_dir=True):
        dest = out_dir or sweep_dir
        os.makedirs(dest, exist_ok=True)
        written += sweep_plots(summary, sweep_dir, dest)
    if not written:
        raise SystemExit(f"no sweep summary found under {path!r}")
    return written


if __name__ == "__main__":
    kind, path = sys.argv[1], sys.argv[2]
    if kind == "sweep":
        print(sweep_report(path))
    elif kind == "plot":
        for p in plot_report(path, sys.argv[3] if len(sys.argv) > 3
                             else None):
            print(f"wrote {p}")
    elif kind == "trace":
        print(trace_report(path))
    elif kind == "traceplot":
        for p in trace_plots(path, sys.argv[3] if len(sys.argv) > 3
                             else None):
            print(f"wrote {p}")
    else:
        recs = load(path)
        print(dryrun_table(recs) if kind == "dryrun"
              else roofline_table(recs))
