"""Render EXPERIMENTS.md tables from the dry-run jsonl records."""
import json
import sys


def load(path):
    try:
        return [json.loads(l) for l in open(path) if l.strip()]
    except FileNotFoundError:
        return []


def dryrun_table(recs):
    hdr = ("| arch | shape | mesh | status | lower s | compile s | "
           "args GB/dev | temp GB/dev | collectives |")
    sep = "|" + "---|" * 9
    rows = [hdr, sep]
    for r in recs:
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | skipped: "
                        f"{r['reason'][:48]} | | | | | |")
            continue
        mem = r.get("memory", {})
        coll = r.get("roofline", {}).get("coll_breakdown", {})
        coll_s = ",".join(f"{k.replace('all-','a')}:{v/1e9:.2f}GB"
                          for k, v in coll.items()) or "none"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} | "
            f"{r.get('lower_s','')} | {r.get('compile_s','')} | "
            f"{mem.get('argument_gb',0):.2f} | {mem.get('temp_gb',0):.1f} | "
            f"{coll_s} |")
    return "\n".join(rows)


def roofline_table(recs):
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL_FLOPS | useful ratio | what moves the dominant term |")
    sep = "|" + "---|" * 9
    rows = [hdr, sep]
    hints = {
        ("collective", "train"): "overlap grad all-reduce with bwd; "
                                 "reduce-scatter instead of all-reduce",
        ("collective", "other"): "re-shard activations to cut all-gathers",
        ("memory", "train"): "microbatching (grad_accum) + bf16 master",
        ("memory", "other"): "shrink/quantise the KV cache; fuse reads",
        ("compute", "train"): "remat policy: save attn outputs",
        ("compute", "other"): "larger decode batch per chip",
    }
    for r in recs:
        if "roofline" not in r:
            continue
        rf = r["roofline"]
        kind = "train" if r["shape"] == "train_4k" else "other"
        hint = hints.get((rf["dominant"], kind), "")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3g} | "
            f"{rf['memory_s']:.3g} | {rf['collective_s']:.3g} | "
            f"**{rf['dominant']}** | {rf['model_flops']:.3g} | "
            f"{rf['useful_ratio']:.3f} | {hint} |")
    return "\n".join(rows)


if __name__ == "__main__":
    kind, path = sys.argv[1], sys.argv[2]
    recs = load(path)
    print(dryrun_table(recs) if kind == "dryrun" else roofline_table(recs))
