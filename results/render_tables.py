"""Render EXPERIMENTS.md tables from the dry-run jsonl records, and the
paper's Figs. 8-12-style cost/accuracy comparison tables from sweep
summaries.

  python results/render_tables.py dryrun  results/dryrun.jsonl
  python results/render_tables.py roofline results/dryrun.jsonl
  python results/render_tables.py sweep   results/sweep_showcase
  python results/render_tables.py sweep   'results/sweep_*'     # glob ok

``sweep`` accepts a sweep directory, its summary.json path, or a glob of
either; each summary renders one table per metric (final accuracy, mean
round cost) with scenarios as rows and scheme columns (policy/allocator/
scheduler/NOMA), mean ± spread over seeds — the Figs. 8-12 protocol view.
"""
import glob as _glob
import json
import math
import os
import sys
from collections import defaultdict


def load(path):
    try:
        return [json.loads(l) for l in open(path) if l.strip()]
    except FileNotFoundError:
        return []


def dryrun_table(recs):
    hdr = ("| arch | shape | mesh | status | lower s | compile s | "
           "args GB/dev | temp GB/dev | collectives |")
    sep = "|" + "---|" * 9
    rows = [hdr, sep]
    for r in recs:
        if r["status"] == "skipped":
            rows.append(f"| {r['arch']} | {r['shape']} | — | skipped: "
                        f"{r['reason'][:48]} | | | | | |")
            continue
        mem = r.get("memory", {})
        coll = r.get("roofline", {}).get("coll_breakdown", {})
        coll_s = ",".join(f"{k.replace('all-','a')}:{v/1e9:.2f}GB"
                          for k, v in coll.items()) or "none"
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | {r['status']} | "
            f"{r.get('lower_s','')} | {r.get('compile_s','')} | "
            f"{mem.get('argument_gb',0):.2f} | {mem.get('temp_gb',0):.1f} | "
            f"{coll_s} |")
    return "\n".join(rows)


def roofline_table(recs):
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| MODEL_FLOPS | useful ratio | what moves the dominant term |")
    sep = "|" + "---|" * 9
    rows = [hdr, sep]
    hints = {
        ("collective", "train"): "overlap grad all-reduce with bwd; "
                                 "reduce-scatter instead of all-reduce",
        ("collective", "other"): "re-shard activations to cut all-gathers",
        ("memory", "train"): "microbatching (grad_accum) + bf16 master",
        ("memory", "other"): "shrink/quantise the KV cache; fuse reads",
        ("compute", "train"): "remat policy: save attn outputs",
        ("compute", "other"): "larger decode batch per chip",
    }
    for r in recs:
        if "roofline" not in r:
            continue
        rf = r["roofline"]
        kind = "train" if r["shape"] == "train_4k" else "other"
        hint = hints.get((rf["dominant"], kind), "")
        rows.append(
            f"| {r['arch']} | {r['shape']} | {rf['compute_s']:.3g} | "
            f"{rf['memory_s']:.3g} | {rf['collective_s']:.3g} | "
            f"**{rf['dominant']}** | {rf['model_flops']:.3g} | "
            f"{rf['useful_ratio']:.3f} | {hint} |")
    return "\n".join(rows)


# ---------------------------------------------------------------------------
# Sweep summaries -> Figs. 8-12 comparison tables
# ---------------------------------------------------------------------------

def _parse_cell_id(cid):
    """scenario__policy__allocator__scheduler__(noma|oma)__sSEED ->
    (scenario, scheme label, seed)."""
    scenario, policy, allocator, scheduler, noma, seed = cid.rsplit("__", 5)
    return scenario, f"{policy}/{allocator}/{scheduler}/{noma}", int(seed[1:])


def _mean_std(vals):
    mean = sum(vals) / len(vals)
    if len(vals) < 2:
        return mean, 0.0
    var = sum((v - mean) ** 2 for v in vals) / (len(vals) - 1)
    return mean, math.sqrt(var)


def _fmt(mean, std, digits=3):
    if std == 0.0:
        return f"{mean:.{digits}f}"
    return f"{mean:.{digits}f} ± {std:.{digits}f}"


def sweep_tables(summary):
    """Markdown tables from one run_sweep summary dict."""
    # rows[metric][scenario][scheme] -> list over seeds
    rows = defaultdict(lambda: defaultdict(lambda: defaultdict(list)))
    for cid, final in summary["final"].items():
        scenario, scheme, _ = _parse_cell_id(cid)
        for metric in ("accuracy", "mean_cost"):
            rows[metric][scenario][scheme].append(float(final[metric]))
    titles = {"accuracy": "Final accuracy",
              "mean_cost": "Mean round cost (Eq. 23a)"}
    out = [f"## sweep `{summary['name']}` — {summary['n_cells']} cells, "
           f"{summary['n_rounds']} rounds, "
           f"{summary['n_compiles']} compiles"]
    scenario_order = summary.get("axes", {}).get("scenarios") or sorted(
        {s for m in rows.values() for s in m})
    for metric, title in titles.items():
        schemes = sorted({s for per in rows[metric].values() for s in per})
        out.append(f"\n### {title}\n")
        out.append("| scenario | " + " | ".join(schemes) + " |")
        out.append("|" + "---|" * (len(schemes) + 1))
        for scenario in scenario_order:
            if scenario not in rows[metric]:
                continue
            cells = []
            for scheme in schemes:
                vals = rows[metric][scenario].get(scheme)
                cells.append(_fmt(*_mean_std(vals)) if vals else "—")
            out.append(f"| {scenario} | " + " | ".join(cells) + " |")
    return "\n".join(out)


def _iter_summaries(path):
    """Yield summary dicts from a dir / summary.json / glob of either."""
    matches = sorted(_glob.glob(path)) or [path]
    for p in matches:
        if os.path.isdir(p):
            p = os.path.join(p, "summary.json")
        if not os.path.exists(p):
            continue
        with open(p) as fh:
            yield json.load(fh)


def sweep_report(path):
    parts = [sweep_tables(s) for s in _iter_summaries(path)]
    if not parts:
        raise SystemExit(f"no sweep summary found under {path!r}")
    return "\n\n".join(parts)


if __name__ == "__main__":
    kind, path = sys.argv[1], sys.argv[2]
    if kind == "sweep":
        print(sweep_report(path))
    else:
        recs = load(path)
        print(dryrun_table(recs) if kind == "dryrun"
              else roofline_table(recs))
