"""Batched serving example: prefill + autoregressive decode with KV caches
over several architectures (dense GQA / hybrid RG-LRU / enc-dec audio).

  PYTHONPATH=src python examples/serve_decode.py
"""
import subprocess
import sys
import os

ARCHS = ["qwen3-8b", "recurrentgemma-9b", "whisper-large-v3"]


def main() -> int:
    env = dict(os.environ)
    env.setdefault("PYTHONPATH", "src")
    for arch in ARCHS:
        print(f"=== serving {arch} (reduced) ===", flush=True)
        r = subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--arch", arch,
             "--tokens", "12", "--batch", "2"], env=env)
        if r.returncode != 0:
            return r.returncode
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
