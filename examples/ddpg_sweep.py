"""ROADMAP item: DDPG under dynamic scenarios, closed end-to-end.

Trains the DDPG resource allocator with the pure scanned driver
(``ddpg.train_allocator``, one XLA program for all of paper Algorithm 2)
on the ``full_dynamic`` preset — moving clients, Markov dropout,
heterogeneous devices — and benchmarks it against the ``mid`` and ``rra``
allocators through the sweep grid.  The ddpg group trains its own actor
on the (3N,) scenario-sliced observation; every cell's trajectory, its
per-round telemetry trace (``<cell>.trace.json`` — the Eq. 23a cost
decomposition the DDPG reward optimises, split by stage) and the final
comparison land under ``results/sweep_ddpg/``.

  PYTHONPATH=src python examples/ddpg_sweep.py [--rounds 12] [--seeds 2]
                                               [--episodes 30]
                                               [--no-telemetry]
"""
import argparse
import dataclasses

import numpy as np

from repro import sweeps
from repro.configs.hfl_mnist import CONFIG


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--seeds", type=int, default=2)
    ap.add_argument("--episodes", type=int, default=30,
                    help="DDPG training episodes (40 steps each)")
    ap.add_argument("--name", default="ddpg")
    ap.add_argument("--out", default="results")
    ap.add_argument("--no-telemetry", action="store_true",
                    help="skip the per-cell RoundTrace JSON")
    args = ap.parse_args()

    cfg = dataclasses.replace(CONFIG, n_clients=32, n_edges=4,
                              clients_per_edge=3, min_samples=80,
                              max_samples=300, hidden=64, input_dim=196)
    grid = sweeps.SweepGrid(
        name=args.name,
        scenarios=("full_dynamic",),
        policies=("fcea",),
        allocators=("ddpg", "mid", "rra"),
        seeds=tuple(range(args.seeds)),
        n_rounds=args.rounds,
        ddpg_episodes=args.episodes, ddpg_steps=40,
        ddpg_warmup=64, ddpg_hidden=64,
        telemetry=not args.no_telemetry)
    summary = sweeps.run_sweep(cfg, grid, out_dir=args.out)

    by_alloc = {}
    for cid, row in summary["final"].items():
        alloc = cid.split("__")[2]
        by_alloc.setdefault(alloc, []).append(row["mean_cost"])
    print(f"\n{'allocator':10s} {'mean round cost':>16s}")
    for alloc, costs in sorted(by_alloc.items(),
                               key=lambda kv: np.mean(kv[1])):
        print(f"{alloc:10s} {np.mean(costs):16.3f}")
    ddpg_cost = np.mean(by_alloc["ddpg"])
    for baseline in ("mid", "rra"):
        gain = 100.0 * (1.0 - ddpg_cost / np.mean(by_alloc[baseline]))
        print(f"ddpg vs {baseline}: {gain:.1f}% cheaper")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
