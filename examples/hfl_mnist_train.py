"""The paper's experiment end-to-end: NOMA-HFL on MNIST-like data.

Trains the global classifier for ``--rounds`` global rounds under the fuzzy
client-edge association, PDD edge scheduling, and (optionally) a DDPG-trained
resource allocator; prints the per-round metrics of Figs. 8-12.

The whole experiment runs through the pure round engine: by default all
rounds execute as ONE compiled ``lax.scan`` program (``run_scanned``);
``--eager`` steps round by round instead (same trajectory, handy for
debugging / incremental output).

  PYTHONPATH=src python examples/hfl_mnist_train.py --rounds 10 [--non-iid]
                                                    [--policy fcea|gcea|rcea]
                                                    [--ddpg] [--full] [--eager]
"""
import argparse
import dataclasses

from repro.configs.hfl_mnist import CONFIG
from repro.core.hfl import HFLSimulation


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=10)
    ap.add_argument("--policy", default="fcea",
                    choices=["fcea", "gcea", "rcea"])
    ap.add_argument("--non-iid", action="store_true")
    ap.add_argument("--oma", action="store_true")
    ap.add_argument("--ddpg", action="store_true")
    ap.add_argument("--full", action="store_true",
                    help="paper-faithful 64-client topology (slower)")
    ap.add_argument("--eager", action="store_true",
                    help="dispatch one jitted round at a time instead of "
                         "one scanned program for all rounds")
    ap.add_argument("--scenario", default="static",
                    help="dynamic-world preset (static, random_waypoint, "
                         "markov_dropout, hetero_devices, mobile_flaky, "
                         "full_dynamic, or a '+'-joined mixture)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = CONFIG if args.full else dataclasses.replace(
        CONFIG, n_clients=24, clients_per_edge=3, min_samples=80,
        max_samples=300, hidden=64, input_dim=196)
    sim = HFLSimulation(cfg, seed=args.seed, iid=not args.non_iid,
                        policy=args.policy, noma_enabled=not args.oma,
                        allocator="ddpg" if args.ddpg else "mid",
                        scenario=args.scenario)
    if args.ddpg:
        print("training DDPG allocator ...")
        hist = sim.train_ddpg(episodes=8, steps_per_episode=30, warmup=64)
        print("episode rewards:",
              [round(r, 2) for r in hist["episode_reward"]])

    print(f"policy={args.policy} noma={not args.oma} "
          f"iid={not args.non_iid} clients={cfg.n_clients} "
          f"scenario={args.scenario} "
          f"driver={'eager' if args.eager else 'scanned'}")
    ms = sim.run(args.rounds) if args.eager else sim.run_scanned(args.rounds)
    for m in ms:
        print(f"round {m.round:3d}  acc={m.accuracy:.4f}  loss={m.loss:.4f}  "
              f"avgMS={m.avg_staleness:.2f}  T={m.total_time_s:.2f}s  "
              f"E={m.total_energy_j:.1f}J  cost={m.cost:.2f}  "
              f"avail={m.n_available}  edges={m.z.astype(int).tolist()}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
