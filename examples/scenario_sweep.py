"""Sweep the paper's protocol across dynamic worlds (DESIGN.md §6).

Runs a declarative scenario × policy grid through ``repro.sweeps``: every
dynamic scenario (moving clients, flaky availability, heterogeneous
devices) batches into ONE vmapped compile per association policy, and each
cell's metric trajectory lands as JSON under ``results/sweep_<name>/``.

  PYTHONPATH=src python examples/scenario_sweep.py [--rounds 12] [--seeds 2]
                                                   [--name showcase]
"""
import argparse
import dataclasses

from repro import sweeps
from repro.configs.hfl_mnist import CONFIG


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=12)
    ap.add_argument("--seeds", type=int, default=2)
    ap.add_argument("--name", default="showcase")
    ap.add_argument("--out", default="results")
    args = ap.parse_args()

    cfg = dataclasses.replace(CONFIG, n_clients=32, n_edges=4,
                              clients_per_edge=3, min_samples=80,
                              max_samples=300, hidden=64, input_dim=196)
    grid = sweeps.SweepGrid(
        name=args.name,
        scenarios=("static", "random_waypoint", "markov_dropout",
                   "hetero_devices", "mobile_flaky", "full_dynamic"),
        policies=("fcea", "gcea"),
        seeds=tuple(range(args.seeds)),
        n_rounds=args.rounds)
    summary = sweeps.run_sweep(cfg, grid, out_dir=args.out)
    print(f"{summary['n_cells']} cells in {summary['n_compiles']} compiles")
    for g in summary["groups"]:
        print(f"  {g['spec']['policy']}/{g['spec']['scenario']}: "
              f"{g['n_cells']} cells in {g['wall_s']}s")
    print(f"\n{'cell':60s} {'acc':>6s} {'cost':>8s} {'avail':>5s}")
    for cid, row in sorted(summary["final"].items()):
        print(f"{cid:60s} {row['accuracy']:6.3f} {row['mean_cost']:8.3f} "
              f"{row['n_available']:5d}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
