"""End-to-end LM training driver: train a ~100M-param dense model for a few
hundred steps on synthetic tokens with checkpointing + cosine schedule.

The model is the stablelm family config scaled to ~100M — the same block
assembly the 110B dry-run lowers, exercised for real.

  PYTHONPATH=src python examples/lm_train_small.py --steps 300
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint
from repro.configs import get_config
from repro.launch.steps import make_train_step
from repro.data.tokens import token_batches


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # ~100M params: 12 layers, d=768, ff=3072, vocab 32k
    cfg = get_config("stablelm-1.6b").replace(
        name="stablelm-100m", n_layers=12, d_model=768, n_heads=12,
        n_kv_heads=12, d_head=64, d_ff=3072, vocab_size=32_000,
        remat=False, param_dtype_str="float32", compute_dtype_str="float32")
    step_fn, model, opt = make_train_step(cfg, lr=3e-4)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    key = jax.random.key(args.seed)
    params = model.init(key)
    n = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(params))
    print(f"{cfg.name}: {n/1e6:.1f}M params, {args.steps} steps")

    opt_state = opt.init(params)
    step = jnp.zeros((), jnp.int32)
    rng = np.random.default_rng(args.seed)
    losses = []
    t0 = time.time()
    for i, b in enumerate(token_batches(rng, vocab=cfg.vocab_size,
                                        batch=args.batch, seq_len=args.seq,
                                        n_batches=args.steps)):
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        params, opt_state, step, m = step_fn(params, opt_state, step, batch)
        losses.append(float(m["loss"]))
        if (i + 1) % 20 == 0:
            rate = args.batch * args.seq * (i + 1) / (time.time() - t0)
            print(f"step {i+1:4d}  loss {losses[-1]:.4f}  ({rate:.0f} tok/s)")
        if (i + 1) % 100 == 0:
            checkpoint.save_checkpoint(args.ckpt_dir, i + 1, params)
    assert losses[-1] < losses[0], "no learning happened"
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f}; "
          f"checkpoints in {args.ckpt_dir}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
