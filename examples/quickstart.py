"""Quickstart: every layer of the framework in ~60 seconds on CPU.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

# 1. The paper's core: fuzzy client scoring -----------------------------------
from repro.core import fuzzy

scores = fuzzy.score_clients(
    channel_gain=jnp.asarray([1e-9, 8e-9, 3e-9]),
    data_quantity=jnp.asarray([300.0, 900.0, 1100.0]),
    staleness=jnp.asarray([1.0, 4.0, 2.0]),
    gain_max=1e-8, data_max=1200.0, staleness_max=5.0)
print("fuzzy competency NO*:", np.round(np.asarray(scores), 1))

# 2. One full HFL round (association + NOMA + PDD + aggregation) ---------------
import dataclasses
from repro.configs.hfl_mnist import CONFIG
from repro.core.hfl import HFLSimulation

cfg = dataclasses.replace(CONFIG, n_clients=16, n_edges=2,
                          clients_per_edge=3, min_samples=60,
                          max_samples=120, hidden=32, input_dim=64)
sim = HFLSimulation(cfg, seed=0, iid=True, policy="fcea")
for m in sim.run(2):
    print(f"round {m.round}: acc={m.accuracy:.3f} loss={m.loss:.3f} "
          f"cost={m.cost:.2f} selected_edges={m.z.astype(int).tolist()}")

# 3. A production architecture (reduced) takes one training step ---------------
from repro.configs import get_config
from repro.launch.steps import make_train_step

arch = get_config("qwen3-8b").reduced()
step_fn, model, opt = make_train_step(arch, lr=1e-3)
key = jax.random.key(0)
params = model.init(key)
opt_state = opt.init(params)
batch = {
    "tokens": jax.random.randint(key, (2, 32), 0, arch.vocab_size, jnp.int32),
    "labels": jax.random.randint(key, (2, 32), 0, arch.vocab_size, jnp.int32),
}
params, opt_state, step, metrics = jax.jit(step_fn)(
    params, opt_state, jnp.zeros((), jnp.int32), batch)
print(f"{arch.name}: train loss {float(metrics['loss']):.3f}")

# 4. A Pallas kernel validated against its oracle ------------------------------
from repro.kernels import ops, ref

ks = jax.random.split(key, 3)
q = jax.random.normal(ks[0], (1, 128, 4, 32))
k = jax.random.normal(ks[1], (1, 128, 2, 32))
v = jax.random.normal(ks[2], (1, 128, 2, 32))
out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64)
want = ref.attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                         v.transpose(0, 2, 1, 3),
                         causal=True).transpose(0, 2, 1, 3)
print("flash-attention max err vs oracle:",
      float(jnp.max(jnp.abs(out - want))))
print("OK")
