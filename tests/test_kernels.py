"""Per-kernel correctness sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention as fa_raw
from repro.kernels.linear_recurrence import linear_recurrence as lr_raw


def _qkv(key, b, s, h, kv, d, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d), dtype)
    return q, k, v


@pytest.mark.parametrize("b,s,h,kv,d", [
    (1, 128, 2, 2, 32),     # MHA
    (2, 256, 4, 2, 64),     # GQA 2:1
    (1, 256, 4, 1, 64),     # MQA
    (1, 512, 8, 8, 16),     # many heads, small dh
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention_sweep(key, b, s, h, kv, d, causal, window):
    q, k, v = _qkv(key, b, s, h, kv, d, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=64, block_k=64, interpret=True)
    want = ref.attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), causal=causal,
                             window=window).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16(key):
    q, k, v = _qkv(key, 1, 128, 2, 2, 32, jnp.bfloat16)
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                              interpret=True)
    want = ref.attention_ref(
        q.transpose(0, 2, 1, 3).astype(jnp.float32),
        k.transpose(0, 2, 1, 3).astype(jnp.float32),
        v.transpose(0, 2, 1, 3).astype(jnp.float32),
        causal=True).transpose(0, 2, 1, 3)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), atol=0.05, rtol=0.05)


@pytest.mark.parametrize("bq,bk", [(32, 32), (64, 128), (128, 64)])
def test_flash_attention_block_shapes(key, bq, bk):
    q, k, v = _qkv(key, 1, 128, 2, 2, 32, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                              interpret=True)
    want = ref.attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3),
                             causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_flash_window_smaller_than_block(key):
    q, k, v = _qkv(key, 1, 256, 2, 2, 32, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=16, block_q=64,
                              block_k=64, interpret=True)
    want = ref.attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), causal=True,
                             window=16).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("b,s,c", [(1, 128, 128), (2, 256, 256),
                                   (1, 512, 384)])
@pytest.mark.parametrize("bt,bc", [(64, 128), (128, 128)])
def test_linear_recurrence_sweep(key, b, s, c, bt, bc):
    k1, k2 = jax.random.split(key)
    log_a = -jax.random.uniform(k1, (b, s, c), jnp.float32, 0.001, 2.0)
    x = jax.random.normal(k2, (b, s, c), jnp.float32)
    out = ops.linear_recurrence(log_a, x, block_t=bt, block_c=bc,
                                interpret=True)
    want = ref.linear_recurrence_ref(log_a, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5,
                               rtol=1e-4)


def test_linear_recurrence_bf16_inputs(key):
    k1, k2 = jax.random.split(key)
    log_a = (-jax.random.uniform(k1, (1, 128, 128), jnp.float32, 0.01, 1.0)
             ).astype(jnp.bfloat16)
    x = jax.random.normal(k2, (1, 128, 128), jnp.bfloat16)
    out = ops.linear_recurrence(log_a, x, interpret=True)
    want = ref.linear_recurrence_ref(log_a.astype(jnp.float32),
                                     x.astype(jnp.float32))
    assert out.dtype == jnp.float32          # fp32 carry by design
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=0.15,
                               rtol=0.05)


def test_linear_recurrence_matches_rglru_scan(key):
    """The kernel is a drop-in for the model's associative-scan oracle."""
    from repro.models.rglru import rglru_scan
    k1, k2 = jax.random.split(key)
    log_a = -jax.random.uniform(k1, (2, 256, 128), jnp.float32, 0.01, 1.0)
    x = jax.random.normal(k2, (2, 256, 128), jnp.float32)
    out = ops.linear_recurrence(log_a, x, interpret=True)
    want = rglru_scan(log_a, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5,
                               rtol=1e-4)


def test_flash_attention_grads(key):
    """Interpret-mode kernels are differentiable enough for training use."""
    q, k, v = _qkv(key, 1, 128, 2, 2, 32, jnp.float32)

    def f(q):
        return jnp.sum(ops.flash_attention(q, k, v, causal=True, block_q=64,
                                           block_k=64, interpret=True))

    def f_ref(q):
        return jnp.sum(ref.attention_ref(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True))

    g = jax.grad(f)(q)
    g_ref = jax.grad(f_ref)(q)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), atol=2e-4,
                               rtol=2e-4)
