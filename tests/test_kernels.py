"""Per-kernel correctness sweeps vs the pure-jnp oracles (interpret mode)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fuzzy, noma
from repro.kernels import hfl_ops, ops, ref
from repro.kernels.flash_attention import flash_attention as fa_raw
from repro.kernels.linear_recurrence import linear_recurrence as lr_raw


def _qkv(key, b, s, h, kv, d, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, h, d), dtype)
    k = jax.random.normal(ks[1], (b, s, kv, d), dtype)
    v = jax.random.normal(ks[2], (b, s, kv, d), dtype)
    return q, k, v


@pytest.mark.parametrize("b,s,h,kv,d", [
    (1, 128, 2, 2, 32),     # MHA
    (2, 256, 4, 2, 64),     # GQA 2:1
    (1, 256, 4, 1, 64),     # MQA
    (1, 512, 8, 8, 16),     # many heads, small dh
])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention_sweep(key, b, s, h, kv, d, causal, window):
    q, k, v = _qkv(key, b, s, h, kv, d, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              block_q=64, block_k=64, interpret=True)
    want = ref.attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), causal=causal,
                             window=window).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16(key):
    q, k, v = _qkv(key, 1, 128, 2, 2, 32, jnp.bfloat16)
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_k=64,
                              interpret=True)
    want = ref.attention_ref(
        q.transpose(0, 2, 1, 3).astype(jnp.float32),
        k.transpose(0, 2, 1, 3).astype(jnp.float32),
        v.transpose(0, 2, 1, 3).astype(jnp.float32),
        causal=True).transpose(0, 2, 1, 3)
    assert out.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want), atol=0.05, rtol=0.05)


@pytest.mark.parametrize("bq,bk", [(32, 32), (64, 128), (128, 64)])
def test_flash_attention_block_shapes(key, bq, bk):
    q, k, v = _qkv(key, 1, 128, 2, 2, 32, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk,
                              interpret=True)
    want = ref.attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3),
                             causal=True).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


def test_flash_window_smaller_than_block(key):
    q, k, v = _qkv(key, 1, 256, 2, 2, 32, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=16, block_q=64,
                              block_k=64, interpret=True)
    want = ref.attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                             v.transpose(0, 2, 1, 3), causal=True,
                             window=16).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=2e-5,
                               rtol=2e-5)


@pytest.mark.parametrize("b,s,c", [(1, 128, 128), (2, 256, 256),
                                   (1, 512, 384)])
@pytest.mark.parametrize("bt,bc", [(64, 128), (128, 128)])
def test_linear_recurrence_sweep(key, b, s, c, bt, bc):
    k1, k2 = jax.random.split(key)
    log_a = -jax.random.uniform(k1, (b, s, c), jnp.float32, 0.001, 2.0)
    x = jax.random.normal(k2, (b, s, c), jnp.float32)
    out = ops.linear_recurrence(log_a, x, block_t=bt, block_c=bc,
                                interpret=True)
    want = ref.linear_recurrence_ref(log_a, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5,
                               rtol=1e-4)


def test_linear_recurrence_bf16_inputs(key):
    k1, k2 = jax.random.split(key)
    log_a = (-jax.random.uniform(k1, (1, 128, 128), jnp.float32, 0.01, 1.0)
             ).astype(jnp.bfloat16)
    x = jax.random.normal(k2, (1, 128, 128), jnp.bfloat16)
    out = ops.linear_recurrence(log_a, x, interpret=True)
    want = ref.linear_recurrence_ref(log_a.astype(jnp.float32),
                                     x.astype(jnp.float32))
    assert out.dtype == jnp.float32          # fp32 carry by design
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=0.15,
                               rtol=0.05)


def test_linear_recurrence_matches_rglru_scan(key):
    """The kernel is a drop-in for the model's associative-scan oracle."""
    from repro.models.rglru import rglru_scan
    k1, k2 = jax.random.split(key)
    log_a = -jax.random.uniform(k1, (2, 256, 128), jnp.float32, 0.01, 1.0)
    x = jax.random.normal(k2, (2, 256, 128), jnp.float32)
    out = ops.linear_recurrence(log_a, x, interpret=True)
    want = rglru_scan(log_a, x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=1e-5,
                               rtol=1e-4)


# ---------------------------------------------------------------------------
# HFL kernels (DESIGN.md §8.2) vs their jnp references, interpret mode
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,m,block_r", [
    (10, 3, 8),       # padded ragged tail
    (64, 8, 512),     # block larger than the row count
    (33, 5, 32),
    (128, 4, 128),
])
def test_hfl_score_matrix_matches_fuzzy(n, m, block_r):
    rng = np.random.default_rng(n * m)
    gains = jnp.asarray(rng.uniform(1e-12, 1e-8, (n, m)))
    counts = jnp.asarray(rng.integers(60, 120, n), jnp.float32)
    stale = jnp.asarray(rng.integers(1, 9, n), jnp.int32)
    want = fuzzy.score_matrix(gains, counts, stale, data_max=120.0)
    got = hfl_ops.score_matrix(gains, counts, stale, data_max=120.0,
                               block_r=block_r, interpret=True)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=2e-4, rtol=1e-5)


def _pairwise_rates(p, g, mask, bandwidth_hz, noise_w):
    return np.stack(
        [np.asarray(noma.achievable_rates(p, g[:, j],
                                          bandwidth_hz=bandwidth_hz,
                                          noise_w=noise_w, mask=mask[:, j]))
         for j in range(g.shape[1])], axis=1)


@pytest.mark.parametrize("n,m,block_n", [
    (12, 3, 8),       # ragged blocks
    (64, 4, 32),      # multi-block j sweep
    (100, 7, 64),
])
def test_hfl_sic_rates_matches_pairwise(n, m, block_n):
    rng = np.random.default_rng(n + m)
    p = jnp.asarray(rng.uniform(0.01, 0.1, n))
    g = jnp.asarray(rng.uniform(0.1, 10.0, (n, m)) * 1e-9)
    mask = jnp.asarray(rng.random((n, m)) < 0.5)
    noise = noma.noise_power_w(-174.0, 1e6)
    want = _pairwise_rates(p, g, mask, 1e6, noise)
    got = hfl_ops.sic_rates(p, g, mask, bandwidth_hz=1e6, noise_w=noise,
                            block_n=block_n, interpret=True)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5,
                               atol=want.max() * 1e-6)


def test_sorted_sic_matrix_matches_pairwise():
    """The jnp sorted-cumsum path (the at-scale default inside
    ``cost.uplink``) against the pairwise oracle, with and without the
    ``max_per_edge`` top-k bound."""
    rng = np.random.default_rng(5)
    n, m, quota = 80, 5, 6
    p = jnp.asarray(rng.uniform(0.01, 0.1, n))
    g = jnp.asarray(rng.uniform(0.1, 10.0, (n, m)) * 1e-9)
    mask_np = np.zeros((n, m), bool)
    for j in range(m):
        mask_np[rng.choice(n, quota, replace=False), j] = True
    mask = jnp.asarray(mask_np)
    noise = noma.noise_power_w(-174.0, 1e6)
    want = _pairwise_rates(p, g, mask, 1e6, noise)
    full = noma.sic_rates_matrix(p, g, mask, bandwidth_hz=1e6,
                                 noise_w=noise)
    topk = noma.sic_rates_matrix(p, g, mask, bandwidth_hz=1e6,
                                 noise_w=noise, max_per_edge=quota)
    np.testing.assert_allclose(np.asarray(full), want, rtol=1e-5,
                               atol=want.max() * 1e-6)
    # the top-k path IS the sorted path on the nonzero prefix: bit-equal
    np.testing.assert_array_equal(np.asarray(full), np.asarray(topk))


def test_sorted_sic_tie_break_matches_pairwise():
    """Exactly equal received powers: both formulations must decode the
    lower client index first."""
    p = jnp.asarray([0.1, 0.1, 0.1])
    g = jnp.asarray([[1e-9], [1e-9], [2e-9]])
    mask = jnp.ones((3, 1), bool)
    noise = noma.noise_power_w(-174.0, 1e6)
    want = _pairwise_rates(p, g, mask, 1e6, noise)
    got = noma.sic_rates_matrix(p, g, mask, bandwidth_hz=1e6, noise_w=noise)
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-6)
    # client 0 (earlier index) decoded before its equal-power twin 1 ->
    # still sees 1's interference (Eq. 7) -> strictly lower rate
    assert float(got[0, 0]) < float(got[1, 0])


def test_flash_attention_grads(key):
    """Interpret-mode kernels are differentiable enough for training use."""
    q, k, v = _qkv(key, 1, 128, 2, 2, 32, jnp.float32)

    def f(q):
        return jnp.sum(ops.flash_attention(q, k, v, causal=True, block_q=64,
                                           block_k=64, interpret=True))

    def f_ref(q):
        return jnp.sum(ref.attention_ref(
            q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
            v.transpose(0, 2, 1, 3), causal=True))

    g = jax.grad(f)(q)
    g_ref = jax.grad(f_ref)(q)
    np.testing.assert_allclose(np.asarray(g), np.asarray(g_ref), atol=2e-4,
                               rtol=2e-4)
