"""NOMA SIC/SINR unit + property tests (paper §II-A2, Eqs. 6-10)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or its absent-shim

from repro.core import noma

B = 1e6
NOISE = noma.noise_power_w(-174.0, B)


def test_sinr_two_users_closed_form():
    """Eq. 7 for K=2: strongest sees the other as interference, weakest
    only noise."""
    p = jnp.asarray([0.1, 0.05])
    g = jnp.asarray([1e-9, 1e-9])
    sinr = np.asarray(noma.sic_sinr(p, g, NOISE))
    rx = np.asarray(p * g)
    assert sinr[0] == pytest.approx(rx[0] / (rx[1] + NOISE), rel=1e-6)
    assert sinr[1] == pytest.approx(rx[1] / NOISE, rel=1e-6)


def test_sinr_order_invariance():
    """Decode order is by received power, not input order."""
    p = jnp.asarray([0.05, 0.1])
    g = jnp.asarray([1e-9, 1e-9])
    sinr = np.asarray(noma.sic_sinr(p, g, NOISE))
    rx = np.asarray(p * g)
    # client 1 is stronger -> decoded first -> sees client 0's interference
    assert sinr[1] == pytest.approx(rx[1] / (rx[0] + NOISE), rel=1e-6)
    assert sinr[0] == pytest.approx(rx[0] / NOISE, rel=1e-6)


def test_mask_zeroes_absent_clients():
    p = jnp.asarray([0.1, 0.1, 0.1])
    g = jnp.asarray([1e-9, 2e-9, 3e-9])
    mask = jnp.asarray([True, False, True])
    sinr = np.asarray(noma.sic_sinr(p, g, NOISE, mask))
    assert sinr[1] == 0.0
    # masked client contributes no interference
    rx = np.asarray(p * g)
    assert sinr[0] == pytest.approx(rx[0] / NOISE, rel=1e-6)


@settings(max_examples=50, deadline=None)
@given(st.integers(1, 6), st.integers(0, 10_000))
def test_sic_sum_rate_identity(k, seed):
    """Σ_k log2(1+SINR_k) == log2(1 + Σ p g / σ²) — SIC achieves the MAC
    sum capacity exactly (the classic NOMA identity)."""
    rng = np.random.default_rng(seed)
    p = jnp.asarray(rng.uniform(0.01, 0.1, k))
    g = jnp.asarray(rng.uniform(0.1, 10.0, k) * 1e-9)
    rates = noma.achievable_rates(p, g, bandwidth_hz=B, noise_w=NOISE)
    bound = noma.sum_rate_upper_bound(p, g, bandwidth_hz=B, noise_w=NOISE)
    np.testing.assert_allclose(float(jnp.sum(rates)), float(bound), rtol=1e-5)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 6), st.integers(0, 10_000))
def test_rates_positive_and_finite(k, seed):
    rng = np.random.default_rng(seed)
    p = jnp.asarray(rng.uniform(0.01, 0.1, k))
    g = jnp.asarray(rng.uniform(0.01, 10.0, k) * 1e-9)
    r = np.asarray(noma.achievable_rates(p, g, bandwidth_hz=B, noise_w=NOISE))
    assert (r > 0).all() and np.isfinite(r).all()


def test_rayleigh_gains_stats(key):
    d = jnp.full((4000,), 100.0)
    g = np.asarray(noma.rayleigh_gains(key, d, path_loss_exponent=3.76))
    # unit-mean exponential fading on top of the path loss
    pl = 100.0 ** -3.76
    assert g.mean() == pytest.approx(pl, rel=0.1)
    assert (g > 0).all()


def test_evolve_gains_correlation(key):
    d = jnp.full((2000,), 50.0)
    k1, k2 = jax.random.split(key)
    g0 = noma.rayleigh_gains(k1, d, path_loss_exponent=3.76)
    g1 = noma.evolve_gains(k2, g0, d, path_loss_exponent=3.76, rho=0.9)
    c = np.corrcoef(np.asarray(g0), np.asarray(g1))[0, 1]
    assert c > 0.7   # strongly correlated fading
    g_fresh = noma.evolve_gains(k2, g0, d, path_loss_exponent=3.76, rho=0.0)
    c2 = np.corrcoef(np.asarray(g0), np.asarray(g_fresh))[0, 1]
    assert abs(c2) < 0.2
