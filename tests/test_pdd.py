"""PDD edge-scheduling tests (paper §IV-B, Algorithm 1)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pdd


def _problem(m=4, seed=0):
    rng = np.random.default_rng(seed)
    energy = jnp.asarray(rng.uniform(50.0, 200.0, m))
    t_cloud = jnp.asarray(rng.uniform(0.01, 0.1, m))
    U = jnp.asarray(rng.uniform(1.0, 5.0))
    return energy, t_cloud, U


def test_binary_feasibility():
    """PDD converges to (near-)binary z: the z(1-z̃), z-z̃ residuals vanish."""
    energy, t_cloud, U = _problem()
    res = pdd.pdd_schedule(energy, t_cloud, U, lam_t=0.5, lam_e=0.5, quota=2)
    assert float(res.residual) < 1e-2
    zb = np.asarray(res.z_binary)
    assert set(np.unique(zb)).issubset({0.0, 1.0})


def test_quota_respected():
    for quota in (1, 2, 3):
        energy, t_cloud, U = _problem(m=5, seed=quota)
        res = pdd.pdd_schedule(energy, t_cloud, U, lam_t=0.5, lam_e=0.5,
                               quota=quota)
        assert int(np.asarray(res.z_binary).sum()) == quota


def test_picks_cheap_edges():
    """With equal times, the quota goes to the lowest-energy edges."""
    energy = jnp.asarray([100.0, 10.0, 100.0, 10.0])
    t_cloud = jnp.full((4,), 0.05)
    U = jnp.asarray(2.0)
    res = pdd.pdd_schedule(energy, t_cloud, U, lam_t=0.0, lam_e=1.0, quota=2)
    zb = np.asarray(res.z_binary)
    assert zb[1] == 1.0 and zb[3] == 1.0


def test_objective_not_worse_than_exhaustive():
    """Against brute force over all z with Σz = quota (M small)."""
    import itertools
    energy, t_cloud, U = _problem(m=5, seed=7)
    quota = 2
    res = pdd.pdd_schedule(energy, t_cloud, U, lam_t=0.5, lam_e=0.5,
                           quota=quota)
    best = np.inf
    for comb in itertools.combinations(range(5), quota):
        z = np.zeros(5)
        z[list(comb)] = 1.0
        obj = 0.5 * np.max(z * np.asarray(t_cloud + U)) \
            + 0.5 * np.sum(z * np.asarray(energy))
        best = min(best, obj)
    # PDD is a stationary-point method; accept within 20% of the optimum
    assert float(res.objective) <= best * 1.2 + 1e-6


def test_paper_literal_no_quota():
    """quota=None recovers the paper's formulation (z=0 admissible)."""
    energy, t_cloud, U = _problem()
    res = pdd.pdd_schedule(energy, t_cloud, U, lam_t=0.5, lam_e=0.5,
                           quota=None)
    zb = np.asarray(res.z_binary)
    assert set(np.unique(zb)).issubset({0.0, 1.0})


def test_semi_sync_fastest():
    t = jnp.asarray([3.0, 1.0, 2.0, 5.0])
    z = np.asarray(pdd.semi_sync_fastest(t, 2))
    assert z.tolist() == [0.0, 1.0, 1.0, 0.0]
