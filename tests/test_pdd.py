"""PDD edge-scheduling tests (paper §IV-B, Algorithm 1)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import pdd


def _problem(m=4, seed=0):
    rng = np.random.default_rng(seed)
    energy = jnp.asarray(rng.uniform(50.0, 200.0, m))
    t_cloud = jnp.asarray(rng.uniform(0.01, 0.1, m))
    U = jnp.asarray(rng.uniform(1.0, 5.0))
    return energy, t_cloud, U


def test_binary_feasibility():
    """PDD converges to (near-)binary z: the z(1-z̃), z-z̃ residuals vanish."""
    energy, t_cloud, U = _problem()
    res = pdd.pdd_schedule(energy, t_cloud, U, lam_t=0.5, lam_e=0.5, quota=2)
    assert float(res.residual) < 1e-2
    zb = np.asarray(res.z_binary)
    assert set(np.unique(zb)).issubset({0.0, 1.0})


def test_quota_respected():
    for quota in (1, 2, 3):
        energy, t_cloud, U = _problem(m=5, seed=quota)
        res = pdd.pdd_schedule(energy, t_cloud, U, lam_t=0.5, lam_e=0.5,
                               quota=quota)
        assert int(np.asarray(res.z_binary).sum()) == quota


def test_picks_cheap_edges():
    """With equal times, the quota goes to the lowest-energy edges."""
    energy = jnp.asarray([100.0, 10.0, 100.0, 10.0])
    t_cloud = jnp.full((4,), 0.05)
    U = jnp.asarray(2.0)
    res = pdd.pdd_schedule(energy, t_cloud, U, lam_t=0.0, lam_e=1.0, quota=2)
    zb = np.asarray(res.z_binary)
    assert zb[1] == 1.0 and zb[3] == 1.0


def test_objective_not_worse_than_exhaustive():
    """Against brute force over all z with Σz = quota (M small)."""
    import itertools
    energy, t_cloud, U = _problem(m=5, seed=7)
    quota = 2
    res = pdd.pdd_schedule(energy, t_cloud, U, lam_t=0.5, lam_e=0.5,
                           quota=quota)
    best = np.inf
    for comb in itertools.combinations(range(5), quota):
        z = np.zeros(5)
        z[list(comb)] = 1.0
        obj = 0.5 * np.max(z * np.asarray(t_cloud + U)) \
            + 0.5 * np.sum(z * np.asarray(energy))
        best = min(best, obj)
    # PDD is a stationary-point method; accept within 20% of the optimum
    assert float(res.objective) <= best * 1.2 + 1e-6


def test_paper_literal_no_quota():
    """quota=None recovers the paper's formulation (z=0 admissible)."""
    energy, t_cloud, U = _problem()
    res = pdd.pdd_schedule(energy, t_cloud, U, lam_t=0.5, lam_e=0.5,
                           quota=None)
    zb = np.asarray(res.z_binary)
    assert set(np.unique(zb)).issubset({0.0, 1.0})


def test_pdd_objective_is_the_billed_cost():
    """Regression (scheduler/bill consistency): with the engine's per-edge
    U = τ₂·max_{n∈N_m} t_n, the PDD objective at its own z must equal the
    Eq. 23a cost ``apply_schedule`` bills for that z — the scheduler may
    not optimise a different surface than the engine charges."""
    import dataclasses

    from repro.configs.hfl_mnist import CONFIG
    from repro.core import cost

    cfg = dataclasses.replace(CONFIG, n_clients=16, n_edges=4)
    rng = np.random.default_rng(5)
    n, m = cfg.n_clients, cfg.n_edges
    assoc = np.zeros((n, m), np.float32)
    assoc[np.arange(n), rng.integers(0, m, n)] = 1.0
    rc_all = cost.round_cost(
        cfg,
        power_w=jnp.asarray(rng.uniform(cfg.p_min_w, cfg.p_max_w, n)),
        f_hz=jnp.asarray(rng.uniform(cfg.f_min_hz, cfg.f_max_hz, n)),
        gains=jnp.asarray(rng.uniform(1e-12, 1e-9, (n, m))),
        assoc=jnp.asarray(assoc), z=jnp.ones((m,)),
        n_samples=jnp.asarray(rng.integers(60, 120, n), jnp.float32))
    t_cloud = jnp.full((m,), cfg.edge_model_size_bits / cfg.edge_rate_bps)
    U = rc_all.per_edge_time_s - t_cloud           # τ₂ × slowest client
    for quota in (1, 2, 3):
        res = pdd.pdd_schedule(rc_all.per_edge_energy_j, t_cloud, U,
                               lam_t=cfg.lambda_t, lam_e=cfg.lambda_e,
                               quota=quota)
        billed = cost.apply_schedule(cfg, rc_all, res.z_binary)
        np.testing.assert_allclose(float(res.objective),
                                   float(billed.cost), rtol=1e-6)


def test_engine_schedule_passes_tau2_scaled_U():
    """The engine's _schedule wiring: its PDD problem bills per-edge time
    ``t_cloud + τ₂·max t_n`` — exactly ``rc_all.per_edge_time_s``."""
    import dataclasses

    from repro.configs.hfl_mnist import CONFIG
    from repro.core import cost, engine

    cfg = dataclasses.replace(CONFIG, n_clients=12, n_edges=4,
                              clients_per_edge=3, min_samples=60,
                              max_samples=120, hidden=16, input_dim=32)
    spec = engine.EngineSpec(policy="fcea", scheduler="pdd")
    state, bundle, _ = engine.init_simulation(cfg, seed=0)
    _, m = engine.round_step_jit(cfg, spec, state, bundle)
    # the billed per-round cost must be reachable by the PDD objective at
    # the engine's chosen z: reconstruct rc_all on the PRE-round state
    rng_keys = engine.round_keys(spec, state.key)
    gains = __import__("repro.core.noma", fromlist=["noma"]).evolve_gains(
        rng_keys[2], state.gains, bundle.dist,
        path_loss_exponent=cfg.path_loss_exponent, rho=spec.fading_rho)
    assoc = engine._associate(cfg, spec, rng_keys[3], gains, bundle.dist,
                              bundle.counts, state.staleness
                              ).astype(jnp.float32)
    p, f = engine._allocate(cfg, spec, rng_keys[4], assoc, gains,
                            bundle.counts, None, None, bundle.dist)
    rc_all = cost.round_cost(cfg, power_w=p, f_hz=f, gains=gains,
                             assoc=assoc, z=jnp.ones((cfg.n_edges,)),
                             n_samples=bundle.counts)
    z = engine._schedule(cfg, spec, rc_all)
    np.testing.assert_array_equal(np.asarray(m.z), np.asarray(z))
    np.testing.assert_allclose(
        float(cost.apply_schedule(cfg, rc_all, z).cost), float(m.cost),
        rtol=1e-6)


def test_semi_sync_fastest():
    t = jnp.asarray([3.0, 1.0, 2.0, 5.0])
    z = np.asarray(pdd.semi_sync_fastest(t, 2))
    assert z.tolist() == [0.0, 1.0, 1.0, 0.0]
