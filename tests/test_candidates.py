"""The (N, K) candidate frontier (DESIGN.md §9) against the dense paths.

The §9 parity contract: with K ≥ the maximum in-coverage degree the
candidate pipeline is BIT-IDENTICAL to dense —

* resolver: ``resolve_candidates`` == ``resolve_parallel`` == the numpy
  oracle (same sweeps, same matching), including tie-heavy and
  zero-coverage worlds;
* SIC: ``noma.sic_rates_assigned`` == the dense sorted/top-k
  ``noma.sic_rates_matrix`` read at the associated pairs;
* cost: ``cost.round_cost(assigned=...)`` == the dense bill with
  ``sic_impl="sorted"`` (the at-scale dense path), NOMA and OMA alike;
* engine: candidate ``run_scanned`` == dense ``run_scanned`` metrics,
  static and dynamic scenarios.

With K < the coverage degree the candidate market is pruned but still
FEASIBLE: one edge per client, per-edge quota, only valid (in-coverage,
available, K-nearest) pairs ever admitted.

Property tests run under hypothesis when installed (CI) and collect as
skips in the offline container (tests/_hyp.py); the plain fixed-seed
tests below cover the same corners either way.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or its absent-shim

from repro.configs.hfl_mnist import CONFIG
from repro.core import association, candidates, cost, engine, fuzzy, noma

CFG = dataclasses.replace(CONFIG, n_clients=24, n_edges=3,
                          clients_per_edge=3, min_samples=60,
                          max_samples=120, hidden=16, input_dim=32)


def _world(n, m, seed, *, tie_heavy=False, drop_cov=0.0):
    """A random (dist, pref, coverage) world; ``tie_heavy`` quantises
    distances and shares one preference vector across edges so multi-edge
    conflicts and exact ties are constant; ``drop_cov`` knocks clients out
    of ALL coverage."""
    rng = np.random.default_rng(seed)
    if tie_heavy:
        dist = rng.choice([50.0, 100.0, 150.0], (n, m)).astype(np.float32)
        pref = np.broadcast_to(
            rng.permutation(n).astype(np.float32)[:, None], (n, m)).copy()
        radius = 120.0
    else:
        dist = rng.uniform(10.0, 400.0, (n, m)).astype(np.float32)
        pref = rng.uniform(0.0, 100.0, (n, m)).astype(np.float32)
        radius = float(rng.uniform(150.0, 400.0))
    cov = dist <= radius
    if drop_cov > 0:
        dead = rng.random(n) < drop_cov
        cov[dead] = False
        radius_row = np.where(dead, -1.0, radius)     # not used downstream
        del radius_row
    return dist, pref, cov, radius


def _dense_assoc(pref, dist, cov, quota):
    masked = jnp.where(jnp.asarray(cov), jnp.asarray(pref), -jnp.inf)
    order = jnp.argsort(-masked, axis=0).T
    return np.asarray(association.resolve_parallel(
        order, jnp.asarray(dist), quota, jnp.asarray(cov)))


def _cand_assoc(pref, dist, cov, radius, quota, k, avail=None):
    cand = candidates.build_candidates(
        jnp.asarray(dist), k, coverage_radius_m=radius, avail=avail)
    pk = candidates.gather(cand, jnp.asarray(pref))
    assigned = association.resolve_candidates(pk, cand, quota,
                                              dist.shape[1])
    return np.asarray(assigned), cand


def _check_parity(n, m, quota, seed, *, tie_heavy=False, drop_cov=0.0):
    dist, pref, cov, radius = _world(n, m, seed, tie_heavy=tie_heavy,
                                     drop_cov=drop_cov)
    if drop_cov > 0:
        # zero-coverage clients enter through the avail mask (the §6 path)
        avail = jnp.asarray(cov.any(axis=1).astype(np.float32))
        cov = cov & np.asarray(avail > 0)[:, None]
    else:
        avail = None
    deg = max(int(cov.sum(axis=1).max()), 1) if cov.any() else 1
    want = _dense_assoc(pref, dist, cov, quota)
    got, _ = _cand_assoc(pref, dist, cov, radius, quota, deg, avail)
    np.testing.assert_array_equal(candidates.assigned_one_hot(
        jnp.asarray(got), m), want)
    # the numpy oracle agrees too (transitively via test_association, but
    # pin it directly so a dense regression cannot mask a candidate one)
    order = np.argsort(-np.where(cov, pref, -np.inf), axis=0,
                       kind="stable").T
    np.testing.assert_array_equal(
        association._resolve(order, dist, quota, cov), want)


# ---------------------------------------------------------------------------
# Resolver parity (K ≥ max coverage degree ⇒ bit-identical)
# ---------------------------------------------------------------------------

@settings(max_examples=40, deadline=None)
@given(st.integers(2, 24), st.integers(1, 5), st.integers(1, 6),
       st.integers(0, 10_000))
def test_resolver_parity_random(n, m, quota, seed):
    _check_parity(n, m, quota, seed)


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 16), st.integers(2, 4), st.integers(1, 4),
       st.integers(0, 10_000))
def test_resolver_parity_tie_heavy(n, m, quota, seed):
    _check_parity(n, m, quota, seed, tie_heavy=True)


@settings(max_examples=25, deadline=None)
@given(st.integers(4, 16), st.integers(1, 4), st.integers(1, 4),
       st.integers(0, 10_000))
def test_resolver_parity_zero_coverage(n, m, quota, seed):
    _check_parity(n, m, quota, seed, drop_cov=0.4)


def test_resolver_parity_fixed_corners():
    """The same corners as plain tests, so the offline container (no
    hypothesis) still exercises every branch."""
    for seed in range(8):
        _check_parity(12, 3, 2, seed)
        _check_parity(10, 4, 3, seed, tie_heavy=True)
        _check_parity(12, 2, 2, seed, drop_cov=0.5)


@settings(max_examples=25, deadline=None)
@given(st.integers(4, 20), st.integers(2, 5), st.integers(1, 4),
       st.integers(1, 3), st.integers(0, 10_000))
def test_small_k_degrades_gracefully(n, m, quota, k, seed):
    _check_small_k(n, m, quota, k, seed)


def _check_small_k(n, m, quota, k, seed):
    """K below the coverage degree: the admitted set must stay feasible
    and every admitted pair must be valid (in-coverage, K-nearest)."""
    dist, pref, cov, radius = _world(n, m, seed)
    k = min(k, m)
    got, cand = _cand_assoc(pref, dist, cov, radius, quota, k)
    one = np.asarray(candidates.assigned_one_hot(jnp.asarray(got), m))
    assert (one.sum(axis=1) <= 1).all()
    assert (one.sum(axis=0) <= quota).all()
    idx, valid = np.asarray(cand.idx), np.asarray(cand.valid)
    for c in np.nonzero(got >= 0)[0]:
        slot = np.nonzero(idx[c] == got[c])[0]
        assert slot.size == 1 and valid[c, slot[0]]
        assert dist[c, got[c]] <= radius


def test_small_k_fixed_corners():
    for seed in range(6):
        _check_small_k(16, 4, 2, 1, seed)
        _check_small_k(16, 4, 2, 2, seed)


def test_build_candidates_row_order():
    """idx rows are (distance, edge index)-sorted — the strict client
    preference order the resolver's first-minimum argmin relies on."""
    dist = jnp.asarray([[3.0, 1.0, 2.0, 1.0],
                        [5.0, 5.0, 5.0, 5.0]], jnp.float32)
    cand = candidates.build_candidates(dist, 4, coverage_radius_m=4.0)
    np.testing.assert_array_equal(np.asarray(cand.idx),
                                  [[1, 3, 2, 0], [0, 1, 2, 3]])
    np.testing.assert_array_equal(np.asarray(cand.valid),
                                  [[True, True, True, True], [False] * 4])
    assert np.asarray(cand.dist).shape == (2, 4)


def test_fcea_candidate_scores_match_dense_gather():
    rng = np.random.default_rng(5)
    n, m, k = 20, 4, 2
    gains = jnp.asarray(rng.uniform(1e-12, 1e-8, (n, m)).astype(np.float32))
    counts = jnp.asarray(rng.integers(60, 120, n).astype(np.float32))
    stale = jnp.asarray(rng.integers(0, 5, n).astype(np.int32))
    dist = jnp.asarray(rng.uniform(10, 400, (n, m)).astype(np.float32))
    cand = candidates.build_candidates(dist, k, coverage_radius_m=300.0)
    dense = fuzzy.score_matrix(gains, counts, stale, data_max=120.0)
    got = fuzzy.score_candidates(gains, cand, counts, stale, data_max=120.0)
    np.testing.assert_array_equal(
        np.asarray(got), np.asarray(candidates.gather(cand, dense)))


def test_dense_scores_rejected_by_candidate_association():
    """The (N, M)-shaped dense matrix is ambiguous at K = M — the API
    must refuse it rather than silently double-gather."""
    rng = np.random.default_rng(0)
    n, m = 8, 3
    dist = jnp.asarray(rng.uniform(10, 400, (n, m)).astype(np.float32))
    cand = candidates.build_candidates(dist, 2, coverage_radius_m=500.0)
    with pytest.raises(ValueError, match="frontier"):
        association.associate_candidates(
            "fcea", scores=jnp.zeros((n, m)), gains=jnp.ones((n, m)),
            cand=cand, quota=2, key=jax.random.key(0), n_edges=m)


# ---------------------------------------------------------------------------
# SIC + cost parity
# ---------------------------------------------------------------------------

def _assigned_world(n, m, quota, seed):
    rng = np.random.default_rng(seed)
    gains = jnp.asarray(rng.uniform(1e-12, 1e-8, (n, m)).astype(np.float32))
    power = jnp.asarray(rng.uniform(0.05, 0.5, n).astype(np.float32))
    # a feasible assignment respecting the quota (some clients unmatched)
    assigned = np.full(n, -1, np.int64)
    slots = [q for e in range(m) for q in [e] * quota]
    picks = rng.permutation(n)[:min(len(slots), int(n * 0.8))]
    for i, c in enumerate(picks):
        assigned[c] = slots[i]
    assigned = jnp.asarray(assigned, jnp.int32)
    return gains, power, assigned


def _check_sic_parity(n, m, quota, seed):
    gains, power, assigned = _assigned_world(n, m, quota, seed)
    mask = np.asarray(candidates.assigned_one_hot(assigned, m)) > 0
    dense = noma.sic_rates_matrix(power, gains, jnp.asarray(mask),
                                  bandwidth_hz=CFG.bandwidth_hz,
                                  noise_w=1e-13, max_per_edge=quota)
    own_gain = candidates.own_edge_gather(assigned, gains)
    got = noma.sic_rates_assigned(power, own_gain, assigned, n_edges=m,
                                  max_per_edge=quota,
                                  bandwidth_hz=CFG.bandwidth_hz,
                                  noise_w=1e-13)
    want = np.asarray(jnp.sum(dense * mask, axis=1))
    np.testing.assert_array_equal(np.asarray(got), want)


@settings(max_examples=30, deadline=None)
@given(st.integers(2, 32), st.integers(1, 5), st.integers(1, 6),
       st.integers(0, 10_000))
def test_sic_assigned_matches_dense_sorted(n, m, quota, seed):
    _check_sic_parity(n, m, quota, seed)


def test_sic_assigned_fixed_corners():
    for seed in range(8):
        _check_sic_parity(24, 3, 3, seed)
        _check_sic_parity(6, 2, 5, seed)       # quota ≥ N: full-sort branch
        _check_sic_parity(4, 1, 2, seed)


@pytest.mark.parametrize("noma_enabled", [True, False])
def test_round_cost_assigned_matches_dense(noma_enabled):
    """The full Eq. 23a bill: compact == dense-sorted, bit for bit."""
    for seed in range(5):
        n, m, quota = 24, 3, 3
        gains, power, assigned = _assigned_world(n, m, quota, seed)
        rng = np.random.default_rng(seed + 100)
        f_hz = jnp.asarray(rng.uniform(CFG.f_min_hz, CFG.f_max_hz,
                                       n).astype(np.float32))
        counts = jnp.asarray(rng.integers(60, 120, n).astype(np.float32))
        z = jnp.asarray(rng.integers(0, 2, m).astype(np.float32))
        assoc = candidates.assigned_one_hot(assigned, m).astype(jnp.float32)
        dense = cost.round_cost(CFG, power_w=power, f_hz=f_hz, gains=gains,
                                assoc=assoc, z=z, n_samples=counts,
                                noma_enabled=noma_enabled,
                                sic_impl="sorted", sic_max_per_edge=quota)
        got = cost.round_cost(CFG, power_w=power, f_hz=f_hz, gains=gains,
                              assoc=assoc, z=z, n_samples=counts,
                              noma_enabled=noma_enabled,
                              sic_max_per_edge=quota, assigned=assigned)
        for field in dense._fields:
            np.testing.assert_array_equal(
                np.asarray(getattr(dense, field)),
                np.asarray(getattr(got, field)), err_msg=field)


def test_round_cost_assigned_requires_bound():
    with pytest.raises(ValueError, match="sic_max_per_edge"):
        cost.round_cost(CFG, power_w=jnp.ones(4), f_hz=jnp.ones(4),
                        gains=jnp.ones((4, 2)), assoc=jnp.zeros((4, 2)),
                        z=jnp.ones(2), n_samples=jnp.ones(4),
                        assigned=jnp.zeros(4, jnp.int32))


# ---------------------------------------------------------------------------
# Engine end-to-end parity (the whole round pipeline, scanned)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("policy", ["fcea", "gcea", "rcea"])
def test_engine_candidate_matches_dense_static(policy):
    state, bundle, _ = engine.init_simulation(CFG, seed=0)
    dense = engine.EngineSpec(policy=policy, scheduler="fastest",
                              sic_impl="sorted")
    candi = dataclasses.replace(dense, candidates_k=CFG.n_edges)
    _, md = engine.run_scanned(CFG, dense, state, bundle, 3)
    _, mc = engine.run_scanned(CFG, candi, state, bundle, 3)
    for f in md._fields:
        np.testing.assert_array_equal(np.asarray(getattr(md, f)),
                                      np.asarray(getattr(mc, f)),
                                      err_msg=f"{policy}:{f}")


def test_engine_candidate_matches_dense_dynamic():
    state, bundle, _ = engine.init_simulation(CFG, seed=1,
                                              scenario="full_dynamic")
    dense = engine.EngineSpec(policy="fcea", scheduler="fastest",
                              sic_impl="sorted", scenario="dynamic")
    candi = dataclasses.replace(dense, candidates_k=CFG.n_edges)
    _, md = engine.run_scanned(CFG, dense, state, bundle, 3)
    _, mc = engine.run_scanned(CFG, candi, state, bundle, 3)
    for f in md._fields:
        np.testing.assert_array_equal(np.asarray(getattr(md, f)),
                                      np.asarray(getattr(mc, f)),
                                      err_msg=f)


def test_engine_small_k_runs_and_is_feasible():
    state, bundle, _ = engine.init_simulation(CFG, seed=0)
    spec = engine.EngineSpec(policy="fcea", scheduler="fastest",
                             candidates_k=1)
    assoc = np.asarray(engine.associate_snapshot(CFG, spec, state, bundle))
    assert (assoc.sum(axis=1) <= 1).all()
    assert (assoc.sum(axis=0) <= CFG.clients_per_edge).all()
    _, ms = engine.run_scanned(CFG, spec, state, bundle, 2)
    assert np.isfinite(np.asarray(ms.cost)).all()


def test_max_coverage_degree_helper():
    dist = np.asarray([[1.0, 2.0, 9.0], [9.0, 9.0, 9.0], [1.0, 1.0, 1.0]])
    assert candidates.max_coverage_degree(dist, 5.0) == 3
    avail = np.asarray([1.0, 1.0, 0.0])
    assert candidates.max_coverage_degree(dist, 5.0, avail) == 2
