"""Semi-async buffered round engine tests (DESIGN.md §11).

(a) the static mode switch: ``engine_mode="sync"`` (explicit or default)
    keeps the buffer STRUCTURALLY absent and reproduces the committed
    golden trajectories bit-for-bit — the buffered refactor must not
    perturb the barrier engine at all,
(b) buffered micro-step semantics: the fill-or-timeout trigger fires at
    EXACTLY (fill ≥ buffer_fill) ∨ (clock ≥ last_agg + timeout_s),
    reconstructed per-step from the telemetry trace,
(c) landing semantics: a drained client's Eq. 20 counter resets to 1 and
    its in-flight flag clears, so it re-enters the market fresh,
(d) buffer algebra properties (via the _hyp shim — these collect as
    skips when hypothesis is absent): the effective merge weights
    w_n / Σw sum to 1 (the merge is scale-invariant in the raw weights),
    the staleness discount lies in (0, 1] and decays monotonically, and
    the Eq. 20 counter saturates at ``STALENESS_MAX``,
(e) the buffered carry composes with the client-axis padding and the
    sweep grid's engine-mode axis.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hyp import given, settings, st
from repro import sweeps
from repro.configs.hfl_mnist import CONFIG
from repro.core import aggregation, engine, staleness

SMALL = dataclasses.replace(CONFIG, n_clients=16, n_edges=2,
                            clients_per_edge=3, min_samples=60,
                            max_samples=120, hidden=32, input_dim=64)
GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "static_parity.json")
ROUNDS = 4

SPEC_BUF = engine.EngineSpec(policy="gcea", scheduler="fastest",
                             engine_mode="buffered", n_tiers=2,
                             retier_every=3, timeout_s=5.0)


# -- (a) sync mode: structural absence + golden bit-parity -------------------

@pytest.mark.parametrize("policy,scheduler", [("fcea", "pdd"),
                                              ("gcea", "fastest")])
def test_sync_mode_bit_equal_golden(policy, scheduler):
    """An EXPLICIT engine_mode="sync" spec reproduces the goldens
    bit-for-bit (they were recorded before the buffer existed)."""
    with open(GOLDEN) as fh:
        golden = json.load(fh)["trajectories"][f"{policy}-{scheduler}"]
    spec = engine.EngineSpec(policy=policy, scheduler=scheduler,
                             engine_mode="sync")
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    final, ms = engine.run_scanned(SMALL, spec, state, bundle, ROUNDS)
    for field in ("accuracy", "loss", "cost", "total_time_s",
                  "total_energy_j", "avg_staleness"):
        np.testing.assert_array_equal(
            np.asarray(getattr(ms, field), np.float64),
            np.asarray(golden[field]), err_msg=field)
    assert final.buffer is None                 # structurally absent


def test_sync_strips_an_attached_buffer():
    spec_sync = engine.EngineSpec(policy="gcea", scheduler="fastest")
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    with_buf = engine.ensure_buffer(SMALL, SPEC_BUF, state)
    assert isinstance(with_buf.buffer, engine.BufferState)
    stripped = engine.ensure_buffer(SMALL, spec_sync, with_buf)
    assert stripped.buffer is None
    # and an already-normalised state passes through untouched
    assert engine.ensure_buffer(SMALL, spec_sync, state) is state
    assert engine.ensure_buffer(SMALL, SPEC_BUF, with_buf) is with_buf


def test_unknown_engine_mode_raises():
    spec = engine.EngineSpec(engine_mode="psync")
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    with pytest.raises(ValueError, match="engine_mode"):
        engine.round_step(SMALL, spec, state, bundle)


# -- (b) the fill-or-timeout trigger, reconstructed exactly ------------------

def test_trigger_fires_at_exactly_fill_or_timeout():
    """Replay the virtual clock from (dt, fill, cause) telemetry and check
    the trigger bit matches (fill ≥ target) ∨ (clock ≥ deadline) at EVERY
    micro-step — no early, late or spurious merges."""
    spec = dataclasses.replace(SPEC_BUF, telemetry=True)
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    steps = 24
    final, (ms, tr) = engine.run_scanned(SMALL, spec, state, bundle, steps)
    target = engine.buffer_fill_for(SMALL, spec)
    dt = np.asarray(ms.total_time_s, np.float64)
    fill = np.asarray(tr.buffer_fill)
    cause = np.asarray(tr.trigger_cause)
    fired = np.asarray(ms.z)[:, 0] > 0          # merge applied this step

    clock, last_agg = 0.0, 0.0
    eps = 1e-4
    n_merges = 0
    for i in range(steps):
        clock += dt[i]
        deadline = last_agg + spec.timeout_s
        by_fill = fill[i] >= target
        by_time = clock >= deadline - eps
        want_fired = by_fill or by_time
        # cause 0 = no trigger, 1 = fill, 2 = timeout (fill wins ties)
        want_cause = 0 if not want_fired else (1 if by_fill else 2)
        assert cause[i] == want_cause, f"step {i}"
        if want_fired:
            last_agg = clock
            if fill[i] > 0:
                n_merges += 1
        # the metrics z bit is the APPLIED merge (trigger ∧ non-empty)
        assert fired[i] == (want_fired and fill[i] > 0), f"step {i}"
    assert float(final.buffer.clock_s) == pytest.approx(clock, rel=1e-5)
    assert int(final.buffer.version) == n_merges
    assert n_merges >= 1                        # the run actually merged


def test_buffered_progresses_and_keeps_carry_invariants():
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    final, ms = engine.run_scanned(SMALL, SPEC_BUF, state, bundle, 16)
    buf = final.buffer
    assert isinstance(buf, engine.BufferState)
    assert float(buf.clock_s) > 0.0
    assert int(buf.step) == 16
    assert np.all(np.asarray(ms.total_time_s) >= 0.0)       # clock monotone
    assert int(buf.fill) >= 0 and float(buf.weight_sum) >= 0.0
    # tiers always index a valid TiFL bucket
    assert np.all((np.asarray(buf.tier) >= 0)
                  & (np.asarray(buf.tier) < SPEC_BUF.n_tiers))
    # micro-step metrics count the admitted cohort, never more than quota·M
    cap = engine.quota_for(SMALL, SPEC_BUF) * SMALL.n_edges
    assert np.all(np.asarray(ms.n_associated) <= cap)


# -- (c) drained clients re-enter fresh --------------------------------------

def test_drained_client_resets_staleness_and_in_flight():
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    state = engine.ensure_buffer(SMALL, SPEC_BUF, state)
    n = SMALL.n_clients
    # client 1: in flight, tier 1 (not admitted at step 0), finishing
    # immediately; client 3: in flight, finishing far in the future
    in_flight = jnp.zeros((n,), bool).at[1].set(True).at[3].set(True)
    finish = jnp.zeros((n,)).at[1].set(1e-4).at[3].set(1e6)
    tier = jnp.zeros((n,), jnp.int32).at[1].set(1).at[3].set(1)
    buf = state.buffer._replace(in_flight=in_flight, finish_s=finish,
                                tier=tier)
    state = state._replace(buffer=buf,
                           staleness=jnp.full((n,), 7, jnp.int32))
    new_state, ms = engine.round_step(SMALL, SPEC_BUF, state, bundle)
    stale = np.asarray(new_state.staleness)
    nbuf = new_state.buffer
    assert stale[1] == 1                        # landed -> reset (Eq. 20)
    assert not bool(nbuf.in_flight[1])          # drained -> idle again
    assert stale[3] == 8                        # still flying -> +1
    assert bool(nbuf.in_flight[3])
    assert int(nbuf.fill) >= 1                  # the landing was buffered


# -- (d) buffer algebra properties (skip without hypothesis) -----------------

@given(st.floats(0.1, 50.0), st.floats(0.1, 50.0), st.floats(0.1, 50.0),
       st.floats(0.01, 100.0))
@settings(max_examples=25, deadline=None)
def test_merge_weights_sum_to_one(w1, w2, w3, scale):
    """The applied step is Σwδ/Σw: rescaling every raw weight by a common
    factor changes nothing, and identical deltas merge to exactly that
    delta — i.e. the effective weights sum to 1."""
    g = {"w": jnp.zeros((3,)), "b": jnp.zeros(())}
    weights = jnp.asarray([w1, w2, w3], jnp.float32)
    v = jnp.asarray([1.0, -2.0, 0.5])
    deltas = {"w": jnp.tile(v[None], (3, 1)), "b": jnp.ones((3,))}
    fired = jnp.asarray(True)

    ds, ws = aggregation.buffer_accumulate(
        aggregation.buffer_zeros(g), jnp.zeros(()), deltas, weights)
    out = aggregation.buffer_apply(g, ds, ws, 1.0, fired)
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(v),
                               rtol=1e-5)
    np.testing.assert_allclose(float(out["b"]), 1.0, rtol=1e-5)
    # scale-invariance of the raw weights
    ds2, ws2 = aggregation.buffer_accumulate(
        aggregation.buffer_zeros(g), jnp.zeros(()), deltas,
        weights * jnp.float32(scale))
    out2 = aggregation.buffer_apply(g, ds2, ws2, 1.0, fired)
    np.testing.assert_allclose(np.asarray(out2["w"]),
                               np.asarray(out["w"]), rtol=1e-4)


@given(st.integers(1, 10**7), st.integers(0, 10**7))
@settings(max_examples=50, deadline=None)
def test_staleness_weight_bounded_and_monotone(age, bump):
    w = float(staleness.buffer_weight(jnp.asarray(age)))
    w2 = float(staleness.buffer_weight(jnp.asarray(age + bump)))
    assert 0.0 < w <= 1.0
    assert w2 <= w + 1e-7                       # older is never up-weighted
    if age == 1:
        assert w == pytest.approx(1.0)          # fresh update undiscounted


@given(st.integers(1, 2**30))
@settings(max_examples=50, deadline=None)
def test_update_staleness_saturates(a):
    stale = jnp.asarray([a], jnp.int32)
    out = int(staleness.update_staleness(stale,
                                         jnp.asarray([False]))[0])
    assert out == min(a + 1, staleness.STALENESS_MAX)
    assert int(staleness.update_staleness(
        jnp.asarray([staleness.STALENESS_MAX], jnp.int32),
        jnp.asarray([False]))[0]) == staleness.STALENESS_MAX


def test_buffer_age_saturates_and_floors():
    ver = jnp.asarray(5, jnp.int32)
    assert int(staleness.buffer_age(ver, jnp.asarray(5, jnp.int32))) == 1
    assert int(staleness.buffer_age(ver, jnp.asarray(9, jnp.int32))) == 1
    big = jnp.asarray(staleness.STALENESS_MAX + 7, jnp.int32)
    assert int(staleness.buffer_age(big, jnp.asarray(0, jnp.int32))) \
        == staleness.STALENESS_MAX


# -- (e) composition: padding + the sweep grid's engine-mode axis ------------

def test_pad_clients_pads_the_buffer_too():
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    state = engine.ensure_buffer(SMALL, SPEC_BUF, state)
    cfg2, state2, bundle2 = engine.pad_clients(SMALL, state, bundle, 10)
    assert cfg2.n_clients == 20
    buf = state2.buffer
    assert buf.finish_s.shape == (20,) and buf.tier.shape == (20,)
    assert not np.any(np.asarray(buf.in_flight[SMALL.n_clients:]))
    # the padded world still steps (inert clients never associate)
    _, ms = engine.run_scanned(cfg2, SPEC_BUF, state2, bundle2, 3)
    assert np.all(np.asarray(ms.n_associated) <= np.asarray(ms.n_available)
                  + 0)


def test_sweep_engine_mode_axis_and_cell_ids(tmp_path):
    grid = sweeps.SweepGrid(name="bt", scenarios=("static",),
                            policies=("gcea",), schedulers=("fastest",),
                            seeds=(0,), n_rounds=2,
                            engine_modes=("sync", "buffered"))
    cells = sweeps.expand_grid(grid)
    ids = {c.cell_id for c in cells}
    assert ids == {"static__gcea__mid__fastest__noma__s0",
                   "static__gcea__mid__fastest__noma__s0__buffered"}
    summary = sweeps.run_sweep(SMALL, grid, out_dir=str(tmp_path))
    assert summary["n_cells"] == 2
    assert summary["n_compiles"] == 2           # one per engine mode
    assert set(summary["final"]) == ids


def test_stream_scanned_accepts_buffered_spec():
    """The streaming drivers must normalise the carry too: a buffered
    spec entering ``stream_scanned`` with ``state.buffer is None`` would
    otherwise change the scan-carry structure mid-scan."""
    from repro.telemetry import sink

    spec = dataclasses.replace(SPEC_BUF, telemetry=True)
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    assert state.buffer is None                    # the hazardous input
    mem = sink.MemorySink()
    final, ms, tr = sink.stream_scanned(SMALL, spec, state, bundle, 3, mem)
    assert len(mem.records) == 3
    assert final.buffer is not None
    assert int(final.buffer.step) == 3
    # the streamed trace carries the buffered leaves
    assert np.asarray(tr.buffer_fill).shape == (3,)
