"""Client-edge association policy tests (paper §III-B last paragraph)."""
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or its absent-shim

from repro.core import association


def _setup(n=12, m=3, seed=0):
    rng = np.random.default_rng(seed)
    dist = rng.uniform(10.0, 400.0, (n, m))
    scores = rng.uniform(0.0, 100.0, n)
    gains = rng.uniform(0.0, 1.0, (n, m)) * 1e-9
    return rng, dist, scores, gains


def test_fcea_quota_and_uniqueness():
    rng, dist, scores, _ = _setup()
    assoc = association.fcea(scores, dist, quota=3, coverage_radius_m=500.0)
    assert assoc.shape == (12, 3)
    assert (assoc.sum(axis=1) <= 1).all()          # one edge per client
    assert (assoc.sum(axis=0) <= 3).all()          # quota per edge


def test_fcea_prefers_high_scores():
    dist = np.full((4, 1), 100.0)
    scores = np.asarray([10.0, 90.0, 50.0, 70.0])
    assoc = association.fcea(scores, dist, quota=2, coverage_radius_m=500.0)
    chosen = set(np.where(assoc[:, 0] == 1)[0].tolist())
    assert chosen == {1, 3}


def test_conflict_resolves_to_nearest():
    """A doubly-wanted client goes to the nearer edge; the loser refills."""
    # 3 clients, 2 edges, quota 1; client 0 best for both, nearer to edge 1
    scores = np.asarray([[90.0, 90.0], [50.0, 10.0], [10.0, 50.0]])
    dist = np.asarray([[200.0, 50.0], [100.0, 100.0], [100.0, 100.0]])
    assoc = association.fcea(scores, dist, quota=1, coverage_radius_m=500.0)
    assert assoc[0, 1] == 1            # client 0 -> nearer edge 1
    assert assoc[1, 0] == 1            # edge 0 refills with its next best


def test_coverage_respected():
    scores = np.asarray([90.0, 80.0])
    dist = np.asarray([[600.0], [100.0]])
    assoc = association.fcea(scores, dist, quota=2, coverage_radius_m=500.0)
    assert assoc[0, 0] == 0 and assoc[1, 0] == 1


def test_gcea_picks_strongest_channel():
    dist = np.full((3, 1), 100.0)
    gains = np.asarray([[1e-9], [5e-9], [3e-9]])
    assoc = association.gcea(gains, dist, quota=1, coverage_radius_m=500.0)
    assert assoc[1, 0] == 1 and assoc.sum() == 1


@settings(max_examples=25, deadline=None)
@given(st.integers(4, 20), st.integers(1, 4), st.integers(1, 5),
       st.integers(0, 1000))
def test_invariants_all_policies(n, m, quota, seed):
    rng, dist, scores, gains = _setup(n, m, seed)
    for policy in ("fcea", "gcea", "rcea"):
        assoc = association.associate(
            policy, scores=scores, gains_to_edges=gains, dist=dist,
            quota=quota, coverage_radius_m=500.0, rng=rng)
        assert (assoc.sum(axis=1) <= 1).all()
        assert (assoc.sum(axis=0) <= quota).all()
        # every associated client is in coverage
        taken = np.argwhere(assoc == 1)
        for c, e in taken:
            assert dist[c, e] <= 500.0


def test_per_edge_scores_matrix_accepted():
    rng, dist, _, gains = _setup()
    scores2d = rng.uniform(0.0, 100.0, dist.shape)
    assoc = association.fcea(scores2d, dist, quota=2, coverage_radius_m=500.0)
    assert assoc.shape == dist.shape
