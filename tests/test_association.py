"""Client-edge association policy tests (paper §III-B last paragraph),
including oracle-vs-JAX parity for BOTH resolvers (the legacy serial
while-loop and the parallel sweep resolver, DESIGN.md §8.1) on the
degenerate corners: quota ≥ N, quota·M > N, zero-coverage clients and
all-edges-conflict preference/distance ties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or its absent-shim

from repro.core import association


def _setup(n=12, m=3, seed=0):
    rng = np.random.default_rng(seed)
    dist = rng.uniform(10.0, 400.0, (n, m))
    scores = rng.uniform(0.0, 100.0, n)
    gains = rng.uniform(0.0, 1.0, (n, m)) * 1e-9
    return rng, dist, scores, gains


def test_fcea_quota_and_uniqueness():
    rng, dist, scores, _ = _setup()
    assoc = association.fcea(scores, dist, quota=3, coverage_radius_m=500.0)
    assert assoc.shape == (12, 3)
    assert (assoc.sum(axis=1) <= 1).all()          # one edge per client
    assert (assoc.sum(axis=0) <= 3).all()          # quota per edge


def test_fcea_prefers_high_scores():
    dist = np.full((4, 1), 100.0)
    scores = np.asarray([10.0, 90.0, 50.0, 70.0])
    assoc = association.fcea(scores, dist, quota=2, coverage_radius_m=500.0)
    chosen = set(np.where(assoc[:, 0] == 1)[0].tolist())
    assert chosen == {1, 3}


def test_conflict_resolves_to_nearest():
    """A doubly-wanted client goes to the nearer edge; the loser refills."""
    # 3 clients, 2 edges, quota 1; client 0 best for both, nearer to edge 1
    scores = np.asarray([[90.0, 90.0], [50.0, 10.0], [10.0, 50.0]])
    dist = np.asarray([[200.0, 50.0], [100.0, 100.0], [100.0, 100.0]])
    assoc = association.fcea(scores, dist, quota=1, coverage_radius_m=500.0)
    assert assoc[0, 1] == 1            # client 0 -> nearer edge 1
    assert assoc[1, 0] == 1            # edge 0 refills with its next best


def test_coverage_respected():
    scores = np.asarray([90.0, 80.0])
    dist = np.asarray([[600.0], [100.0]])
    assoc = association.fcea(scores, dist, quota=2, coverage_radius_m=500.0)
    assert assoc[0, 0] == 0 and assoc[1, 0] == 1


def test_gcea_picks_strongest_channel():
    dist = np.full((3, 1), 100.0)
    gains = np.asarray([[1e-9], [5e-9], [3e-9]])
    assoc = association.gcea(gains, dist, quota=1, coverage_radius_m=500.0)
    assert assoc[1, 0] == 1 and assoc.sum() == 1


@settings(max_examples=25, deadline=None)
@given(st.integers(4, 20), st.integers(1, 4), st.integers(1, 5),
       st.integers(0, 1000))
def test_invariants_all_policies(n, m, quota, seed):
    rng, dist, scores, gains = _setup(n, m, seed)
    for policy in ("fcea", "gcea", "rcea"):
        assoc = association.associate(
            policy, scores=scores, gains_to_edges=gains, dist=dist,
            quota=quota, coverage_radius_m=500.0, rng=rng)
        assert (assoc.sum(axis=1) <= 1).all()
        assert (assoc.sum(axis=0) <= quota).all()
        # every associated client is in coverage
        taken = np.argwhere(assoc == 1)
        for c, e in taken:
            assert dist[c, e] <= 500.0


def test_per_edge_scores_matrix_accepted():
    rng, dist, _, gains = _setup()
    scores2d = rng.uniform(0.0, 100.0, dist.shape)
    assoc = association.fcea(scores2d, dist, quota=2, coverage_radius_m=500.0)
    assert assoc.shape == dist.shape


# ---------------------------------------------------------------------------
# Oracle-vs-JAX resolver parity (both implementations, degenerate corners)
# ---------------------------------------------------------------------------

def _both_resolvers(order, dist, quota, cov):
    want = association._resolve(order, dist, quota, cov)
    for name, fn in association.RESOLVERS.items():
        got = np.asarray(fn(jnp.asarray(order), jnp.asarray(dist), quota,
                            jnp.asarray(cov)))
        np.testing.assert_array_equal(got, want, err_msg=name)
    return want


def _order_from(pref, cov):
    return np.argsort(-np.where(cov, pref, -np.inf), axis=0,
                      kind="stable").T


@settings(max_examples=40, deadline=None)
@given(st.integers(1, 24), st.integers(1, 5), st.integers(1, 30),
       st.integers(0, 10_000))
def test_resolvers_match_oracle_random(n, m, quota, seed):
    """Property parity on randomized topologies, quota up to ≫ N."""
    rng = np.random.default_rng(seed)
    dist = rng.uniform(10.0, 400.0, (n, m)).astype(np.float32)
    pref = rng.uniform(0.0, 100.0, (n, m)).astype(np.float32)
    cov = dist <= rng.uniform(100.0, 400.0)
    assoc = _both_resolvers(_order_from(pref, cov), dist, quota, cov)
    assert (assoc.sum(axis=1) <= 1).all()
    assert (assoc.sum(axis=0) <= quota).all()


def test_quota_at_least_n_admits_every_covered_client():
    """quota ≥ N: every in-coverage client lands somewhere."""
    rng = np.random.default_rng(1)
    n, m = 10, 3
    dist = rng.uniform(10.0, 300.0, (n, m)).astype(np.float32)
    pref = rng.uniform(0.0, 100.0, (n, m)).astype(np.float32)
    cov = np.ones((n, m), bool)
    assoc = _both_resolvers(_order_from(pref, cov), dist, n + 5, cov)
    assert assoc.sum() == n
    # with every edge's quota open, each client gets its NEAREST edge
    np.testing.assert_array_equal(np.argmax(assoc, axis=1),
                                  np.argmin(dist, axis=1))


def test_total_quota_exceeds_n():
    """quota·M > N but quota < N: all covered clients admitted."""
    rng = np.random.default_rng(2)
    n, m, quota = 9, 4, 3                   # 12 slots for 9 clients
    dist = rng.uniform(10.0, 300.0, (n, m)).astype(np.float32)
    pref = rng.uniform(0.0, 100.0, (n, m)).astype(np.float32)
    cov = np.ones((n, m), bool)
    assoc = _both_resolvers(_order_from(pref, cov), dist, quota, cov)
    assert assoc.sum() == n


def test_zero_coverage_client_never_admitted():
    rng = np.random.default_rng(3)
    n, m = 8, 2
    dist = rng.uniform(10.0, 300.0, (n, m)).astype(np.float32)
    pref = rng.uniform(0.0, 100.0, (n, m)).astype(np.float32)
    cov = np.ones((n, m), bool)
    cov[3] = False                          # client 3 sees no edge at all
    assoc = _both_resolvers(_order_from(pref, cov), dist, 4, cov)
    assert assoc[3].sum() == 0


def test_all_clients_conflict_with_ties():
    """Every edge ranks clients identically AND distances tie exactly:
    the (distance, edge-index) tie-break keeps serial == parallel ==
    oracle bit-for-bit."""
    n, m, quota = 6, 3, 2
    pref = np.broadcast_to(
        np.asarray([5., 4., 3., 2., 1., 0.], np.float32)[:, None],
        (n, m)).copy()                      # all edges want client 0 first
    dist = np.full((n, m), 100.0, np.float32)      # every distance ties
    cov = np.ones((n, m), bool)
    assoc = _both_resolvers(_order_from(pref, cov), dist, quota, cov)
    assert assoc.sum() == n                 # quota·M = N: everyone admitted
    # ties resolve to the lowest edge index in preference order
    np.testing.assert_array_equal(np.argmax(assoc, axis=1),
                                  [0, 0, 1, 1, 2, 2])


@settings(max_examples=25, deadline=None)
@given(st.integers(2, 16), st.integers(2, 4), st.integers(1, 4),
       st.integers(0, 10_000))
def test_resolvers_match_oracle_under_ties(n, m, quota, seed):
    """Property parity on tie-heavy worlds: quantised distances and
    shared preference vectors force constant multi-edge conflicts."""
    rng = np.random.default_rng(seed)
    dist = rng.choice([50.0, 100.0, 150.0], (n, m)).astype(np.float32)
    pref = np.broadcast_to(
        rng.permutation(n).astype(np.float32)[:, None], (n, m)).copy()
    cov = rng.random((n, m)) < 0.8
    _both_resolvers(_order_from(pref, cov), dist, quota, cov)


def test_resolver_registry_and_unknown_name():
    assert set(association.RESOLVERS) == {"parallel", "serial"}
    rng = np.random.default_rng(0)
    with pytest.raises(ValueError, match="resolver"):
        association.associate_jax(
            "gcea", scores=None, gains=jnp.ones((4, 2)) * 1e-9,
            dist=jnp.asarray(rng.uniform(10, 300, (4, 2))), quota=1,
            coverage_radius_m=500.0, key=jax.random.key(0),
            resolver="bogus")
