"""Decode-vs-teacher-forcing parity: step-by-step decode with the KV cache
must reproduce the full forward's logits — per mask family (global, sliding
ring buffer, chunked ring buffer, prefix-LM, recurrent states, enc-dec)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model

ATOL = 2e-4


def _roundtrip(cfg, key, s=24):
    model = build_model(cfg)
    params = model.init(key)
    k1, k2 = jax.random.split(key)
    toks = jax.random.randint(k1, (2, s), 0, cfg.vocab_size, jnp.int32)

    extra = None
    prefix = cfg.prefix_tokens
    if cfg.encoder_layers:
        extra = jax.random.normal(k2, (2, cfg.stub_frames, cfg.d_model),
                                  cfg.compute_dtype)
    elif prefix:
        extra = jax.random.normal(k2, (2, prefix, cfg.d_model),
                                  cfg.compute_dtype)

    full_logits, _ = model.apply(params, toks, extra_embeddings=extra)

    if cfg.encoder_layers:
        cache = model.init_cache(2, s, cfg.stub_frames)
        cache = model.prefill_cross(params, cache, extra)
    else:
        cache = model.init_cache(2, s + prefix)
        if prefix:
            cache = model.prefill_prefix(params, cache, extra)

    dec = jax.jit(lambda p, t, c, i: model.decode_step(
        p, t, c, i, prefix_len=prefix))
    outs = []
    for i in range(s):
        logits, cache = dec(params, toks[:, i:i + 1], cache,
                            jnp.asarray(i + prefix, jnp.int32))
        outs.append(logits[:, 0])
    step_logits = jnp.stack(outs, axis=1)
    return np.asarray(full_logits, np.float32), \
        np.asarray(step_logits, np.float32)


@pytest.mark.parametrize("arch", [
    "qwen3-8b",                     # global causal + qk_norm + GQA
    "qwen1.5-110b",                 # qkv bias
    "yi-34b",                       # llama GQA
    "stablelm-1.6b",                # MHA
])
def test_dense_parity(arch, key):
    cfg = get_config(arch).reduced()
    full, step = _roundtrip(cfg, key)
    np.testing.assert_allclose(full, step, atol=ATOL, rtol=1e-3)


def test_sliding_window_ring_buffer(key):
    """recurrentgemma: RG-LRU state + sliding-window KV ring smaller than S."""
    cfg = get_config("recurrentgemma-9b").reduced().replace(window=8)
    full, step = _roundtrip(cfg, key, s=24)
    np.testing.assert_allclose(full, step, atol=ATOL, rtol=1e-3)


def test_chunked_ring_buffer(key):
    """llama4: chunked-local attention ring + NoPE global layers + MoE.

    capacity_factor is raised so no token is dropped — train-time capacity
    dropping is the one (intentional, MaxText-style) train/decode divergence,
    covered separately by test_moe_capacity_drops."""
    cfg = get_config("llama4-maverick-400b-a17b").reduced().replace(
        attn_chunk=8, moe_capacity_factor=8.0)
    full, step = _roundtrip(cfg, key, s=24)
    np.testing.assert_allclose(full, step, atol=ATOL, rtol=1e-3)


def test_moe_capacity_drops(key):
    """With a tight capacity factor, the batched forward drops tokens
    (combine weights zeroed) while decode never does — assert the drop
    actually occurs and the outputs stay finite."""
    from repro.models import moe as moe_mod
    cfg = get_config("llama4-maverick-400b-a17b").reduced().replace(
        moe_capacity_factor=0.3)
    model = build_model(cfg)
    params = model.init(key)
    toks = jax.random.randint(key, (2, 24), 0, cfg.vocab_size, jnp.int32)
    logits, aux = model.apply(params, toks)
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_prefix_lm_vlm(key):
    """paligemma: bidirectional prefix + causal text, MQA."""
    cfg = get_config("paligemma-3b").reduced()
    full, step = _roundtrip(cfg, key)
    np.testing.assert_allclose(full, step, atol=ATOL, rtol=1e-3)


def test_ssm_states(key):
    """xlstm: sLSTM + mLSTM recurrent decode states."""
    cfg = get_config("xlstm-125m").reduced()
    full, step = _roundtrip(cfg, key)
    np.testing.assert_allclose(full, step, atol=5e-4, rtol=1e-3)


def test_encdec_cross_attention(key):
    """whisper: decoder self-KV + precomputed cross-KV."""
    cfg = get_config("whisper-large-v3").reduced()
    full, step = _roundtrip(cfg, key)
    np.testing.assert_allclose(full, step, atol=ATOL, rtol=1e-3)
