"""Fault-injection & graceful-degradation tests (DESIGN.md §12).

(a) the static flag: ``EngineSpec.faults=None`` keeps the fault state
    STRUCTURALLY absent (``ensure_carry`` strips a stale ``FaultState``)
    and a no-fault run is bit-identical whether or not the fault code
    exists — the committed goldens stay valid un-re-recorded,
(b) injection-process units: the churn chain's min-edges veto, the
    exponential backoff schedule, the SINR-tied loss curve, orphan
    accounting and the quarantine guard's clip/reject algebra,
(c) graceful degradation end to end: a killed edge disappears from the
    association frontier and the cohort re-forms on survivors within one
    round; a lost uplink re-enters flight with backoff and either lands
    or is dropped after ``max_attempts``; an all-NaN poisoned round
    leaves the global model bit-unchanged; a scaled poisoned round is
    clipped to the quarantine sphere,
(d) run-level fault tolerance: ``run_scanned_resumable`` interrupted
    mid-run (max_segments=1) resumes to a trajectory BIT-IDENTICAL to
    the uninterrupted scan, typed PRNG key included, and the checkpoint
    store round-trips the full buffered+faulted carry exactly,
(e) the chaos sweep axis: a ``SweepGrid(faults=...)`` runs end to end,
    and a crashed group is isolated into ``summary["failed_cells"]``
    instead of killing the sweep.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs.hfl_mnist import CONFIG
from repro.core import engine
from repro.faults import FaultSpec, FaultState, guard, inject
from repro.faults.resume import run_scanned_resumable

SMALL = dataclasses.replace(CONFIG, n_clients=16, n_edges=2,
                            clients_per_edge=3, min_samples=60,
                            max_samples=120, hidden=32, input_dim=64)
ROUNDS = 4

SPEC_SYNC = engine.EngineSpec(policy="gcea", scheduler="fastest")
SPEC_BUF = engine.EngineSpec(policy="gcea", scheduler="fastest",
                             engine_mode="buffered", n_tiers=2,
                             retier_every=3, timeout_s=5.0)
# churn frozen (kill=respawn=0): a pre-set edge_up mask stays put, so the
# degradation under test is deterministic
FROZEN = dict(edge_p_kill=0.0, edge_p_respawn=0.0)


def _faulted(spec, **kw):
    return dataclasses.replace(spec, faults=FaultSpec(**kw))


def _tree_equal(a, b, msg=""):
    fa, _ = jax.tree_util.tree_flatten(a)
    fb, _ = jax.tree_util.tree_flatten(b)
    assert len(fa) == len(fb), msg
    for la, lb in zip(fa, fb):
        if (isinstance(la, jax.Array)
                and jax.dtypes.issubdtype(la.dtype, jax.dtypes.prng_key)):
            la, lb = jax.random.key_data(la), jax.random.key_data(lb)
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=msg)


def _delta_norm(a, b):
    return float(jnp.sqrt(sum(
        jnp.sum((x - y) ** 2) for x, y in
        zip(jax.tree.leaves(a), jax.tree.leaves(b)))))


# -- (a) static flag: structural absence + no-fault bit-parity ---------------

def test_ensure_carry_attaches_and_strips_fault_state():
    spec_f = _faulted(SPEC_SYNC, edge_p_kill=0.3)
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    with_f = engine.ensure_carry(SMALL, spec_f, state)
    assert isinstance(with_f.faults, FaultState)
    assert with_f.faults.edge_up.shape == (SMALL.n_edges,)
    # faults-off spec strips a stale FaultState (e.g. a spec change
    # between runs); normalised states pass through untouched
    stripped = engine.ensure_carry(SMALL, SPEC_SYNC, with_f)
    assert stripped.faults is None
    assert engine.ensure_carry(SMALL, SPEC_SYNC, state) is state
    assert engine.ensure_carry(SMALL, spec_f, with_f) is with_f


def test_no_fault_run_ignores_stale_fault_state():
    """run_scanned with faults=None produces the same trajectory whether
    the input carry holds a stale FaultState or not — ensure_carry
    normalises before tracing, so the no-fault program never sees it."""
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    stale = engine.ensure_carry(SMALL, _faulted(SPEC_SYNC), state)
    f_clean, ms_clean = engine.run_scanned(SMALL, SPEC_SYNC, state, bundle,
                                           ROUNDS)
    f_stale, ms_stale = engine.run_scanned(SMALL, SPEC_SYNC, stale, bundle,
                                           ROUNDS)
    _tree_equal(ms_clean, ms_stale, "metrics")
    assert f_stale.faults is None
    _tree_equal(f_clean.global_params, f_stale.global_params, "global")


# -- (b) injection-process units ---------------------------------------------

def test_advance_edges_min_edges_veto():
    fsp = FaultSpec(edge_p_kill=1.0, edge_p_respawn=0.0, min_edges_up=1)
    up = jnp.ones((3,), jnp.float32)
    # kill=1 would leave zero live edges — the step is vetoed wholesale
    nxt = inject.advance_edges(fsp, jax.random.key(0), up)
    np.testing.assert_array_equal(np.asarray(nxt), np.ones(3, np.float32))
    # with the veto disabled the same draw kills everything
    fsp0 = dataclasses.replace(fsp, min_edges_up=0)
    nxt0 = inject.advance_edges(fsp0, jax.random.key(0), up)
    np.testing.assert_array_equal(np.asarray(nxt0), np.zeros(3, np.float32))


def test_advance_edges_frozen_chain_is_identity():
    fsp = FaultSpec(**FROZEN)
    up = jnp.asarray([0.0, 1.0, 1.0], jnp.float32)
    nxt = inject.advance_edges(fsp, jax.random.key(7), up)
    np.testing.assert_array_equal(np.asarray(nxt), np.asarray(up))


def test_backoff_schedule_is_exponential():
    fsp = FaultSpec(backoff_base_s=2.0, backoff_factor=3.0)
    got = inject.backoff_s(fsp, jnp.asarray([0, 1, 2], jnp.int32))
    np.testing.assert_allclose(np.asarray(got), [2.0, 6.0, 18.0], rtol=1e-6)


def test_uplink_loss_prob_tied_to_channel():
    fsp = FaultSpec(uplink_p_loss=0.1, uplink_loss_slope=0.4)
    gains = jnp.asarray([[1.0, 0.0], [0.5, 0.0], [1e-9, 0.0]])
    p = np.asarray(inject.uplink_loss_prob(
        fsp, gains, jnp.ones((2,), jnp.float32)))
    assert p[0] == pytest.approx(0.1, abs=1e-6)       # best channel: floor
    assert p[1] == pytest.approx(0.3, abs=1e-6)       # halfway up the slope
    assert p[2] == pytest.approx(0.5, abs=1e-4)       # worst: floor + slope
    assert np.all(p <= 0.95)
    # a dead best edge worsens the proxy: client 1's best LIVE gain drops
    p_dead = np.asarray(inject.uplink_loss_prob(
        fsp, jnp.asarray([[1.0, 0.9], [0.5, 0.1]]),
        jnp.asarray([0.0, 1.0], jnp.float32)))
    assert p_dead[1] > p_dead[0]


def test_orphan_count_requires_all_covering_edges_dead():
    radius = 10.0
    #            edge0  edge1
    dist = jnp.asarray([[5.0, 50.0],     # covered by edge 0 only
                        [5.0, 5.0],      # covered by both
                        [50.0, 50.0]])   # out of coverage entirely
    dead0 = jnp.asarray([0.0, 1.0], jnp.float32)
    assert int(inject.orphan_count(dist, dead0, radius, None)) == 1
    all_up = jnp.ones((2,), jnp.float32)
    assert int(inject.orphan_count(dist, all_up, radius, None)) == 0
    all_dead = jnp.zeros((2,), jnp.float32)
    assert int(inject.orphan_count(dist, all_dead, radius, None)) == 2
    # unavailable clients don't count as orphans
    avail = jnp.asarray([0.0, 1.0, 1.0])
    assert int(inject.orphan_count(dist, dead0, radius, avail)) == 0


def test_quarantine_rejects_nonfinite_and_clips():
    deltas = {"w": jnp.asarray([[3.0, 4.0],        # norm 5 — clipped
                                [jnp.nan, 1.0],    # rejected
                                [0.1, 0.0],        # small — untouched
                                [9.9, 9.9]])}      # not produced
    produced = jnp.asarray([True, True, True, False])
    cleaned, ok, n_rej = guard.quarantine(deltas, produced, clip=1.0)
    c = np.asarray(cleaned["w"])
    assert np.all(np.isfinite(c))                  # zero-first: no NaN out
    np.testing.assert_allclose(np.linalg.norm(c[0]), 1.0, rtol=1e-5)
    np.testing.assert_array_equal(c[1], [0.0, 0.0])
    np.testing.assert_allclose(c[2], [0.1, 0.0], rtol=1e-6)
    np.testing.assert_array_equal(c[3], [0.0, 0.0])
    np.testing.assert_array_equal(np.asarray(ok), [True, False, True, False])
    assert int(n_rej) == 1


# -- (c) graceful degradation end to end -------------------------------------

def _kill_edge(cfg, spec, state, dead_idx):
    state = engine.ensure_carry(cfg, spec, state)
    up = np.ones((cfg.n_edges,), np.float32)
    up[dead_idx] = 0.0
    return state._replace(faults=state.faults._replace(
        edge_up=jnp.asarray(up)))


@pytest.mark.parametrize("candidates_k", [None, 2])
def test_dead_edge_masked_from_frontier_cohort_reforms(candidates_k):
    """With edge 0 killed (frozen churn), no client associates to it and
    the cohort re-forms on the survivor within the very first round —
    on both the dense path and the (N, K) candidate frontier."""
    spec = dataclasses.replace(_faulted(SPEC_SYNC, **FROZEN),
                               telemetry=True, candidates_k=candidates_k)
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    state = _kill_edge(SMALL, spec, state, dead_idx=0)
    final, out = engine.run_scanned(SMALL, spec, state, bundle, ROUNDS)
    ms, tr = engine.split_output(spec, out)
    load = np.asarray(tr.edge_load)                       # (R, M)
    assert np.all(load[:, 0] == 0), "dead edge admitted clients"
    assert np.all(load[:, 1] > 0), "cohort failed to re-form on survivor"
    np.testing.assert_array_equal(np.asarray(tr.dead_edges), ROUNDS * [1])
    assert np.all(np.asarray(ms.n_associated) > 0)
    # the survivor keeps training the model: metrics stay finite
    assert np.all(np.isfinite(np.asarray(ms.loss)))
    np.testing.assert_array_equal(np.asarray(final.faults.edge_up), [0., 1.])


def test_all_nan_poison_leaves_global_bit_unchanged():
    """p_poison=1 + NaN fill: every delta is quarantined, so the global
    model never moves — bit-exactly — and nothing non-finite escapes."""
    spec = _faulted(SPEC_SYNC, **FROZEN, p_poison=1.0, poison_nan=True)
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    final, ms = engine.run_scanned(SMALL, spec, state, bundle, ROUNDS)
    _tree_equal(final.global_params, state.global_params,
                "global model moved despite all-NaN quarantine")
    assert int(final.faults.n_quarantined) > 0
    assert np.all(np.isfinite(np.asarray(ms.loss)))
    assert np.all(np.isfinite(np.asarray(ms.accuracy)))


def test_scaled_poison_clipped_to_quarantine_sphere():
    """Finite but huge deltas (×1e6) pass the guard CLIPPED: the merge
    moves the global model, but at most ``quarantine_clip`` per round."""
    clip = 1.0
    spec = _faulted(SPEC_SYNC, **FROZEN, p_poison=1.0, poison_scale=1e6,
                    quarantine_clip=clip)
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    prev = state.global_params
    moved = 0.0
    for _ in range(2):
        state, _ = jax.block_until_ready(
            engine.run_scanned(SMALL, spec, state, bundle, 1))
        step = _delta_norm(state.global_params, prev)
        assert step <= clip * (1.0 + 1e-4), "delta escaped the clip sphere"
        moved = max(moved, step)
        prev = state.global_params
    assert moved > 0.0, "clipped deltas should still move the model"
    assert int(state.faults.n_quarantined) == 0    # clipped, not rejected


def test_buffered_uplink_loss_retries_then_drops():
    """Near-certain uplink loss: every landing re-enters flight with
    backoff until ``max_attempts``, then is dropped and counted; the
    retry ledger never exceeds the cap."""
    spec = _faulted(SPEC_BUF, **FROZEN, uplink_p_loss=0.95,
                    max_attempts=2, backoff_base_s=0.1)
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    final, ms = engine.run_scanned(SMALL, spec, state, bundle, 32)
    flt = final.faults
    assert int(flt.n_retries) > 0, "no uplink retry ever happened"
    assert int(flt.n_dropped) > 0, "no upload exhausted its attempts"
    assert int(np.max(np.asarray(flt.attempts))) <= 2
    assert np.all(np.isfinite(np.asarray(ms.loss)))


def test_buffered_moderate_loss_still_merges():
    """A lossy-but-survivable uplink (30%): retries land eventually and
    the buffered merge keeps firing (version advances)."""
    spec = _faulted(SPEC_BUF, **FROZEN, uplink_p_loss=0.3, max_attempts=3)
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    final, _ = engine.run_scanned(SMALL, spec, state, bundle, 24)
    assert int(final.buffer.version) > 0
    assert int(final.faults.n_retries) > 0


def test_buffered_min_participation_blocks_merge():
    """min_participation above any reachable fill: triggers keep firing
    (the clock must not freeze) but no merge ever applies."""
    spec = _faulted(SPEC_BUF, **FROZEN,
                    min_participation=SMALL.n_clients + 1)
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    final, _ = engine.run_scanned(SMALL, spec, state, bundle, 16)
    assert int(final.buffer.version) == 0
    assert float(final.buffer.clock_s) > 0.0


# -- (d) checkpoint round-trip + resumable bit-identity ----------------------

def test_checkpoint_roundtrips_full_faulted_carry(tmp_path):
    """The full buffered+faulted scan carry — BufferState, FaultState and
    the TYPED PRNG key — survives save/load bit-exactly."""
    spec = _faulted(SPEC_BUF, edge_p_kill=0.2, uplink_p_loss=0.2)
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    state = engine.ensure_carry(SMALL, spec, state)
    # run a few micro-steps so every leaf holds non-init values
    state, _ = engine.run_scanned(SMALL, spec, state, bundle, 3)
    store.save_checkpoint(str(tmp_path), 3, {"carry": state})
    tree, step, _ = store.load_checkpoint(str(tmp_path), {"carry": state})
    assert step == 3
    _tree_equal(tree["carry"], state, "carry round-trip")
    # the restored key is a TYPED key again, usable for new draws
    restored = tree["carry"].key
    assert jax.dtypes.issubdtype(restored.dtype, jax.dtypes.prng_key)
    _tree_equal(jax.random.split(restored, 2), jax.random.split(state.key, 2),
                "restored key draws diverge")


def test_latest_step_empty_and_garbage_dirs(tmp_path):
    assert store.latest_step(str(tmp_path / "never_created")) is None
    assert store.latest_step(str(tmp_path)) is None          # empty
    (tmp_path / "not_a_checkpoint.npz").write_bytes(b"junk")
    (tmp_path / "step_x.npz").write_bytes(b"junk")
    (tmp_path / "step_7.json").write_text("{}")              # manifest only
    assert store.latest_step(str(tmp_path)) is None
    (tmp_path / "step_4.npz").write_bytes(b"junk")
    (tmp_path / "step_11.npz").write_bytes(b"junk")
    assert store.latest_step(str(tmp_path)) == 11


def test_resumable_interrupted_run_resumes_bit_identical(tmp_path):
    """A mid-run interruption (max_segments=1) + resume reproduces the
    uninterrupted scan bit-for-bit: metrics, trace AND the final carry
    (typed PRNG key included)."""
    spec = dataclasses.replace(
        _faulted(SPEC_BUF, edge_p_kill=0.2, edge_p_respawn=0.5,
                 uplink_p_loss=0.2, uplink_loss_slope=0.2),
        telemetry=True)
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    state = engine.ensure_carry(SMALL, spec, state)
    n_rounds = 6

    # the uninterrupted reference: one scan over all rounds
    ref_final, out = engine.run_scanned(SMALL, spec, state, bundle, n_rounds)
    ref_ms, ref_tr = engine.split_output(spec, out)

    # segment 1, then a simulated host crash, then resume to completion
    first = run_scanned_resumable(SMALL, spec, state, bundle, n_rounds,
                                  directory=str(tmp_path),
                                  segment_rounds=2, max_segments=1)
    assert first.completed_rounds == 2 and not first.done
    assert store.latest_step(str(tmp_path)) == 2
    res = run_scanned_resumable(SMALL, spec, state, bundle, n_rounds,
                                directory=str(tmp_path), segment_rounds=2)
    assert res.done and res.completed_rounds == n_rounds

    _tree_equal(ref_ms, res.metrics, "metrics diverged across resume")
    _tree_equal(ref_tr, res.trace, "trace diverged across resume")
    _tree_equal(ref_final, res.state, "final carry diverged across resume")


def test_resumable_without_interruption_matches_scan(tmp_path):
    """Sanity: segmented-but-uninterrupted == one scan (no faults, no
    telemetry — the plain sync engine through the same driver)."""
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    ref_final, ref_ms = engine.run_scanned(SMALL, SPEC_SYNC, state, bundle,
                                           ROUNDS)
    res = run_scanned_resumable(SMALL, SPEC_SYNC, state, bundle, ROUNDS,
                                directory=str(tmp_path), segment_rounds=3)
    assert res.done and res.trace is None
    _tree_equal(ref_ms, res.metrics, "metrics")
    _tree_equal(ref_final, res.state, "final carry")


# -- (e) the chaos sweep axis ------------------------------------------------

@pytest.mark.slow
def test_sweep_grid_chaos_cells(tmp_path):
    from repro.sweeps import grid as sweeps_grid
    g = sweeps_grid.SweepGrid(
        name="chaos_t", scenarios=("static",), policies=("gcea",),
        seeds=(0,), n_rounds=2, telemetry=True,
        engine_modes=("buffered",),
        faults=FaultSpec(edge_p_kill=0.2, edge_p_respawn=0.5,
                         uplink_p_loss=0.1, uplink_loss_slope=0.2))
    summary = sweeps_grid.run_sweep(SMALL, g, out_dir=str(tmp_path))
    assert summary["failed_cells"] == {}
    assert len(summary["final"]) == 1
    (cell,) = summary["final"].values()
    assert np.isfinite(cell["loss"])
    # the chaos cell persisted its RoundTrace with the fault leaves
    tdir = tmp_path / "sweep_chaos_t"
    traces = list(tdir.glob("*.trace.json"))
    assert len(traces) == 1
    tr = json.loads(traces[0].read_text())["trace"]
    for leaf in ("dead_edges", "uplink_retries", "quarantined"):
        assert leaf in tr and len(tr[leaf]) == 2


def test_sweep_isolates_a_crashed_group(tmp_path, monkeypatch):
    """A group that raises lands in summary['failed_cells'] (one entry
    per member cell) without aborting the sweep."""
    from repro.sweeps import grid as sweeps_grid

    def boom(*a, **k):
        raise RuntimeError("chaos cell diverged")

    monkeypatch.setattr(engine, "run_fleet", boom)
    g = sweeps_grid.SweepGrid(name="crash_t", scenarios=("static",),
                              policies=("gcea",), seeds=(0, 1), n_rounds=2)
    summary = sweeps_grid.run_sweep(SMALL, g, out_dir=str(tmp_path),
                                    write_json=False)
    assert summary["final"] == {}
    assert len(summary["failed_cells"]) == 2
    assert all("chaos cell diverged" in v
               for v in summary["failed_cells"].values())
    assert any("error" in t for t in summary["groups"])
