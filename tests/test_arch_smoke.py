"""Per-architecture smoke tests: REDUCED variant of each assigned family,
one forward + one train step on CPU, asserting shapes + finiteness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config
from repro.launch.steps import make_serve_step, make_train_step
from repro.models import build_model


def _batch(cfg, key, b=2, s=16):
    ks = jax.random.split(key, 3)
    batch = {
        "tokens": jax.random.randint(ks[0], (b, s), 0, cfg.vocab_size,
                                     jnp.int32),
        "labels": jax.random.randint(ks[1], (b, s), 0, cfg.vocab_size,
                                     jnp.int32),
    }
    if cfg.prefix_tokens or cfg.stub_frames:
        n = cfg.prefix_tokens or cfg.stub_frames
        batch["embeddings"] = jax.random.normal(ks[2], (b, n, cfg.d_model),
                                                cfg.compute_dtype)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED)
def test_forward_shapes_and_finite(arch, key):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(key)
    batch = _batch(cfg, key)
    logits, aux = model.apply(params, batch["tokens"],
                              extra_embeddings=batch.get("embeddings"))
    assert logits.shape == (2, 16, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_reduces_loss(arch, key):
    cfg = get_config(arch).reduced()
    step_fn, model, opt = make_train_step(cfg, lr=1e-2)
    step_fn = jax.jit(step_fn)
    params = model.init(key)
    opt_state = opt.init(params)
    step = jnp.zeros((), jnp.int32)
    batch = _batch(cfg, key)
    losses = []
    for _ in range(4):
        params, opt_state, step, m = step_fn(params, opt_state, step, batch)
        losses.append(float(m["loss"]))
        assert np.isfinite(losses[-1])
    assert losses[-1] < losses[0]      # same batch -> loss must drop


@pytest.mark.parametrize("arch", ASSIGNED)
def test_serve_step_shapes(arch, key):
    cfg = get_config(arch).reduced()
    serve_step, model = make_serve_step(cfg)
    serve_step = jax.jit(serve_step)
    params = model.init(key)
    b, cache_len = 2, 32
    if cfg.encoder_layers:
        cache = model.init_cache(b, cache_len, cfg.stub_frames)
    else:
        cache = model.init_cache(b, cache_len)
    tok = jnp.zeros((b, 1), jnp.int32)
    for i in range(3):
        tok, cache = serve_step(params, tok, cache, jnp.asarray(i, jnp.int32))
        assert tok.shape == (b, 1) and tok.dtype == jnp.int32
        assert int(tok.max()) < cfg.vocab_size


@pytest.mark.parametrize("arch", ["grok-1-314b", "llama4-maverick-400b-a17b"])
def test_moe_aux_loss_positive(arch, key):
    cfg = get_config(arch).reduced()
    model = build_model(cfg)
    params = model.init(key)
    batch = _batch(cfg, key, b=2, s=16)
    _, aux = model.apply(params, batch["tokens"])
    assert float(aux) > 0.0            # load-balance loss active


def test_grad_accum_equivalence(key):
    """grad_accum=2 must match grad_accum=1 on the same batch (linearity)."""
    cfg = get_config("stablelm-1.6b").reduced()
    batch = _batch(cfg, key, b=4, s=16)

    def run(accum):
        c = cfg.replace(grad_accum=accum)
        step_fn, model, opt = make_train_step(c, lr=1e-2)
        params = model.init(key)
        opt_state = opt.init(params)
        p, _, _, m = jax.jit(step_fn)(params, opt_state,
                                      jnp.zeros((), jnp.int32), batch)
        return p, float(m["loss"])

    p1, l1 = run(1)
    p2, l2 = run(2)
    assert l1 == pytest.approx(l2, rel=1e-4)
    # Adam at step 0 is ~sign(g)·lr, so reduction-order noise on near-zero
    # grads flips a few updates by ±2·lr — bound the mean drift instead.
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        a = np.asarray(a, np.float32)
        b = np.asarray(b, np.float32)
        np.testing.assert_allclose(a, b, atol=2.5e-2)
        assert np.mean(np.abs(a - b)) < 2e-3


def test_unroll_matches_scan(key):
    """scan_layers=False (roofline mode) is numerically identical."""
    cfg = get_config("qwen3-8b").reduced()
    model_s = build_model(cfg)
    model_u = build_model(cfg.replace(scan_layers=False))
    params = model_s.init(key)
    toks = jax.random.randint(key, (2, 16), 0, cfg.vocab_size, jnp.int32)
    ls, _ = model_s.apply(params, toks)
    lu, _ = model_u.apply(params, toks)
    np.testing.assert_allclose(np.asarray(ls), np.asarray(lu), atol=1e-5)


def test_param_count_matches_init(key):
    """Analytic count_params == actual init pytree size, per arch."""
    for arch in ASSIGNED:
        cfg = get_config(arch).reduced()
        model = build_model(cfg)
        shapes = jax.eval_shape(model.init, key)
        actual = sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))
        want = cfg.param_count()
        assert actual == want, (arch, actual, want)
