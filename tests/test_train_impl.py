"""Training-stage implementation tests (DESIGN.md §13).

(a) PRNG lattice: the batched ``_batch_index_lattice`` draws exactly
    the index sequences of the nested split/fold_in reference loop —
    the stream-layout contract the PR-10 goldens were re-recorded on,
(b) impl bit-parity: ``train_impl="batched"`` (what "auto" resolves to)
    and ``train_impl="vmap"`` produce bit-identical trajectories and
    final params under the sync AND buffered engines, faults on or off,
(c) Pallas: ``local_sgd_step`` (interpret mode on CPU) matches the
    batched path to float tolerance at the kernel and the round level,
(d) warm-start: warm assignment == cold assignment bit-for-bit (the
    blocking-pair fallback guards exactness), the deferred-acceptance
    sweep count under ``random_waypoint`` mobility drops (median warm
    ≤ median cold, asserted from ``RoundTrace.assoc_sweeps``), and the
    cold carry keeps the warm leaf structurally absent.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.hfl_mnist import CONFIG
from repro.core import engine
from repro.faults import FaultSpec
from repro.kernels import hfl_ops
from repro.models.mlp import MLPClassifier

SMALL = dataclasses.replace(CONFIG, n_clients=16, n_edges=2,
                            clients_per_edge=3, min_samples=60,
                            max_samples=120, hidden=32, input_dim=64)
ROUNDS = 4


def _spec(**kw):
    return engine.EngineSpec(policy="gcea", scheduler="fastest", **kw)


def _tree_equal(a, b, msg=""):
    fa, ta = jax.tree_util.tree_flatten(a)
    fb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb, msg
    for la, lb in zip(fa, fb):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb),
                                      err_msg=msg)


# -- (a) batched PRNG lattice vs the nested reference loop -------------------

def test_lattice_matches_nested_splits():
    """One split + fold_in lattice == the per-iteration nested draws."""
    key = jax.random.key(7)
    tau2, tau1, k_lanes, batch = 3, 2, 5, 8
    gid = jnp.asarray([0, 3, 3, 9, 15], jnp.int32)
    counts = jnp.asarray([60, 0, 120, 77, 61], jnp.int32)
    got = np.asarray(engine._batch_index_lattice(
        key, tau2, tau1, gid, counts, batch))
    assert got.shape == (tau2, tau1, k_lanes, batch)
    k_t = jax.random.split(key, tau2)
    for t in range(tau2):
        for i in range(tau1):
            for j in range(k_lanes):
                kc = jax.random.fold_in(
                    jax.random.fold_in(k_t[t], i), int(gid[j]))
                want = jax.random.randint(
                    kc, (batch,), 0, max(int(counts[j]), 1))
                np.testing.assert_array_equal(got[t, i, j],
                                              np.asarray(want))


def test_lattice_indices_in_range():
    key = jax.random.key(0)
    counts = jnp.asarray([1, 60, 120], jnp.int32)
    idx = np.asarray(engine._batch_index_lattice(
        key, 4, 3, jnp.arange(3, dtype=jnp.int32), counts, 16))
    assert (idx >= 0).all()
    assert (idx < np.asarray(counts)[None, None, :, None]).all()


def test_unknown_train_impl_raises():
    with pytest.raises(ValueError, match="train_impl"):
        engine._train_impl_for(_spec(train_impl="fused"))
    assert engine._train_impl_for(_spec()) == "batched"   # auto default


# -- (b) batched vs vmap bit-parity across engines ---------------------------

@pytest.mark.parametrize("mode,faulted", [("sync", False), ("sync", True),
                                          ("buffered", False),
                                          ("buffered", True)])
def test_batched_bit_equal_vmap(mode, faulted):
    """scan-of-batched-GEMMs and vmap-of-scans are the same XLA math —
    bit-for-bit, under both engines, with and without the fault layer."""
    kw = dict(engine_mode=mode)
    if mode == "buffered":
        kw.update(n_tiers=2, retier_every=3, timeout_s=5.0)
    if faulted:
        kw["faults"] = FaultSpec(edge_p_kill=0.0, edge_p_respawn=0.0,
                                 uplink_p_loss=0.2)
    outs = {}
    for impl in ("batched", "vmap"):
        state, bundle, _ = engine.init_simulation(SMALL, seed=0)
        st, ms = engine.run_scanned(SMALL, _spec(train_impl=impl, **kw),
                                    state, bundle, ROUNDS)
        outs[impl] = (st.global_params, st.client_params, ms)
    _tree_equal(outs["batched"][0], outs["vmap"][0], "global_params")
    _tree_equal(outs["batched"][1], outs["vmap"][1], "client_params")
    _tree_equal(outs["batched"][2], outs["vmap"][2], "metrics")


def test_vmap_matches_goldens_via_auto():
    """"auto" resolves to "batched"; a vmap run of the same spec must be
    bit-equal — i.e. the vmap path also reproduces the committed goldens
    (test_scenarios pins auto against them directly)."""
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    _, ms_auto = engine.run_scanned(SMALL, _spec(), state, bundle, ROUNDS)
    _, ms_vmap = engine.run_scanned(SMALL, _spec(train_impl="vmap"),
                                    state, bundle, ROUNDS)
    _tree_equal(ms_auto, ms_vmap, "auto-vs-vmap metrics")


# -- (c) Pallas local_sgd_step parity ----------------------------------------

def test_local_sgd_step_kernel_parity():
    """The fused kernel == τ₁ hand-stepped SGD on the same minibatches
    (interpret mode; float tolerance — softmax vs logsumexp op order)."""
    rng = np.random.default_rng(3)
    k_lanes, tau1, batch, dim, hid, ncls = 4, 3, 8, 16, 12, 5
    model = MLPClassifier(dim, hid, ncls)
    p0 = model.init(jax.random.key(1))
    params = jax.tree.map(
        lambda l: jnp.stack([l + 0.01 * i for i in range(k_lanes)]), p0)
    bx = jnp.asarray(rng.normal(size=(tau1, k_lanes, batch, dim)),
                     jnp.float32)
    by = jnp.asarray(rng.integers(0, ncls, size=(tau1, k_lanes, batch)),
                     jnp.int32)
    got = hfl_ops.local_sgd_step(params, bx, by, lr=0.1, interpret=True)

    def one(params, xs, ys):
        def step(p, xy):
            g = jax.grad(model.loss)(p, xy)
            return jax.tree.map(lambda a, b: a - 0.1 * b, p, g), None
        p, _ = jax.lax.scan(step, params, (xs, ys))
        return p
    want = jax.vmap(one, in_axes=(0, 1, 1))(params, bx, by)
    for k in want:
        np.testing.assert_allclose(np.asarray(got[k]),
                                   np.asarray(want[k]),
                                   rtol=2e-5, atol=2e-6, err_msg=k)


def test_pallas_round_close_to_batched():
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    _, ms_b = engine.run_scanned(SMALL, _spec(train_impl="batched"),
                                 state, bundle, 2)
    _, ms_p = engine.run_scanned(SMALL, _spec(train_impl="pallas"),
                                 state, bundle, 2)
    np.testing.assert_allclose(np.asarray(ms_p.loss),
                               np.asarray(ms_b.loss), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(ms_p.accuracy),
                               np.asarray(ms_b.accuracy), atol=1e-3)


# -- (d) warm-started association --------------------------------------------

def test_warm_leaf_structural_absence():
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    cold = engine.ensure_carry(SMALL, _spec(), state)
    assert cold.warm is None
    warm = engine.ensure_carry(SMALL, _spec(warm_start=True), state)
    assert warm.warm is not None
    np.testing.assert_array_equal(np.asarray(warm.warm),
                                  np.full(SMALL.n_clients, -1, np.int32))
    # a stale warm leaf is STRIPPED when the flag is off — the cold
    # carry (and with it the golden program) is structurally unchanged
    stripped = engine.ensure_carry(SMALL, _spec(), warm)
    assert stripped.warm is None


@pytest.mark.parametrize("candidates_k", [None, 2])
def test_warm_equals_cold(candidates_k):
    """Seeded deferred acceptance lands on the SAME matching: the
    blocking-pair check falls back to the cold resolver whenever the
    seeded fixpoint could diverge, so trajectories are bit-equal."""
    outs = {}
    for warm in (False, True):
        spec = _spec(scenario="dynamic", warm_start=warm,
                     candidates_k=candidates_k)
        state, bundle, _ = engine.init_simulation(
            SMALL, seed=0, scenario="random_waypoint")
        st, ms = engine.run_scanned(SMALL, spec, state, bundle, 6)
        outs[warm] = (st.global_params, ms)
    _tree_equal(outs[False][0], outs[True][0], "global_params")
    _tree_equal(outs[False][1], outs[True][1], "metrics")


def test_warm_start_reduces_sweeps_under_mobility():
    """The point of the seed: under random_waypoint mobility last
    round's matching is nearly stable, so the seeded resolver converges
    in fewer deferred-acceptance sweeps (RoundTrace.assoc_sweeps)."""
    sweeps = {}
    for warm in (False, True):
        spec = _spec(scenario="dynamic", warm_start=warm, telemetry=True)
        state, bundle, _ = engine.init_simulation(
            SMALL, seed=0, scenario="random_waypoint")
        _, (_, tr) = engine.run_scanned(SMALL, spec, state, bundle, 8)
        sweeps[warm] = np.asarray(tr.assoc_sweeps)
    # round 0 has no seed yet — compare the steady-state tail
    assert np.median(sweeps[True][1:]) <= np.median(sweeps[False][1:])
    assert sweeps[True][1:].mean() < sweeps[False][1:].mean()


def test_warm_start_requires_parallel_resolver():
    from repro.core import association
    with pytest.raises(ValueError, match="parallel"):
        association.associate_jax(
            "gcea", scores=None, gains=jnp.ones((16, 2)),
            dist=jnp.ones((16, 2)) * 10.0, quota=3,
            coverage_radius_m=100.0, key=jax.random.key(0),
            resolver="serial", seed=jnp.full((16,), -1, jnp.int32))
