"""Pure-functional round engine parity + purity tests (DESIGN.md §2).

(a) scanned vs eager rounds produce identical metrics for fixed seeds,
(b) JAX FCEA conflict resolution matches the numpy ``_resolve`` oracle,
(c) ``run_fleet(seeds)`` equals sequential per-seed scanned runs,
(d) ``round_step`` lowers with no host callbacks on the gcea/rcea +
    fastest-scheduler path,
(e) ``fuzzy.score_matrix`` matches per-edge scoring.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.hfl_mnist import CONFIG
from repro.core import association, engine, fuzzy
from repro.core.hfl import HFLSimulation

SMALL = dataclasses.replace(CONFIG, n_clients=16, n_edges=2,
                            clients_per_edge=3, min_samples=60,
                            max_samples=120, hidden=32, input_dim=64)


# -- (a) eager == scanned ----------------------------------------------------

@pytest.mark.parametrize("policy,scheduler", [("fcea", "pdd"),
                                              ("gcea", "fastest")])
def test_eager_matches_scanned(policy, scheduler):
    rounds = 3
    eager = HFLSimulation(SMALL, seed=0, iid=True, policy=policy,
                          scheduler=scheduler)
    scanned = HFLSimulation(SMALL, seed=0, iid=True, policy=policy,
                            scheduler=scheduler)
    me = eager.run(rounds)
    ms = scanned.run_scanned(rounds)
    for a, b in zip(me, ms):
        assert a.round == b.round
        assert a.n_associated == b.n_associated
        np.testing.assert_array_equal(a.z, b.z)
        np.testing.assert_allclose(a.accuracy, b.accuracy, rtol=1e-5)
        np.testing.assert_allclose(a.loss, b.loss, rtol=1e-5)
        np.testing.assert_allclose(a.cost, b.cost, rtol=1e-5)
        np.testing.assert_allclose(a.avg_staleness, b.avg_staleness,
                                   rtol=1e-6)
    # the final states agree too, so the drivers are interchangeable
    for le, ls in zip(jax.tree.leaves(eager.state.global_params),
                      jax.tree.leaves(scanned.state.global_params)):
        np.testing.assert_allclose(np.asarray(le), np.asarray(ls),
                                   rtol=1e-5, atol=1e-6)


# -- (b) JAX resolver == numpy oracle ---------------------------------------

def test_resolve_jax_matches_numpy_oracle():
    rng = np.random.default_rng(0)
    for _ in range(40):
        n = int(rng.integers(4, 24))
        m = int(rng.integers(1, 5))
        quota = int(rng.integers(1, 6))
        dist = rng.uniform(10.0, 400.0, (n, m))
        pref = rng.uniform(0.0, 100.0, (n, m))
        cov = dist <= 350.0
        order = np.argsort(-np.where(cov, pref, -np.inf), axis=0,
                           kind="stable").T
        want = association._resolve(order, dist, quota, cov)
        got = np.asarray(association.resolve_jax(
            jnp.asarray(order), jnp.asarray(dist), quota, jnp.asarray(cov)))
        np.testing.assert_array_equal(got, want)


def test_fcea_jax_matches_numpy_end_to_end():
    rng = np.random.default_rng(1)
    for trial in range(20):
        n = int(rng.integers(4, 20))
        m = int(rng.integers(1, 4))
        quota = int(rng.integers(1, 5))
        dist = rng.uniform(10.0, 400.0, (n, m))
        scores = rng.uniform(0.0, 100.0, (n, m))
        want = association.fcea(scores, dist, quota, 350.0)
        got = np.asarray(association.associate_jax(
            "fcea", scores=jnp.asarray(scores), gains=jnp.asarray(scores),
            dist=jnp.asarray(dist), quota=quota, coverage_radius_m=350.0,
            key=jax.random.key(trial)))
        np.testing.assert_array_equal(got, want, err_msg=f"trial {trial}")


def test_association_invariants_jax():
    rng = np.random.default_rng(2)
    key = jax.random.key(0)
    for policy in ("fcea", "gcea", "rcea"):
        n, m, quota = 18, 3, 2
        dist = rng.uniform(10.0, 400.0, (n, m))
        scores = rng.uniform(0.0, 100.0, (n, m))
        assoc = np.asarray(association.associate_jax(
            policy, scores=jnp.asarray(scores),
            gains=jnp.asarray(scores * 1e-11), dist=jnp.asarray(dist),
            quota=quota, coverage_radius_m=350.0, key=key))
        assert (assoc.sum(axis=1) <= 1).all()
        assert (assoc.sum(axis=0) <= quota).all()
        for c, e in np.argwhere(assoc == 1):
            assert dist[c, e] <= 350.0


# -- (c) fleet == sequential -------------------------------------------------

def test_fleet_matches_sequential():
    seeds = (0, 1, 2)
    rounds = 2
    spec = engine.EngineSpec(policy="fcea", scheduler="pdd")
    pairs = [engine.init_simulation(SMALL, seed=s)[:2] for s in seeds]
    states, bundles = engine.stack_fleet(pairs)
    _, fleet = engine.run_fleet(SMALL, spec, states, bundles, rounds)
    for i, (st, bu) in enumerate(pairs):
        _, seq = engine.run_scanned(SMALL, spec, st, bu, rounds)
        np.testing.assert_allclose(np.asarray(fleet.loss[i]),
                                   np.asarray(seq.loss), rtol=1e-5)
        np.testing.assert_allclose(np.asarray(fleet.cost[i]),
                                   np.asarray(seq.cost), rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(fleet.z[i]),
                                      np.asarray(seq.z))


# -- (d) purity: no host callbacks in the lowered program --------------------

@pytest.mark.parametrize("policy", ["gcea", "rcea"])
def test_round_step_lowers_without_callbacks(policy):
    spec = engine.EngineSpec(policy=policy, scheduler="fastest")
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    txt = jax.jit(engine.round_step, static_argnums=(0, 1)).lower(
        SMALL, spec, state, bundle).as_text()
    assert "callback" not in txt
    assert "CustomCall" not in txt


# -- (e) fuzzy score matrix == per-edge scoring ------------------------------

def test_score_matrix_matches_per_edge_loop():
    rng = np.random.default_rng(3)
    n, m = 10, 3
    gains = jnp.asarray(rng.uniform(1e-12, 1e-8, (n, m)))
    counts = jnp.asarray(rng.integers(60, 120, n), jnp.float32)
    stale = jnp.asarray(rng.integers(1, 9, n), jnp.int32)
    got = np.asarray(fuzzy.score_matrix(gains, counts, stale,
                                        data_max=120.0))
    db = 10.0 * np.log10(np.maximum(np.asarray(gains), 1e-30))
    lo, hi = db.min(), db.max()
    cq = np.asarray(fuzzy.normalize(jnp.asarray(db - lo),
                                    float(max(hi - lo, 1e-9))))
    dq = np.asarray(fuzzy.normalize(counts, 120.0))
    ms = np.asarray(fuzzy.normalize(stale.astype(jnp.float32),
                                    float(np.asarray(stale).max())))
    for j in range(m):
        want = np.asarray(fuzzy.fuzzy_scores(
            jnp.asarray(cq[:, j]), jnp.asarray(dq), jnp.asarray(ms)))
        np.testing.assert_allclose(got[:, j], want, rtol=1e-5, atol=1e-5)


# -- apply_schedule == full recompute ---------------------------------------

def test_apply_schedule_matches_recompute():
    from repro.core import cost
    rng = np.random.default_rng(4)
    n, m = 8, 2
    p = jnp.asarray(rng.uniform(0.01, 0.1, n))
    f = jnp.asarray(rng.uniform(1e9, 1e10, n))
    gains = jnp.asarray(rng.uniform(1e-12, 1e-9, (n, m)))
    assoc = np.zeros((n, m), np.float32)
    assoc[np.arange(n), rng.integers(0, m, n)] = 1.0
    assoc = jnp.asarray(assoc)
    samples = jnp.asarray(rng.integers(60, 120, n), jnp.float32)
    z = jnp.asarray([1.0, 0.0])
    rc_all = cost.round_cost(SMALL, power_w=p, f_hz=f, gains=gains,
                             assoc=assoc, z=jnp.ones((m,)),
                             n_samples=samples)
    rc_masked = cost.apply_schedule(SMALL, rc_all, z)
    rc_full = cost.round_cost(SMALL, power_w=p, f_hz=f, gains=gains,
                              assoc=assoc, z=z, n_samples=samples)
    np.testing.assert_allclose(float(rc_masked.total_time_s),
                               float(rc_full.total_time_s), rtol=1e-6)
    np.testing.assert_allclose(float(rc_masked.total_energy_j),
                               float(rc_full.total_energy_j), rtol=1e-6)
    np.testing.assert_allclose(float(rc_masked.cost), float(rc_full.cost),
                               rtol=1e-6)
