"""DDPG + environment tests (paper §IV-C, Algorithm 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.hfl_mnist import CONFIG as HFL
from repro.core import ddpg, env


def _env(n=6, m=2, seed=0):
    rng = np.random.default_rng(seed)
    assoc = np.zeros((n, m))
    for i in range(n):
        assoc[i, i % m] = 1.0
    dist = rng.uniform(50.0, 300.0, (n, m))
    counts = rng.integers(200, 1200, n).astype(np.float32)
    return env.NomaHflEnv(HFL, jnp.asarray(assoc, jnp.float32),
                          jnp.ones((m,)), jnp.asarray(dist),
                          jnp.asarray(counts))


def test_env_reset_step(key):
    e = _env()
    state, obs = e.reset(key)
    assert obs.shape == (e.state_dim,)
    act = jnp.full((e.action_dim,), 0.5)
    state2, obs2, reward, rc = e.step(state, act)
    assert float(reward) == pytest.approx(-float(rc.cost))
    assert np.isfinite(np.asarray(obs2)).all()
    # channel evolved
    assert not np.allclose(np.asarray(state.gains), np.asarray(state2.gains))


def test_decode_action_bounds():
    e = _env()
    p, f = e.decode_action(jnp.zeros((e.action_dim,)))
    assert float(p.min()) == pytest.approx(HFL.p_min_w)
    assert float(f.min()) == pytest.approx(HFL.f_min_hz)
    p, f = e.decode_action(jnp.ones((e.action_dim,)))
    assert float(p.max()) == pytest.approx(HFL.p_max_w)
    assert float(f.max()) == pytest.approx(HFL.f_max_hz)


def test_networks_shapes(key):
    cfg = ddpg.DDPGConfig(state_dim=12, action_dim=12, hidden=32,
                          buffer_size=128, batch_size=16)
    st = ddpg.init_ddpg(key, cfg)
    s = jnp.zeros((12,))
    a = ddpg.actor_apply(st.actor, s)
    assert a.shape == (12,)
    assert float(a.min()) >= 0.0 and float(a.max()) <= 1.0
    q = ddpg.critic_apply(st.critic, s, a)
    assert q.shape == ()


def test_replay_ring(key):
    cfg = ddpg.DDPGConfig(state_dim=2, action_dim=2, buffer_size=4,
                          batch_size=2)
    st = ddpg.init_ddpg(key, cfg)
    for i in range(6):
        st = ddpg.store(st, cfg, jnp.full((2,), float(i)), jnp.zeros((2,)),
                        jnp.asarray(float(i)), jnp.zeros((2,)))
    assert bool(st.buffer_full)
    assert int(st.buffer_idx) == 2
    # slots hold the most recent 4 rewards {2,3,4,5}
    assert sorted(np.asarray(st.buffer["r"]).tolist()) == [2.0, 3.0, 4.0, 5.0]


def test_train_step_updates_and_targets_move(key):
    cfg = ddpg.DDPGConfig(state_dim=4, action_dim=2, hidden=32,
                          buffer_size=64, batch_size=16, tau=0.5)
    st = ddpg.init_ddpg(key, cfg)
    rng = np.random.default_rng(0)
    for i in range(32):
        s = jnp.asarray(rng.normal(size=4), jnp.float32)
        a = jnp.asarray(rng.uniform(size=2), jnp.float32)
        r = jnp.asarray(-float(np.sum(np.asarray(a) ** 2)))
        st = ddpg.store(st, cfg, s, a, r, s)
    t0 = jax.tree.leaves(st.target_actor)[0].copy()
    a0 = jax.tree.leaves(st.actor)[0].copy()
    st2, metrics = ddpg.train_step(key, st, cfg)
    assert np.isfinite(float(metrics["critic_loss"]))
    assert not np.allclose(a0, jax.tree.leaves(st2.actor)[0])
    assert not np.allclose(t0, jax.tree.leaves(st2.target_actor)[0])
    # soft update: target moved toward online, not equal to it
    assert not np.allclose(jax.tree.leaves(st2.target_actor)[0],
                           jax.tree.leaves(st2.actor)[0])


def test_ddpg_learns_simple_env(key):
    """Reward = -(a - 0.7)²: the actor should move its mean action to 0.7."""
    cfg = ddpg.DDPGConfig(state_dim=2, action_dim=1, hidden=32,
                          actor_lr=3e-3, critic_lr=3e-3,
                          buffer_size=512, batch_size=32, noise_sigma=0.3)
    st = ddpg.init_ddpg(key, cfg)
    rng = np.random.default_rng(0)
    k = key
    obs = jnp.zeros((2,))
    for i in range(400):
        k, ka, kt = jax.random.split(k, 3)
        a = ddpg.select_action(ka, st, obs)
        r = -float((np.asarray(a)[0] - 0.7) ** 2)
        st = ddpg.store(st, cfg, obs, a, jnp.asarray(r), obs)
        if i > 64:
            st, _ = ddpg.train_step(kt, st, cfg)
    final = float(ddpg.actor_apply(st.actor, obs)[0])
    assert abs(final - 0.7) < 0.2


def test_env_functional_matches_class(key):
    """The class is a shell over env_reset/env_step: same params, same
    trajectory."""
    e = _env()
    s_cls, o_cls = e.reset(key)
    s_fn, o_fn = env.env_reset(HFL, e.params, key)
    np.testing.assert_array_equal(np.asarray(o_cls), np.asarray(o_fn))
    act = jnp.full((e.action_dim,), 0.3)
    s_cls2, o_cls2, r_cls, _ = e.step(s_cls, act)
    s_fn2, o_fn2, r_fn, _ = env.env_step(HFL, e.params, s_fn, act)
    np.testing.assert_array_equal(np.asarray(o_cls2), np.asarray(o_fn2))
    np.testing.assert_array_equal(np.asarray(r_cls), np.asarray(r_fn))
    np.testing.assert_array_equal(np.asarray(s_cls2.gains),
                                  np.asarray(s_fn2.gains))


def test_train_step_before_store_is_masked(key):
    """Regression (replay warmup): a train_step on an EMPTY buffer must be
    a no-op — the all-zero init transitions are not experience."""
    cfg = ddpg.DDPGConfig(state_dim=4, action_dim=2, hidden=16,
                          buffer_size=32, batch_size=8)
    st = ddpg.init_ddpg(key, cfg)
    st2, losses = ddpg.train_step(key, st, cfg)
    for a, b in zip(jax.tree.leaves(st), jax.tree.leaves(st2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert float(losses["critic_loss"]) == 0.0
    assert float(losses["actor_loss"]) == 0.0
    # one stored transition is enough to unmask the update
    st3 = ddpg.store(st, cfg, jnp.ones((4,)), jnp.full((2,), 0.5),
                     jnp.asarray(-1.0), jnp.ones((4,)))
    st4, _ = ddpg.train_step(key, st3, cfg)
    assert not np.allclose(jax.tree.leaves(st3.actor)[0],
                           jax.tree.leaves(st4.actor)[0])
    # ... and a FULL buffer whose write index wrapped back to 0 still
    # trains — the mask keys on (idx == 0 AND not full), not idx alone
    for i in range(cfg.buffer_size):
        st3 = ddpg.store(st3, cfg, jnp.ones((4,)), jnp.full((2,), 0.5),
                         jnp.asarray(-1.0), jnp.ones((4,)))
    st3 = st3._replace(buffer_idx=jnp.zeros((), jnp.int32))
    assert bool(st3.buffer_full)
    st5, _ = ddpg.train_step(key, st3, cfg)
    assert not np.allclose(jax.tree.leaves(st3.critic)[0],
                           jax.tree.leaves(st5.critic)[0])


def _sim_setup(scenario=None, kind="static"):
    import dataclasses

    from repro.core import engine
    small = dataclasses.replace(HFL, n_clients=8, n_edges=2,
                                clients_per_edge=3, min_samples=60,
                                max_samples=120, hidden=16, input_dim=32)
    spec = engine.EngineSpec(policy="gcea", scheduler="fastest",
                             scenario=kind)
    state, bundle, _ = engine.init_simulation(small, seed=0,
                                              scenario=scenario)
    return small, spec, state, bundle


@pytest.mark.parametrize("scenario,kind", [(None, "static"),
                                           ("full_dynamic", "dynamic")])
def test_train_allocator_matches_eager_oracle(scenario, kind):
    """Tentpole parity: the fully scanned trainer and the eager oracle walk
    the SAME key stream through the SAME pure pieces — identical episode
    rewards, losses and final actor weights."""
    small, spec, state, bundle = _sim_setup(scenario, kind)
    dcfg = ddpg.allocator_config(small, spec, hidden=16, buffer_size=64,
                                 batch_size=8)
    key = jax.random.key(3)
    kw = dict(episodes=2, steps_per_episode=8, warmup=4)
    agent_s, hist_s = ddpg.train_allocator(small, spec, state, bundle,
                                           dcfg, key, **kw)
    agent_e, hist_e = ddpg.train_allocator_eager(small, spec, state, bundle,
                                                 dcfg, key, **kw)
    for k in ("episode_reward", "critic_loss", "actor_loss"):
        assert hist_s[k].shape == (2,)
        np.testing.assert_allclose(np.asarray(hist_s[k]),
                                   np.asarray(hist_e[k]),
                                   rtol=1e-5, atol=1e-6, err_msg=k)
    for a, b in zip(jax.tree.leaves(agent_s.actor),
                    jax.tree.leaves(agent_e.actor)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)
    assert int(agent_s.step) == int(agent_e.step) > 0


def test_train_allocator_dynamic_observation_and_actor_io():
    """Under full_dynamic the trainer's MDP is the (3N,) scenario-sliced
    observation and the trained actor maps it to a (2N,) action in [0,1] —
    the exact I/O the engine's ddpg allocator path replays."""
    from repro.core import engine
    small, spec, state, bundle = _sim_setup("full_dynamic", "dynamic")
    n = small.n_clients
    dcfg = ddpg.allocator_config(small, spec, hidden=16)
    assert dcfg.state_dim == 3 * n and dcfg.action_dim == 2 * n
    agent, hist = ddpg.train_allocator(small, spec, state, bundle, dcfg,
                                       jax.random.key(0), episodes=1,
                                       steps_per_episode=4, warmup=2)
    assert np.isfinite(np.asarray(hist["episode_reward"])).all()
    obs = env.observe(jnp.zeros((n, small.n_edges)), state.gains,
                      bundle.counts, avail=state.scenario.avail)
    act = ddpg.actor_apply(agent.actor, obs)
    assert act.shape == (2 * n,)
    assert float(act.min()) >= 0.0 and float(act.max()) <= 1.0
    # and the engine consumes the trained actor end-to-end
    import dataclasses
    ddpg_spec = dataclasses.replace(spec, allocator="ddpg")
    _, m = engine.round_step_jit(small, ddpg_spec, state, bundle,
                                 agent.actor)
    assert np.isfinite(float(m.cost))


def test_engine_fpa_fca_match_env_definitions(key):
    """Regression (baseline drift): the engine's fpa/fca columns must mean
    what env.fpa_best_action / fca_best_action define — the fixed axis
    pinned at its MAX, the free axis grid-optimised on the billed cost."""
    import dataclasses

    from repro.core import engine
    small = dataclasses.replace(HFL, n_clients=8, n_edges=2)
    rng = np.random.default_rng(4)
    n, m = 8, 2
    assoc = np.zeros((n, m), np.float32)
    assoc[np.arange(n), rng.integers(0, m, n)] = 1.0
    assoc = jnp.asarray(assoc)
    dist = jnp.asarray(rng.uniform(50.0, 300.0, (n, m)))
    counts = jnp.asarray(rng.integers(60, 120, n), jnp.float32)
    gains = jax.random.gamma(key, 1.0, (n, m)) * 1e-10
    e = env.NomaHflEnv(small, assoc, jnp.ones((m,)), dist, counts)
    for allocator, best_fn in (("fpa", env.fpa_best_action),
                               ("fca", env.fca_best_action)):
        spec = engine.EngineSpec(policy="gcea", allocator=allocator,
                                 scheduler="fastest")
        p_eng, f_eng = engine._allocate(small, spec, key, assoc, gains,
                                        counts, None, None, dist)
        p_env, f_env = e.decode_action(best_fn(e, gains))
        np.testing.assert_allclose(np.asarray(p_eng), np.asarray(p_env),
                                   rtol=1e-6, err_msg=allocator)
        np.testing.assert_allclose(np.asarray(f_eng), np.asarray(f_env),
                                   rtol=1e-6, err_msg=allocator)
    # and the definitions themselves: fpa pins power at p_max, fca pins
    # frequency at f_max (§V-D)
    spec = engine.EngineSpec(allocator="fpa")
    p_eng, _ = engine._allocate(small, spec, key, assoc, gains, counts,
                                None, None, dist)
    np.testing.assert_allclose(np.asarray(p_eng), small.p_max_w, rtol=1e-6)
    spec = engine.EngineSpec(allocator="fca")
    _, f_eng = engine._allocate(small, spec, key, assoc, gains, counts,
                                None, None, dist)
    np.testing.assert_allclose(np.asarray(f_eng), small.f_max_hz, rtol=1e-6)


def test_baseline_allocators():
    a = env.rra_action(jax.random.key(0), 4)
    assert a.shape == (8,) and float(a.min()) >= 0 and float(a.max()) <= 1
    a = env.fpa_action(4, jnp.full((4,), 0.3))
    np.testing.assert_allclose(np.asarray(a[:4]), 0.5)
    a = env.fca_action(4, jnp.full((4,), 0.3))
    np.testing.assert_allclose(np.asarray(a[4:]), 0.5)
