"""DDPG + environment tests (paper §IV-C, Algorithm 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.hfl_mnist import CONFIG as HFL
from repro.core import ddpg, env


def _env(n=6, m=2, seed=0):
    rng = np.random.default_rng(seed)
    assoc = np.zeros((n, m))
    for i in range(n):
        assoc[i, i % m] = 1.0
    dist = rng.uniform(50.0, 300.0, (n, m))
    counts = rng.integers(200, 1200, n).astype(np.float32)
    return env.NomaHflEnv(HFL, jnp.asarray(assoc, jnp.float32),
                          jnp.ones((m,)), jnp.asarray(dist),
                          jnp.asarray(counts))


def test_env_reset_step(key):
    e = _env()
    state, obs = e.reset(key)
    assert obs.shape == (e.state_dim,)
    act = jnp.full((e.action_dim,), 0.5)
    state2, obs2, reward, rc = e.step(state, act)
    assert float(reward) == pytest.approx(-float(rc.cost))
    assert np.isfinite(np.asarray(obs2)).all()
    # channel evolved
    assert not np.allclose(np.asarray(state.gains), np.asarray(state2.gains))


def test_decode_action_bounds():
    e = _env()
    p, f = e.decode_action(jnp.zeros((e.action_dim,)))
    assert float(p.min()) == pytest.approx(HFL.p_min_w)
    assert float(f.min()) == pytest.approx(HFL.f_min_hz)
    p, f = e.decode_action(jnp.ones((e.action_dim,)))
    assert float(p.max()) == pytest.approx(HFL.p_max_w)
    assert float(f.max()) == pytest.approx(HFL.f_max_hz)


def test_networks_shapes(key):
    cfg = ddpg.DDPGConfig(state_dim=12, action_dim=12, hidden=32,
                          buffer_size=128, batch_size=16)
    st = ddpg.init_ddpg(key, cfg)
    s = jnp.zeros((12,))
    a = ddpg.actor_apply(st.actor, s)
    assert a.shape == (12,)
    assert float(a.min()) >= 0.0 and float(a.max()) <= 1.0
    q = ddpg.critic_apply(st.critic, s, a)
    assert q.shape == ()


def test_replay_ring(key):
    cfg = ddpg.DDPGConfig(state_dim=2, action_dim=2, buffer_size=4,
                          batch_size=2)
    st = ddpg.init_ddpg(key, cfg)
    for i in range(6):
        st = ddpg.store(st, cfg, jnp.full((2,), float(i)), jnp.zeros((2,)),
                        jnp.asarray(float(i)), jnp.zeros((2,)))
    assert bool(st.buffer_full)
    assert int(st.buffer_idx) == 2
    # slots hold the most recent 4 rewards {2,3,4,5}
    assert sorted(np.asarray(st.buffer["r"]).tolist()) == [2.0, 3.0, 4.0, 5.0]


def test_train_step_updates_and_targets_move(key):
    cfg = ddpg.DDPGConfig(state_dim=4, action_dim=2, hidden=32,
                          buffer_size=64, batch_size=16, tau=0.5)
    st = ddpg.init_ddpg(key, cfg)
    rng = np.random.default_rng(0)
    for i in range(32):
        s = jnp.asarray(rng.normal(size=4), jnp.float32)
        a = jnp.asarray(rng.uniform(size=2), jnp.float32)
        r = jnp.asarray(-float(np.sum(np.asarray(a) ** 2)))
        st = ddpg.store(st, cfg, s, a, r, s)
    t0 = jax.tree.leaves(st.target_actor)[0].copy()
    a0 = jax.tree.leaves(st.actor)[0].copy()
    st2, metrics = ddpg.train_step(key, st, cfg)
    assert np.isfinite(float(metrics["critic_loss"]))
    assert not np.allclose(a0, jax.tree.leaves(st2.actor)[0])
    assert not np.allclose(t0, jax.tree.leaves(st2.target_actor)[0])
    # soft update: target moved toward online, not equal to it
    assert not np.allclose(jax.tree.leaves(st2.target_actor)[0],
                           jax.tree.leaves(st2.actor)[0])


def test_ddpg_learns_simple_env(key):
    """Reward = -(a - 0.7)²: the actor should move its mean action to 0.7."""
    cfg = ddpg.DDPGConfig(state_dim=2, action_dim=1, hidden=32,
                          actor_lr=3e-3, critic_lr=3e-3,
                          buffer_size=512, batch_size=32, noise_sigma=0.3)
    st = ddpg.init_ddpg(key, cfg)
    rng = np.random.default_rng(0)
    k = key
    obs = jnp.zeros((2,))
    for i in range(400):
        k, ka, kt = jax.random.split(k, 3)
        a = ddpg.select_action(ka, st, obs)
        r = -float((np.asarray(a)[0] - 0.7) ** 2)
        st = ddpg.store(st, cfg, obs, a, jnp.asarray(r), obs)
        if i > 64:
            st, _ = ddpg.train_step(kt, st, cfg)
    final = float(ddpg.actor_apply(st.actor, obs)[0])
    assert abs(final - 0.7) < 0.2


def test_baseline_allocators():
    a = env.rra_action(jax.random.key(0), 4)
    assert a.shape == (8,) and float(a.min()) >= 0 and float(a.max()) <= 1
    a = env.fpa_action(4, jnp.full((4,), 0.3))
    np.testing.assert_allclose(np.asarray(a[:4]), 0.5)
    a = env.fca_action(4, jnp.full((4,), 0.3))
    np.testing.assert_allclose(np.asarray(a[4:]), 0.5)
