"""In-scan telemetry tests (DESIGN.md §10).

(a) the static flag: telemetry off keeps the engine output structurally
    identical (a plain ``RoundMetrics``) and BIT-equal to the PR-1
    goldens; telemetry on changes only the output arity — the metrics
    half stays bit-equal to the same goldens,
(b) ``RoundTrace`` shape/dtype invariants under ``run_scanned``,
    ``run_fleet`` and the client-sharded driver,
(c) the Eq. 23a decomposition identity: the three energy terms sum
    exactly to ``RoundMetrics.total_energy_j`` and the time terms
    upper-bound ``total_time_s``,
(d) streaming: a JSONL sink written by ``stream_scanned`` parses back to
    the same stacked pytree the pure collect mode returns,
(e) the sweep runner persists ``<cell>.trace.json`` beside the metrics.
"""
import dataclasses
import json
import os

import jax
import numpy as np
import pytest

from repro import sweeps
from repro.configs.hfl_mnist import CONFIG
from repro.core import engine
from repro.telemetry import RoundTrace, STALE_BIN_EDGES, sink, trace

SMALL = dataclasses.replace(CONFIG, n_clients=16, n_edges=2,
                            clients_per_edge=3, min_samples=60,
                            max_samples=120, hidden=32, input_dim=64)
GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "static_parity.json")
ROUNDS = 4

INT_LEAVES = {"round", "assoc_sweeps", "edge_load", "pdd_iters",
              "sic_depth", "stale_hist", "buffer_fill", "trigger_cause",
              "tier_active", "tier_occupancy", "dead_edges",
              "orphaned_clients", "uplink_retries", "uplink_dropped",
              "quarantined"}


def _leaf_shapes(m):
    """Expected trailing (per-round) shape of every RoundTrace leaf."""
    return {"edge_load": (m,), "z_relaxed": (m,),
            "stale_hist": (len(STALE_BIN_EDGES),)}


def _check_trace(tr, lead, m):
    assert isinstance(tr, RoundTrace)
    trailing = _leaf_shapes(m)
    for name, leaf in tr._asdict().items():
        leaf = np.asarray(leaf)
        want = lead + trailing.get(name, ())
        assert leaf.shape == want, f"{name}: {leaf.shape} != {want}"
        if name in INT_LEAVES:
            assert np.issubdtype(leaf.dtype, np.integer), name
        else:
            assert leaf.dtype == np.float32, name


# -- (a) static flag: structural absence + golden bit-parity -----------------

@pytest.mark.parametrize("policy,scheduler", [("fcea", "pdd"),
                                              ("gcea", "fastest")])
def test_telemetry_off_is_structurally_absent(policy, scheduler):
    spec = engine.EngineSpec(policy=policy, scheduler=scheduler)
    assert not spec.telemetry                       # off by default
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    _, out = engine.run_scanned(SMALL, spec, state, bundle, 2)
    assert isinstance(out, engine.RoundMetrics)     # no trace half at all
    ms, tr = engine.split_output(spec, out)
    assert ms is out and tr is None


@pytest.mark.parametrize("policy,scheduler", [("fcea", "pdd"),
                                              ("gcea", "fastest")])
def test_telemetry_on_metrics_bit_equal_golden(policy, scheduler):
    """Turning the flag on must not perturb a single metrics bit."""
    with open(GOLDEN) as fh:
        golden = json.load(fh)["trajectories"][f"{policy}-{scheduler}"]
    spec = engine.EngineSpec(policy=policy, scheduler=scheduler,
                             telemetry=True)
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    _, (ms, tr) = engine.run_scanned(SMALL, spec, state, bundle, ROUNDS)
    for field in ("accuracy", "loss", "cost", "total_time_s",
                  "total_energy_j", "avg_staleness"):
        np.testing.assert_array_equal(
            np.asarray(getattr(ms, field), np.float64),
            np.asarray(golden[field]), err_msg=field)
    _check_trace(tr, (ROUNDS,), SMALL.n_edges)
    # scheduler internals match the spec: PDD iterates, "fastest" doesn't
    if scheduler == "pdd":
        assert np.all(np.asarray(tr.pdd_iters) > 0)
    else:
        assert np.all(np.asarray(tr.pdd_iters) == 0)


# -- (b) shape/dtype invariants under every driver ---------------------------

def test_trace_shapes_scanned_and_fleet():
    spec = engine.EngineSpec(policy="fcea", scheduler="pdd", telemetry=True)
    seeds = (0, 1)
    pairs = [engine.init_simulation(SMALL, seed=s)[:2] for s in seeds]
    _, ms, tr = sink.collect_scanned(SMALL, spec, *pairs[0], 3)
    _check_trace(tr, (3,), SMALL.n_edges)
    states, bundles = engine.stack_fleet(pairs)
    _, msf, trf = sink.collect_fleet(SMALL, spec, states, bundles, 3)
    _check_trace(trf, (len(seeds), 3), SMALL.n_edges)
    # fleet lane 0 == the single-sim run (same world, same program)
    for name in RoundTrace._fields:
        np.testing.assert_allclose(
            np.asarray(getattr(trf, name))[0],
            np.asarray(getattr(tr, name)), rtol=1e-5, err_msg=name)


def test_trace_shapes_client_sharded():
    spec = engine.EngineSpec(policy="gcea", scheduler="fastest",
                             telemetry=True)
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    _, out = engine.run_scanned_client_sharded(SMALL, spec, state, bundle, 2)
    ms, tr = engine.split_output(spec, out)
    _check_trace(tr, (2,), SMALL.n_edges)
    # N=16 on the 1-device CPU mesh needs no padding: bit-equal to plain
    _, out2 = engine.run_scanned(SMALL, spec, state, bundle, 2)
    np.testing.assert_array_equal(np.asarray(tr.edge_load),
                                  np.asarray(out2[1].edge_load))


def test_trace_candidate_frontier_fields():
    spec = engine.EngineSpec(policy="gcea", scheduler="fastest",
                             candidates_k=2, telemetry=True)
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    _, ms, tr = sink.collect_scanned(SMALL, spec, state, bundle, 3)
    _check_trace(tr, (3,), SMALL.n_edges)
    vf = np.asarray(tr.frontier_valid_frac)
    sat = np.asarray(tr.frontier_saturation)
    assert np.all((vf >= 0) & (vf <= 1)) and np.all((sat >= 0) & (sat <= 1))
    assert np.all(np.asarray(tr.assoc_sweeps) >= 1)


# -- (c) Eq. 23a decomposition identity --------------------------------------

@pytest.mark.parametrize("policy,scheduler", [("fcea", "pdd"),
                                              ("gcea", "fastest")])
def test_cost_decomposition_identity(policy, scheduler):
    spec = engine.EngineSpec(policy=policy, scheduler=scheduler,
                             telemetry=True)
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    _, ms, tr = sink.collect_scanned(SMALL, spec, state, bundle, ROUNDS)
    energy = (np.asarray(tr.energy_local_j) + np.asarray(tr.energy_uplink_j)
              + np.asarray(tr.energy_cloud_j))
    np.testing.assert_allclose(energy, np.asarray(ms.total_energy_j),
                               rtol=1e-5)
    tsum = (np.asarray(tr.time_local_s) + np.asarray(tr.time_uplink_s)
            + np.asarray(tr.time_cloud_s))
    assert np.all(tsum >= np.asarray(ms.total_time_s) - 1e-5)
    # the SIC decode depth is the max edge occupancy, capped by the quota
    assert np.all(np.asarray(tr.sic_depth)
                  == np.asarray(tr.edge_load).max(axis=1))
    assert np.all(np.asarray(tr.sic_depth) <= SMALL.clients_per_edge)


def test_staleness_histogram_counts_every_client():
    stale = np.array([1, 1, 2, 3, 5, 7, 9, 20], np.int32)
    hist = np.asarray(trace.staleness_histogram(stale))
    assert hist.sum() == stale.size
    assert hist[0] == 2 and hist[-1] == 1          # A_n=1 pair; A_n=20


# -- (d) streaming sinks: JSONL round-trip -----------------------------------

class _Tee:
    def __init__(self, *sinks):
        self.sinks = sinks

    def emit(self, tr):
        for s in self.sinks:
            s.emit(tr)


def test_jsonl_sink_roundtrip(tmp_path):
    spec = engine.EngineSpec(policy="gcea", scheduler="fastest",
                             candidates_k=2, telemetry=True)
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    path = str(tmp_path / "rounds.jsonl")
    mem = sink.MemorySink()
    with sink.JsonlSink(path) as js:
        _, ms, tr = sink.stream_scanned(SMALL, spec, state, bundle, ROUNDS,
                                        _Tee(mem, js))
    assert len(mem.records) == ROUNDS
    parsed = sink.load_jsonl(path)
    stacked = mem.stacked()
    for name in RoundTrace._fields:
        want = np.asarray(getattr(tr, name))
        np.testing.assert_allclose(parsed[name], want, rtol=1e-6,
                                   err_msg=name)
        np.testing.assert_array_equal(np.asarray(getattr(stacked, name)),
                                      want, err_msg=name)
    # the stream is a tee: the returned pytree is the collect-mode result
    _, ms2, tr2 = sink.collect_scanned(SMALL, spec, state, bundle, ROUNDS)
    np.testing.assert_array_equal(np.asarray(tr.edge_load),
                                  np.asarray(tr2.edge_load))


def test_stream_requires_telemetry():
    spec = engine.EngineSpec(policy="gcea", scheduler="fastest")
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    with pytest.raises(ValueError, match="telemetry"):
        sink.stream_scanned(SMALL, spec, state, bundle, 1,
                            sink.MemorySink())


def test_emit_stacked_bridges_fleet_traces():
    spec = engine.EngineSpec(policy="fcea", scheduler="pdd", telemetry=True)
    pairs = [engine.init_simulation(SMALL, seed=s)[:2] for s in (0, 1)]
    states, bundles = engine.stack_fleet(pairs)
    _, ms, tr = sink.collect_fleet(SMALL, spec, states, bundles, 2)
    mem = sink.MemorySink()
    sink.emit_stacked(tr, mem, fleet_axes=1)
    assert len(mem.records) == 2 * 2               # (seed, round) pairs
    assert all(r.edge_load.shape == (SMALL.n_edges,) for r in mem.records)


# -- (e) the sweep runner persists traces ------------------------------------

def test_sweep_writes_trace_json(tmp_path):
    grid = sweeps.SweepGrid(name="tt", scenarios=("static",),
                            policies=("gcea",), schedulers=("fastest",),
                            seeds=(0,), n_rounds=2, telemetry=True)
    summary = sweeps.run_sweep(SMALL, grid, out_dir=str(tmp_path))
    sweep_dir = os.path.join(str(tmp_path), "sweep_tt")
    traces = [f for f in os.listdir(sweep_dir) if f.endswith(".trace.json")]
    assert len(traces) == summary["n_cells"] == 1
    with open(os.path.join(sweep_dir, traces[0])) as fh:
        payload = json.load(fh)
    assert payload["n_rounds"] == 2
    tr = payload["trace"]
    assert set(tr) == set(RoundTrace._fields)
    assert len(tr["time_local_s"]) == 2
    assert len(tr["edge_load"][0]) == SMALL.n_edges
