"""Hierarchical aggregation tests (paper Eqs. 11, 17) + staleness (Eq. 20)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregation, staleness


def _stacked(n, seed=0):
    rng = np.random.default_rng(seed)
    return {"w": jnp.asarray(rng.normal(size=(n, 3, 2)), jnp.float32),
            "b": jnp.asarray(rng.normal(size=(n, 2)), jnp.float32)}


def test_weighted_mean_matches_numpy():
    p = _stacked(4)
    w = jnp.asarray([1.0, 2.0, 3.0, 4.0])
    out = aggregation.weighted_mean(p, w)
    want = np.average(np.asarray(p["w"]), axis=0, weights=np.asarray(w))
    np.testing.assert_allclose(np.asarray(out["w"]), want, rtol=1e-6)


def test_edge_aggregate_eq11():
    p = _stacked(4)
    assoc = jnp.asarray([[1., 0.], [1., 0.], [0., 1.], [0., 0.]])
    d = jnp.asarray([100., 300., 500., 700.])
    out = aggregation.edge_aggregate(p, assoc, d)
    w = np.asarray(p["w"])
    want0 = (100 * w[0] + 300 * w[1]) / 400
    np.testing.assert_allclose(np.asarray(out["w"][0]), want0, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(out["w"][1]), w[2], rtol=1e-5)


def test_hierarchical_equals_flat():
    """Edge-then-cloud == one flat data-weighted average when every edge is
    selected — the sanity identity of the client→edge→cloud hierarchy."""
    n, m = 6, 2
    p = _stacked(n, seed=1)
    assoc = jnp.asarray([[1., 0.], [1., 0.], [1., 0.],
                         [0., 1.], [0., 1.], [0., 1.]])
    d = jnp.asarray([1., 2., 3., 4., 5., 6.]) * 100
    edge = aggregation.edge_aggregate(p, assoc, d)
    edge_data = jnp.sum(assoc * d[:, None], axis=0)
    cloud = aggregation.cloud_aggregate(edge, jnp.ones((m,)), edge_data)
    flat = aggregation.weighted_mean(p, d)
    np.testing.assert_allclose(np.asarray(cloud["w"]), np.asarray(flat["w"]),
                               rtol=1e-5)


def test_cloud_aggregate_mask():
    m = 3
    p = _stacked(m)
    z = jnp.asarray([1.0, 0.0, 1.0])
    d = jnp.asarray([100.0, 100.0, 300.0])
    out = aggregation.cloud_aggregate(p, z, d)
    w = np.asarray(p["w"])
    want = (100 * w[0] + 300 * w[2]) / 400
    np.testing.assert_allclose(np.asarray(out["w"]), want, rtol=1e-5)


def test_broadcast_to_clients():
    n, m = 3, 2
    edge = _stacked(m)
    client = _stacked(n, seed=2)
    assoc = jnp.asarray([[1., 0.], [0., 1.], [0., 0.]])
    out = aggregation.broadcast_to_clients(None, assoc, edge, client)
    np.testing.assert_allclose(np.asarray(out["w"][0]),
                               np.asarray(edge["w"][0]))
    np.testing.assert_allclose(np.asarray(out["w"][1]),
                               np.asarray(edge["w"][1]))
    # unassociated client keeps its own params
    np.testing.assert_allclose(np.asarray(out["w"][2]),
                               np.asarray(client["w"][2]))


def test_replicate():
    p = {"w": jnp.ones((2, 2))}
    out = aggregation.replicate(p, 5)
    assert out["w"].shape == (5, 2, 2)


def test_staleness_eq20():
    s = staleness.init_staleness(4)
    np.testing.assert_array_equal(np.asarray(s), [1, 1, 1, 1])
    s = staleness.update_staleness(s, jnp.asarray([True, False, False, True]))
    np.testing.assert_array_equal(np.asarray(s), [1, 2, 2, 1])
    s = staleness.update_staleness(s, jnp.asarray([False, False, True, True]))
    np.testing.assert_array_equal(np.asarray(s), [2, 3, 1, 1])
