"""Shared fixtures.  NOTE: no XLA_FLAGS here — tests must see the single
real CPU device; only the dry-run subprocess spawns 512 placeholders."""
import jax
import numpy as np
import pytest


@pytest.fixture
def key():
    return jax.random.key(0)


@pytest.fixture
def rng():
    return np.random.default_rng(0)
