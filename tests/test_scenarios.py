"""Dynamic-scenario subsystem tests (DESIGN.md §6).

(a) golden parity: scenario="static" reproduces the PR-1 engine's
    trajectories bit-for-bit (tests/golden/static_parity.json was recorded
    from the pre-scenario engine),
(b) purity: the scenario-enabled ``round_step`` lowers with no host
    callbacks,
(c) transition semantics: waypoint motion stays inside the cell,
    availability is a boolean Markov chain with the configured stationary
    rate, device classes respect the cfg bounds,
(d) the availability mask actually excludes clients from association,
    aggregation and cost,
(e) eager == scanned == fleet for dynamic scenarios too.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or its absent-shim

from repro import scenarios
from repro.configs.hfl_mnist import CONFIG
from repro.core import engine
from repro.core.hfl import HFLSimulation

SMALL = dataclasses.replace(CONFIG, n_clients=16, n_edges=2,
                            clients_per_edge=3, min_samples=60,
                            max_samples=120, hidden=32, input_dim=64)
GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "static_parity.json")


def _advance_n(cfg, sspec, seed, rounds):
    rng = np.random.default_rng(seed)
    topo = engine.make_topology(rng, n_clients=cfg.n_clients,
                                n_edges=cfg.n_edges,
                                area_side_m=cfg.area_side_m)
    s = scenarios.init_scenario(cfg, sspec, rng, topo)
    states = [s]
    key = jax.random.key(seed)
    step = jax.jit(scenarios.advance_dynamic, static_argnums=(0,))
    for _ in range(rounds):
        key, k = jax.random.split(key)
        s = step(cfg, k, s)
        states.append(s)
    return states


# -- (a) golden static parity -------------------------------------------------

@pytest.mark.parametrize("policy,scheduler", [("fcea", "pdd"),
                                              ("gcea", "fastest")])
def test_static_matches_pr1_golden(policy, scheduler):
    """Bit-exact float equality against the recorded PR-1 trajectories."""
    with open(GOLDEN) as fh:
        golden = json.load(fh)["trajectories"][f"{policy}-{scheduler}"]
    spec = engine.EngineSpec(policy=policy, scheduler=scheduler)
    assert spec.scenario == "static"          # the default IS the PR-1 path
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    _, ms = engine.run_scanned(SMALL, spec, state, bundle, 4)
    for field in ("accuracy", "loss", "cost", "total_time_s",
                  "total_energy_j", "avg_staleness"):
        got = np.asarray(getattr(ms, field), np.float64)
        np.testing.assert_array_equal(got, np.asarray(golden[field]),
                                      err_msg=field)
    np.testing.assert_array_equal(np.asarray(ms.n_associated),
                                  golden["n_associated"])
    np.testing.assert_array_equal(np.asarray(ms.z), golden["z"])


# -- (b) purity of the scenario-enabled program -------------------------------

@pytest.mark.parametrize("kind", ["static", "dynamic"])
def test_scenario_round_step_lowers_without_callbacks(kind):
    spec = engine.EngineSpec(policy="gcea", scheduler="fastest",
                             scenario=kind)
    state, bundle, _ = engine.init_simulation(
        SMALL, seed=0, scenario="full_dynamic" if kind == "dynamic" else None)
    txt = jax.jit(engine.round_step, static_argnums=(0, 1)).lower(
        SMALL, spec, state, bundle).as_text()
    assert "callback" not in txt
    assert "CustomCall" not in txt


# -- (c) transition semantics -------------------------------------------------

def test_waypoint_positions_stay_inside_cell():
    sspec = scenarios.ScenarioSpec(kind="random_waypoint",
                                   speed_max_mps=40.0, round_duration_s=10.0)
    for s in _advance_n(SMALL, sspec, seed=0, rounds=25):
        pos = np.asarray(s.pos)
        assert (pos >= 0.0).all() and (pos <= SMALL.area_side_m).all()
        # distances stay consistent with positions
        want = np.linalg.norm(pos[:, None, :] - np.asarray(s.edges)[None],
                              axis=-1)
        np.testing.assert_allclose(np.asarray(s.dist), want, rtol=1e-5)


def test_waypoint_actually_moves_clients():
    sspec = scenarios.ScenarioSpec(kind="random_waypoint", speed_min_mps=5.0)
    states = _advance_n(SMALL, sspec, seed=0, rounds=5)
    moved = np.abs(np.asarray(states[-1].pos) - np.asarray(states[0].pos))
    assert moved.max() > 1.0


def test_markov_availability_boolean_and_stationary_rate():
    big = dataclasses.replace(SMALL, n_clients=512)
    sspec = scenarios.ScenarioSpec(kind="markov_dropout", p_drop=0.3,
                                   p_return=0.2)
    states = _advance_n(big, sspec, seed=1, rounds=40)
    fractions = []
    for s in states[10:]:                       # after burn-in
        a = np.asarray(s.avail)
        assert set(np.unique(a)) <= {0.0, 1.0}
        fractions.append(a.mean())
    want = sspec.stationary_availability        # 0.2 / 0.5 = 0.4
    assert abs(np.mean(fractions) - want) < 0.05


def test_hetero_device_classes_within_bounds():
    sspec = scenarios.ScenarioSpec(kind="hetero_devices", n_device_classes=5)
    rng = np.random.default_rng(2)
    topo = engine.make_topology(rng, n_clients=64, n_edges=2,
                                area_side_m=SMALL.area_side_m)
    cfg = dataclasses.replace(SMALL, n_clients=64)
    s = scenarios.init_scenario(cfg, sspec, rng, topo)
    f = np.asarray(s.f_max_hz)
    p = np.asarray(s.p_max_w)
    assert (f >= cfg.f_min_hz).all() and (f <= cfg.f_max_hz).all()
    assert (p >= cfg.p_min_w).all() and (p <= cfg.p_max_w).all()
    assert (np.asarray(s.kappa) >= cfg.capacitance).all()
    assert len(np.unique(f)) > 1                # genuinely heterogeneous
    # device classes are persistent under the transition
    s2 = scenarios.advance_dynamic(cfg, jax.random.key(0), s)
    np.testing.assert_array_equal(np.asarray(s2.f_max_hz), f)


def test_static_transition_is_identity():
    sspec = scenarios.ScenarioSpec()
    rng = np.random.default_rng(3)
    topo = engine.make_topology(rng, n_clients=8, n_edges=2,
                                area_side_m=SMALL.area_side_m)
    cfg = dataclasses.replace(SMALL, n_clients=8)
    s = scenarios.init_scenario(cfg, sspec, rng, topo)
    s2 = scenarios.advance(cfg, "static", jax.random.key(0), s)
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(s2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # and a static-parameterised DYNAMIC step leaves the world fixed too
    # (identity-by-parameterisation: speed 0, p_drop 0, p_return 1)
    s3 = scenarios.advance_dynamic(cfg, jax.random.key(0), s)
    np.testing.assert_array_equal(np.asarray(s3.pos), np.asarray(s.pos))
    np.testing.assert_array_equal(np.asarray(s3.avail), np.asarray(s.avail))
    # distances are recomputed on-device from the (unmoved) positions —
    # equal up to the f32 vs host-f64 norm rounding
    np.testing.assert_allclose(np.asarray(s3.dist), np.asarray(s.dist),
                               rtol=1e-6)


# -- hypothesis property versions --------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.floats(1.0, 50.0))
def test_prop_waypoint_in_cell(seed, speed_max):
    sspec = scenarios.ScenarioSpec(kind="random_waypoint",
                                   speed_max_mps=speed_max)
    for s in _advance_n(SMALL, sspec, seed=seed, rounds=8):
        pos = np.asarray(s.pos)
        assert (pos >= 0.0).all() and (pos <= SMALL.area_side_m).all()


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.floats(0.05, 0.6), st.floats(0.05, 0.6))
def test_prop_availability_mask_boolean(seed, p_drop, p_return):
    sspec = scenarios.ScenarioSpec(kind="markov_dropout", p_drop=p_drop,
                                   p_return=p_return)
    for s in _advance_n(SMALL, sspec, seed=seed, rounds=6):
        a = np.asarray(s.avail)
        assert set(np.unique(a)) <= {0.0, 1.0}


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 1000), st.integers(1, 8))
def test_prop_device_classes_within_bounds(seed, n_classes):
    sspec = scenarios.ScenarioSpec(kind="hetero_devices",
                                   n_device_classes=n_classes)
    rng = np.random.default_rng(seed)
    topo = engine.make_topology(rng, n_clients=32, n_edges=2,
                                area_side_m=SMALL.area_side_m)
    cfg = dataclasses.replace(SMALL, n_clients=32)
    s = scenarios.init_scenario(cfg, sspec, rng, topo)
    f = np.asarray(s.f_max_hz)
    assert (f >= cfg.f_min_hz).all() and (f <= cfg.f_max_hz).all()


# -- (d) the mask reaches association / aggregation / cost --------------------

def test_unavailable_clients_never_associated():
    spec = engine.EngineSpec(policy="fcea", scheduler="pdd",
                             scenario="markov_dropout")
    state, bundle, _ = engine.init_simulation(
        SMALL, seed=0,
        scenario=scenarios.ScenarioSpec(kind="markov_dropout", p_drop=0.6,
                                        p_return=0.2))
    for _ in range(6):
        state, m = engine.round_step_jit(SMALL, spec, state, bundle)
        avail = np.asarray(state.scenario.avail)
        # re-derive this round's association to inspect it: the metrics
        # count must also never exceed the available population
        assert int(m.n_associated) <= int(m.n_available)
        assert int(m.n_available) == int(avail.sum())


def test_all_clients_dropped_keeps_global_model():
    """Degenerate world: nobody is available — the global model must ride
    through unchanged (Eq. 17 guard) and the round must not NaN."""
    sspec = scenarios.ScenarioSpec(kind="markov_dropout", p_drop=1.0,
                                   p_return=0.0)
    spec = engine.EngineSpec(policy="gcea", scheduler="fastest",
                             scenario="dynamic")
    state, bundle, _ = engine.init_simulation(SMALL, seed=0, scenario=sspec)
    s1, m = engine.round_step_jit(SMALL, spec, state, bundle)
    assert int(m.n_available) == 0 and int(m.n_associated) == 0
    for a, b in zip(jax.tree.leaves(state.global_params),
                    jax.tree.leaves(s1.global_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert np.isfinite(float(m.cost))


def test_hetero_devices_raise_energy_cost():
    """Weaker devices (higher κ at the same clamped f) change Eq. 23a."""
    spec_s = engine.EngineSpec(policy="gcea", scheduler="fastest")
    spec_d = engine.EngineSpec(policy="gcea", scheduler="fastest",
                               scenario="dynamic")
    st0, bu, _ = engine.init_simulation(SMALL, seed=0)
    _, ms = engine.run_scanned(SMALL, spec_s, st0, bu, 3)
    sspec = scenarios.ScenarioSpec(kind="hetero_devices", kappa_spread=4.0)
    st1, bu1, _ = engine.init_simulation(SMALL, seed=0, scenario=sspec)
    _, md = engine.run_scanned(SMALL, spec_d, st1, bu1, 3)
    assert not np.allclose(np.asarray(ms.total_energy_j),
                           np.asarray(md.total_energy_j))


# -- (e) drivers agree under dynamic scenarios --------------------------------

def test_dynamic_eager_matches_scanned():
    rounds = 3
    kwargs = dict(seed=0, iid=True, policy="fcea", scheduler="pdd",
                  scenario="full_dynamic")
    eager = HFLSimulation(SMALL, **kwargs)
    scanned = HFLSimulation(SMALL, **kwargs)
    assert eager.spec.scenario == "dynamic"
    me = eager.run(rounds)
    ms = scanned.run_scanned(rounds)
    for a, b in zip(me, ms):
        assert a.n_associated == b.n_associated
        assert a.n_available == b.n_available
        np.testing.assert_array_equal(a.z, b.z)
        np.testing.assert_allclose(a.loss, b.loss, rtol=1e-5)
        np.testing.assert_allclose(a.cost, b.cost, rtol=1e-5)


def test_dynamic_fleet_matches_sequential():
    spec = engine.EngineSpec(policy="gcea", scheduler="fastest",
                             scenario="dynamic")
    pairs = [engine.init_simulation(SMALL, seed=s,
                                    scenario="mobile_flaky")[:2]
             for s in (0, 1)]
    states, bundles = engine.stack_fleet(pairs)
    _, fleet = engine.run_fleet(SMALL, spec, states, bundles, 2)
    for i, (st_i, bu_i) in enumerate(pairs):
        _, seq = engine.run_scanned(SMALL, spec, st_i, bu_i, 2)
        np.testing.assert_allclose(np.asarray(fleet.cost[i]),
                                   np.asarray(seq.cost), rtol=1e-5)
        np.testing.assert_array_equal(np.asarray(fleet.n_available[i]),
                                      np.asarray(seq.n_available))


def test_ddpg_allocator_runs_under_dynamic_scenario():
    """Regression: the actor must train on the scenario-sliced (3N,)
    observation so the engine's DDPG path doesn't shape-mismatch."""
    sim = HFLSimulation(SMALL, seed=0, policy="gcea", scheduler="fastest",
                        allocator="ddpg", scenario="full_dynamic")
    sim.train_ddpg(episodes=1, steps_per_episode=3, warmup=2, hidden=16)
    assert sim.agent_cfg.state_dim == 3 * SMALL.n_clients
    m = sim.run_round()
    assert np.isfinite(m.cost)


# -- spec plumbing ------------------------------------------------------------

def test_preset_and_kind_validation():
    assert scenarios.preset("static").engine_kind() == "static"
    assert scenarios.preset("full_dynamic").engine_kind() == "dynamic"
    assert scenarios.preset(
        "random_waypoint+markov_dropout").engine_kind() == "dynamic"
    with pytest.raises(ValueError):
        scenarios.preset("warp_drive").parts
    with pytest.raises(ValueError):
        scenarios.advance(SMALL, "warp_drive", jax.random.key(0), None)


def test_register_custom_transition_end_to_end():
    """The documented extension path: a registered custom kind must flow
    through preset/init_simulation/EngineSpec into round_step."""
    kind = "_test_blackout"

    def blackout(cfg, key, s):
        return s._replace(avail=s.avail * 0.0)

    scenarios.register_transition(kind, blackout)
    try:
        sspec = scenarios.preset(kind)
        assert sspec.is_dynamic and sspec.parts == ()
        assert sspec.engine_kind() == kind
        spec = engine.EngineSpec(policy="gcea", scheduler="fastest",
                                 scenario=kind)
        state, bundle, _ = engine.init_simulation(SMALL, seed=0,
                                                  scenario=kind)
        _, m = engine.round_step_jit(SMALL, spec, state, bundle)
        assert int(m.n_available) == 0          # the custom world acted
    finally:
        del scenarios.TRANSITIONS[kind]


def test_env_respects_noma_switch():
    """train_ddpg's env must bill the simulation's NOMA/OMA uplink."""
    from repro.core import env as env_mod
    n, m = SMALL.n_clients, SMALL.n_edges
    rng = np.random.default_rng(1)
    assoc = np.zeros((n, m), np.float32)
    assoc[np.arange(n), rng.integers(0, m, n)] = 1.0
    dist = jnp.asarray(rng.uniform(50.0, 300.0, (n, m)))
    counts = jnp.asarray(rng.integers(60, 120, n), jnp.float32)
    rewards = {}
    for noma in (True, False):
        e = env_mod.NomaHflEnv(SMALL, jnp.asarray(assoc), jnp.ones((m,)),
                               dist, counts, noma_enabled=noma)
        s0, _ = e.reset(jax.random.key(0))
        _, _, r, _ = e.step(s0, jnp.full((2 * n,), 0.5))
        rewards[noma] = float(r)
    assert rewards[True] != rewards[False]


def test_env_availability_evolves_during_training():
    """With (p_drop, p_return) the env's availability chain runs BETWEEN
    slots, so the actor's third obs block actually varies (and dropped
    clients are not billed)."""
    from repro.core import env as env_mod
    n, m = SMALL.n_clients, SMALL.n_edges
    rng = np.random.default_rng(2)
    assoc = np.zeros((n, m), np.float32)
    assoc[np.arange(n), rng.integers(0, m, n)] = 1.0
    dist = jnp.asarray(rng.uniform(50.0, 300.0, (n, m)))
    counts = jnp.asarray(rng.integers(60, 120, n), jnp.float32)
    e = env_mod.NomaHflEnv(SMALL, jnp.asarray(assoc), jnp.ones((m,)),
                           dist, counts,
                           p_drop=jnp.full((n,), 0.5),
                           p_return=jnp.full((n,), 0.5))
    assert e.state_dim == 3 * n
    s, obs = e.reset(jax.random.key(0))
    assert obs.shape == (3 * n,)
    seen = set()
    for _ in range(6):
        s, obs, r, _ = e.step(s, jnp.full((2 * n,), 0.5))
        assert np.isfinite(float(r))
        seen.add(tuple(np.asarray(s.avail).tolist()))
        # dropped clients vanish from ALL observation blocks, exactly as
        # the engine's masked assoc makes them vanish at deployment
        a = np.asarray(s.avail)
        o = np.asarray(obs).reshape(3, n)
        assert (o[:, a == 0.0] == 0.0).all()
    assert len(seen) > 1                      # the chain really moves


def test_all_part_mixtures_registered():
    """Every kind string ScenarioSpec.parts accepts must resolve to a
    transition — including the 3-part mixture, in any order."""
    import itertools
    parts = ("random_waypoint", "markov_dropout", "hetero_devices")
    for r in (1, 2, 3):
        for combo in itertools.permutations(parts, r):
            kind = "+".join(combo)
            assert scenarios.preset(kind).is_dynamic
            assert kind in scenarios.TRANSITIONS, kind


def test_env_bills_scenario_cost_surface():
    """The DDPG env must charge the engine's bill: per-device κ raises the
    reward's energy term and the device caps clamp the decoded action."""
    from repro.core import env as env_mod
    n, m = SMALL.n_clients, SMALL.n_edges
    rng = np.random.default_rng(0)
    assoc = np.zeros((n, m), np.float32)
    assoc[np.arange(n), rng.integers(0, m, n)] = 1.0
    dist = jnp.asarray(rng.uniform(50.0, 300.0, (n, m)))
    counts = jnp.asarray(rng.integers(60, 120, n), jnp.float32)
    kappa = jnp.full((n,), SMALL.capacitance * 5.0)
    f_cap = jnp.full((n,), SMALL.f_min_hz)
    common = dict(fading_rho=0.9)
    e_plain = env_mod.NomaHflEnv(SMALL, jnp.asarray(assoc),
                                 jnp.ones((m,)), dist, counts, **common)
    e_scen = env_mod.NomaHflEnv(SMALL, jnp.asarray(assoc),
                                jnp.ones((m,)), dist, counts,
                                kappa=kappa, f_max_hz=f_cap, **common)
    act = jnp.full((2 * n,), 1.0)                  # max p, max f requested
    _, f_plain = e_plain.decode_action(act)
    _, f_scen = e_scen.decode_action(act)
    assert float(jnp.max(f_scen)) == SMALL.f_min_hz   # clamped to the cap
    assert float(jnp.max(f_plain)) == pytest.approx(SMALL.f_max_hz,
                                                    rel=1e-6)
    key = jax.random.key(0)
    s0, _ = e_plain.reset(key)
    _, _, r_plain, _ = e_plain.step(s0, jnp.full((2 * n,), 0.5))
    s1, _ = e_scen.reset(key)
    _, _, r_scen, _ = e_scen.step(s1, jnp.full((2 * n,), 0.5))
    assert float(r_plain) != float(r_scen)


def test_flash_crowd_returns_in_waves():
    """flash_crowd (DESIGN.md §11): between bursts the up-set only decays
    (no lone returns); on a burst EVERY previously-dropped client comes
    back at once — the all-or-nothing wave property."""
    big = dataclasses.replace(SMALL, n_clients=256)
    sspec = scenarios.ScenarioSpec(kind="flash_crowd", p_drop=0.3,
                                   p_return=0.2)
    rng = np.random.default_rng(3)
    topo = engine.make_topology(rng, n_clients=big.n_clients,
                                n_edges=big.n_edges,
                                area_side_m=big.area_side_m)
    s = scenarios.init_scenario(big, sspec, rng, topo)
    step = jax.jit(scenarios.advance, static_argnums=(0, 1))
    key = jax.random.key(3)
    bursts = quiets = 0
    for _ in range(60):
        before = np.asarray(s.avail) > 0
        key, k = jax.random.split(key)
        s = step(big, "flash_crowd", k, s)
        after = np.asarray(s.avail)
        assert set(np.unique(after)) <= {0.0, 1.0}
        returned = (~before) & (after > 0)
        n_down = int((~before).sum())
        if n_down and returned.sum() == n_down:
            bursts += 1
        else:
            assert returned.sum() == 0          # no lone returns
            quiets += 1
    assert bursts >= 1 and quiets >= 1          # the sawtooth really runs
    # the preset registers through the normal registry machinery
    assert "flash_crowd" in scenarios.TRANSITIONS
    assert scenarios.preset("flash_crowd").is_dynamic
