"""End-to-end HFL integration: fuzzy + NOMA + PDD + aggregation interoperate
and the global model actually learns over rounds (paper Figs. 8-11 in
miniature)."""
import dataclasses

import numpy as np
import pytest

from repro.configs.hfl_mnist import CONFIG
from repro.core.hfl import HFLSimulation

SMALL = dataclasses.replace(CONFIG, n_clients=16, n_edges=2,
                            clients_per_edge=3, min_samples=60,
                            max_samples=120, hidden=32, input_dim=64)


def test_three_rounds_learn():
    sim = HFLSimulation(SMALL, seed=0, iid=True, policy="fcea")
    ms = sim.run(3)
    assert ms[-1].loss < ms[0].loss + 1e-6
    assert ms[-1].accuracy >= ms[0].accuracy - 0.05
    for m in ms:
        assert np.isfinite(m.cost) and m.cost > 0
        assert m.n_associated <= SMALL.clients_per_edge * SMALL.n_edges
        assert m.z.sum() >= 1


def test_policies_run():
    for policy in ("fcea", "gcea", "rcea"):
        sim = HFLSimulation(SMALL, seed=1, iid=True, policy=policy)
        m = sim.run_round()
        assert np.isfinite(m.loss)


def test_noniid_runs():
    sim = HFLSimulation(SMALL, seed=2, iid=False, policy="fcea")
    ms = sim.run(2)
    assert np.isfinite(ms[-1].loss)


def test_staleness_tracked():
    sim = HFLSimulation(SMALL, seed=3, iid=True, policy="fcea")
    ms = sim.run(3)
    # unselected clients age -> average staleness grows above 1
    assert ms[-1].avg_staleness > 1.0


@pytest.mark.slow
def test_fcea_vs_rcea_staleness():
    """FCEA considers MS -> lower average staleness than RCEA over rounds
    (paper Fig. 12), with matched seeds."""
    rounds = 6
    f = HFLSimulation(SMALL, seed=4, iid=True, policy="fcea")
    r = HFLSimulation(SMALL, seed=4, iid=True, policy="rcea")
    fm = f.run(rounds)
    rm = r.run(rounds)
    assert fm[-1].avg_staleness <= rm[-1].avg_staleness + 0.5


def test_oma_fewer_effective_rates():
    sim_noma = HFLSimulation(SMALL, seed=5, iid=True, noma_enabled=True)
    sim_oma = HFLSimulation(SMALL, seed=5, iid=True, noma_enabled=False)
    mn = sim_noma.run_round()
    mo = sim_oma.run_round()
    assert np.isfinite(mn.cost) and np.isfinite(mo.cost)


@pytest.mark.slow
def test_ddpg_training_loop():
    sim = HFLSimulation(SMALL, seed=6, iid=True, allocator="ddpg")
    hist = sim.train_ddpg(episodes=3, steps_per_episode=10, warmup=16,
                          hidden=32)
    assert len(hist["episode_reward"]) == 3
    assert all(np.isfinite(v) for v in hist["episode_reward"])
    m = sim.run_round()          # uses the trained agent
    assert np.isfinite(m.cost)


def test_scheduler_variants():
    for sched in ("pdd", "fastest"):
        sim = HFLSimulation(SMALL, seed=7, iid=True, scheduler=sched)
        m = sim.run_round()
        quota = max(1, int(round(SMALL.semi_sync_fraction * SMALL.n_edges)))
        assert int(m.z.sum()) == quota
