"""Optional-dependency shim for hypothesis.

The property tests use ``@given`` with simple scalar strategies; when
hypothesis is installed they run as usual, and when it is absent (the
offline container) they collect as skips instead of killing the whole
module at import time — the plain unit tests keep running either way.

Usage in tests:  ``from _hyp import given, settings, st``.
"""
try:
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import pytest

    HAVE_HYPOTHESIS = False

    def given(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed (property test)")(fn)
        return deco

    def settings(*_args, **_kwargs):
        return lambda fn: fn

    class _Strategies:
        """Accepts any strategy constructor; values are never drawn."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()
