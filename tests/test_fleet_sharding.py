"""Fleet-axis sharding (DESIGN.md §8.3): ``run_fleet_sharded`` and the
sharded sweep runner must reproduce the unsharded results exactly, with
the fleet axis genuinely split across devices.

The multi-device cases run in a SUBPROCESS: the placeholder-device
``XLA_FLAGS`` must be set before jax imports and must not leak into this
test process (same pattern as test_dryrun_subprocess.py).
"""
import dataclasses
import os
import subprocess
import sys

import jax
import numpy as np

from repro.configs.hfl_mnist import CONFIG
from repro.core import engine

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SMALL = dataclasses.replace(CONFIG, n_clients=16, n_edges=2,
                            clients_per_edge=3, min_samples=60,
                            max_samples=120, hidden=32, input_dim=64)


def test_single_device_sharded_matches_plain():
    """On the 1-device default mesh the sharded driver is a pass-through."""
    spec = engine.EngineSpec(policy="gcea", scheduler="fastest")
    pairs = [engine.init_simulation(SMALL, seed=s)[:2] for s in range(3)]
    states, bundles = engine.stack_fleet(pairs)
    _, plain = engine.run_fleet(SMALL, spec, states, bundles, 2)
    _, sharded = engine.run_fleet_sharded(SMALL, spec, states, bundles, 2)
    for field in ("loss", "cost", "accuracy"):
        np.testing.assert_array_equal(
            np.asarray(getattr(plain, field)),
            np.asarray(getattr(sharded, field)), err_msg=field)


def test_fleet_mesh_shape():
    mesh = engine.fleet_mesh()
    assert mesh.axis_names == ("fleet",)
    assert int(mesh.devices.size) == len(jax.devices())


_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import numpy as np
import jax
from repro.configs.hfl_mnist import CONFIG
from repro.core import engine
from repro import sweeps

assert len(jax.devices()) == 4
SMALL = dataclasses.replace(CONFIG, n_clients=16, n_edges=2,
                            clients_per_edge=3, min_samples=60,
                            max_samples=120, hidden=32, input_dim=64)
spec = engine.EngineSpec(policy="gcea", scheduler="fastest")

# 6 seeds on 4 devices: exercises the ragged-fleet padding path too
pairs = [engine.init_simulation(SMALL, seed=s)[:2] for s in range(6)]
states, bundles = engine.stack_fleet(pairs)
_, plain = engine.run_fleet(SMALL, spec, states, bundles, 2)
_, sharded = engine.run_fleet_sharded(SMALL, spec, states, bundles, 2)
for f in ("loss", "cost", "accuracy"):
    np.testing.assert_allclose(np.asarray(getattr(plain, f)),
                               np.asarray(getattr(sharded, f)),
                               rtol=1e-6, err_msg=f)
np.testing.assert_array_equal(np.asarray(plain.z), np.asarray(sharded.z))
print("FLEET_OK")

# sharded sweep == unsharded sweep, per cell
grid = sweeps.SweepGrid(name="shardtest",
                        scenarios=("static", "markov_dropout"),
                        policies=("gcea",), schedulers=("fastest",),
                        seeds=(0, 1), n_rounds=2)
plain = sweeps.run_sweep(SMALL, grid, write_json=False)
sharded = sweeps.run_sweep(SMALL, grid, write_json=False,
                           mesh=engine.fleet_mesh())
assert plain["cells"].keys() == sharded["cells"].keys()
for cid in plain["cells"]:
    for k in plain["cells"][cid]:
        np.testing.assert_allclose(np.asarray(plain["cells"][cid][k]),
                                   np.asarray(sharded["cells"][cid][k]),
                                   rtol=1e-6, err_msg=f"{cid}:{k}")
print("SWEEP_OK")
"""


def test_multi_device_fleet_and_sweep_parity():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "FLEET_OK" in out.stdout and "SWEEP_OK" in out.stdout
