"""Data pipeline, optimizer, and checkpoint substrate tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or its absent-shim

from repro import checkpoint
from repro.data import federated, synthetic, tokens
from repro.optim import (adam, adamw, clip_by_global_norm, cosine_decay,
                         global_norm, linear_warmup, momentum, sgd,
                         warmup_cosine)


# -- data ---------------------------------------------------------------------

def test_partitioner_conservation_iid(rng):
    fd = federated.make_federated(rng, n_clients=8, dim=16, iid=True,
                                  min_samples=20, max_samples=50,
                                  test_samples=30)
    assert fd.x.shape == (8, 50, 16)
    for c in range(8):
        n = fd.counts[c]
        assert (fd.x[c, n:] == 0).all()          # padding zeroed
        assert np.abs(fd.x[c, :n]).sum() > 0     # data present


def test_partitioner_noniid_skew(rng):
    fd = federated.make_federated(rng, n_clients=8, dim=16, iid=False,
                                  min_samples=50, max_samples=100,
                                  dirichlet_alpha=0.1, test_samples=30)
    # with α=0.1 clients should be label-skewed: few distinct labels dominate
    fracs = []
    for c in range(8):
        y = fd.y[c, :fd.counts[c]]
        _, counts = np.unique(y, return_counts=True)
        fracs.append(counts.max() / counts.sum())
    assert np.mean(fracs) > 0.35


def test_partitioner_noniid_exact_quantities(rng):
    """Regression: the Dirichlet branch's per-class floor used to under-fill
    the drawn D_n; every client must now get EXACTLY its drawn quantity."""
    expected = np.maximum(
        np.random.default_rng(7).integers(30, 81, 12), 1)
    fd = federated.make_federated(np.random.default_rng(7), n_clients=12,
                                  dim=8, iid=False, min_samples=30,
                                  max_samples=80, dirichlet_alpha=0.3,
                                  test_samples=20)
    np.testing.assert_array_equal(fd.counts, expected)
    for c in range(12):
        n = fd.counts[c]
        assert n >= 1
        assert np.abs(fd.x[c, :n]).sum() > 0
        assert (fd.x[c, n:] == 0).all()


def test_partitioner_noniid_empty_class_pool(rng):
    """Regression: a class absent from the tiny shared pool used to crash
    the Dirichlet loop with a modulo-by-zero; the deficit must instead be
    topped up from non-empty classes."""
    for seed in range(5):
        r = np.random.default_rng(seed)
        # ~9 pool samples over 10 classes guarantees empty classes
        fd = federated.make_federated(r, n_clients=3, dim=4, iid=False,
                                      min_samples=2, max_samples=4,
                                      dirichlet_alpha=0.5, test_samples=5)
        assert (fd.counts >= 1).all()
        for c in range(3):
            y = fd.y[c, :fd.counts[c]]
            assert len(y) == fd.counts[c]


def test_partitioner_min_one_sample(rng):
    """min_samples=0 must still leave every client with ≥ 1 sample."""
    fd = federated.make_federated(rng, n_clients=6, dim=4, iid=True,
                                  min_samples=0, max_samples=10,
                                  test_samples=10)
    assert (fd.counts >= 1).all()


def test_classification_learnable(rng):
    x, y = synthetic.make_classification(rng, n_samples=500, dim=32,
                                         noise=0.5)
    # nearest-template accuracy must beat chance by a wide margin
    assert x.shape == (500, 32) and y.shape == (500,)
    assert len(np.unique(y)) == 10


def test_token_batches(rng):
    bs = list(tokens.token_batches(rng, vocab=100, batch=4, seq_len=16,
                                   n_batches=3))
    assert len(bs) == 3
    for b in bs:
        assert b["tokens"].shape == (4, 16)
        np.testing.assert_array_equal(b["labels"][:, :-1], b["tokens"][:, 1:])
        assert b["tokens"].max() < 100


# -- optimizers ---------------------------------------------------------------

def _quadratic(params):
    return sum(jnp.sum(jnp.square(l)) for l in jax.tree.leaves(params))


@pytest.mark.parametrize("factory", [
    lambda: sgd(0.1), lambda: momentum(0.05), lambda: adam(0.1),
    lambda: adamw(0.1, weight_decay=0.0)])
def test_optimizer_reduces_quadratic(factory):
    opt = factory()
    params = {"w": jnp.asarray([3.0, -2.0]), "b": jnp.asarray([[1.0, 4.0]])}
    state = opt.init(params)
    step = jnp.zeros((), jnp.int32)
    start = float(_quadratic(params))
    for i in range(50):
        grads = jax.grad(_quadratic)(params)
        params, state = opt.update(grads, state, params, step)
        step = step + 1
    assert float(_quadratic(params)) < 0.05 * start


@settings(max_examples=20, deadline=None)
@given(st.floats(0.5, 5.0), st.integers(0, 100))
def test_adam_step_bounded(scale, seed):
    """Adam's per-step move is bounded by ~lr regardless of grad scale."""
    rng = np.random.default_rng(seed)
    params = {"w": jnp.asarray(rng.normal(size=4), jnp.float32)}
    opt = adam(0.01)
    state = opt.init(params)
    grads = {"w": jnp.asarray(scale * rng.normal(size=4), jnp.float32)}
    new, _ = opt.update(grads, state, params, jnp.zeros((), jnp.int32))
    move = np.abs(np.asarray(new["w"]) - np.asarray(params["w"]))
    assert (move <= 0.011).all()


def test_clip_by_global_norm():
    t = {"a": jnp.asarray([3.0, 4.0])}          # norm 5
    c = clip_by_global_norm(t, 1.0)
    assert float(global_norm(c)) == pytest.approx(1.0, rel=1e-5)
    c2 = clip_by_global_norm(t, 10.0)           # under the cap: unchanged
    np.testing.assert_allclose(np.asarray(c2["a"]), [3.0, 4.0])


def test_schedules():
    s = linear_warmup(1.0, 10)
    assert float(s(jnp.asarray(0))) == pytest.approx(0.1)
    assert float(s(jnp.asarray(9))) == pytest.approx(1.0)
    c = cosine_decay(1.0, 100, final_frac=0.1)
    assert float(c(jnp.asarray(0))) == pytest.approx(1.0)
    assert float(c(jnp.asarray(100))) == pytest.approx(0.1)
    wc = warmup_cosine(1.0, 10, 100)
    assert float(wc(jnp.asarray(5))) < 1.0
    assert float(wc(jnp.asarray(10))) == pytest.approx(1.0)


def test_adam_bf16_moments():
    opt = adam(0.01, opt_dtype="bfloat16")
    params = {"w": jnp.ones((4,), jnp.float32)}
    state = opt.init(params)
    assert state["m"]["w"].dtype == jnp.bfloat16
    grads = {"w": jnp.ones((4,), jnp.float32)}
    new, state = opt.update(grads, state, params, jnp.zeros((), jnp.int32))
    assert new["w"].dtype == jnp.float32
    assert np.isfinite(np.asarray(new["w"])).all()


# -- checkpoint ---------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path, key):
    tree = {"layer": {"w": jax.random.normal(key, (3, 4)),
                      "b": jnp.zeros((4,), jnp.bfloat16)},
            "stack": [jnp.arange(5), jnp.ones((2, 2), jnp.int32)]}
    checkpoint.save_checkpoint(str(tmp_path), 7, tree, extra={"loss": 1.5})
    out, step, extra = checkpoint.load_checkpoint(str(tmp_path), tree)
    assert step == 7 and extra["loss"] == 1.5
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        assert str(np.asarray(a).dtype) == str(np.asarray(b).dtype)
        np.testing.assert_array_equal(
            np.asarray(a, np.float32), np.asarray(b, np.float32))


def test_checkpoint_latest(tmp_path):
    tree = {"w": jnp.ones((2,))}
    for s in (1, 5, 3):
        checkpoint.save_checkpoint(str(tmp_path), s, tree)
    assert checkpoint.latest_step(str(tmp_path)) == 5
    _, step, _ = checkpoint.load_checkpoint(str(tmp_path), tree)
    assert step == 5


def test_checkpoint_missing_dir(tmp_path):
    assert checkpoint.latest_step(str(tmp_path / "nope")) is None
    with pytest.raises(FileNotFoundError):
        checkpoint.load_checkpoint(str(tmp_path / "nope"), {"w": jnp.ones(1)})
