"""Dry-run smoke in a SUBPROCESS so the 512-placeholder-device XLA flag
never leaks into this test process (assignment requirement)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import json
import jax
from repro.configs import get_config
from repro.launch.dryrun import lower_pair
from repro.launch.mesh import make_production_mesh

assert len(jax.devices()) == 512

# reduced config through the REAL production meshes (both of them)
cfg = get_config("stablelm-1.6b").reduced()
for mp in (False, True):
    rec = lower_pair("stablelm-1.6b", "train_4k", multi_pod=mp,
                     cfg_override=cfg)
    assert rec["status"] == "compiled", rec
    print(json.dumps({"mesh": rec["mesh"], "status": rec["status"]}))
"""


@pytest.mark.slow
def test_dryrun_production_meshes():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    lines = [json.loads(l) for l in out.stdout.strip().splitlines()
             if l.startswith("{")]
    meshes = {l["mesh"] for l in lines}
    assert meshes == {"16x16", "2x16x16"}


def test_main_process_sees_one_device():
    import jax
    assert len(jax.devices()) == 1
