"""§Perf optimizations stay correct: context-parallel attention equals the
unsharded computation on a real (host-device) mesh, and the fp8 KV cache
decodes finitely."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import build_model

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_config
from repro.models import build_model
from repro.launch.mesh import _make_mesh    # AxisType-compat shim

mesh = _make_mesh((2, 4), ("data", "model"))
cfg = get_config("yi-34b").reduced()          # attn_seq_shard=True inherited
assert cfg.attn_seq_shard
model = build_model(cfg)
key = jax.random.key(0)
params = model.init(key)
toks = jax.random.randint(key, (4, 32), 0, cfg.vocab_size, jnp.int32)

plain, _ = model.apply(params, toks)          # no mesh: constraint no-ops
with mesh:
    sharded = jax.jit(
        lambda p, t: model.apply(p, t)[0],
        in_shardings=(None, NamedSharding(mesh, P("data", None))),
    )(params, toks)
err = float(jnp.max(jnp.abs(plain - sharded)))
assert err < 1e-4, err
print("context-parallel parity ok", err)
"""


@pytest.mark.slow
def test_context_parallel_matches_unsharded():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "parity ok" in out.stdout


def test_seq_shard_noop_without_mesh(key):
    """attn_seq_shard archs run unchanged on a plain single device."""
    for arch in ("yi-34b", "whisper-large-v3", "llama4-maverick-400b-a17b"):
        cfg = get_config(arch)
        assert cfg.attn_seq_shard
        r = cfg.reduced()
        model = build_model(r)
        params = model.init(key)
        toks = jnp.zeros((2, 8), jnp.int32)
        extra = None
        if r.stub_frames:
            extra = jnp.zeros((2, r.stub_frames, r.d_model), r.compute_dtype)
        logits, _ = model.apply(params, toks, extra_embeddings=extra)
        assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_fp8_kv_cache_decodes(key):
    cfg = get_config("qwen3-8b").reduced().replace(
        kv_cache_dtype_str="float8_e4m3fn")
    model = build_model(cfg)
    params = model.init(key)
    cache = model.init_cache(2, 16)
    leaf = jax.tree.leaves(cache)[0]
    assert leaf.dtype == jnp.float8_e4m3fn
    tok = jnp.zeros((2, 1), jnp.int32)
    for i in range(4):
        logits, cache = model.decode_step(params, tok, cache,
                                          jnp.asarray(i, jnp.int32))
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
        assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_fp8_cache_close_to_bf16(key):
    """fp8 cache is a controlled approximation: logits stay close."""
    base = get_config("qwen3-8b").reduced()
    m1 = build_model(base)
    m2 = build_model(base.replace(kv_cache_dtype_str="float8_e4m3fn"))
    params = m1.init(key)
    toks = jax.random.randint(key, (2, 12), 0, base.vocab_size, jnp.int32)
    c1, c2 = m1.init_cache(2, 12), m2.init_cache(2, 12)
    for i in range(12):
        l1, c1 = m1.decode_step(params, toks[:, i:i+1], c1,
                                jnp.asarray(i, jnp.int32))
        l2, c2 = m2.decode_step(params, toks[:, i:i+1], c2,
                                jnp.asarray(i, jnp.int32))
    d = float(jnp.mean(jnp.abs(l1 - l2)))
    scale = float(jnp.mean(jnp.abs(l1))) + 1e-9
    assert d / scale < 0.15, (d, scale)