"""Fuzzy client-scoring unit + property tests (paper §III, Table I, Fig. 4)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # hypothesis or its absent-shim

from repro.core import fuzzy


def test_membership_peaks():
    # at v=0: fully 'weak'; at 50: fully 'medium'; at 100: fully 'strong'
    for v, idx in [(0.0, 0), (50.0, 1), (100.0, 2)]:
        m = np.asarray(fuzzy.input_memberships(jnp.asarray(v)))
        assert m[idx] == pytest.approx(1.0)
        assert m.sum() == pytest.approx(1.0)  # triangles overlap-partition


def test_membership_halfway():
    m = np.asarray(fuzzy.input_memberships(jnp.asarray(25.0)))
    assert m[0] == pytest.approx(0.5) and m[1] == pytest.approx(0.5)


def test_normalize_eq21():
    v = jnp.asarray([0.0, 5.0, 10.0])
    nv = np.asarray(fuzzy.normalize(v, 10.0))
    np.testing.assert_allclose(nv, [0.0, 50.0, 100.0])


def test_rule_table_corners():
    """Pure corners fire exactly one rule — spot-check Table I."""
    cases = [  # (cq, dq, ms) -> output set
        ((100.0, 100.0, 100.0), fuzzy.EXCELLENT),   # rule 9
        ((100.0, 0.0, 0.0), fuzzy.FAIR),            # rule 1
        ((0.0, 0.0, 0.0), fuzzy.POOR),              # rule 19
        ((0.0, 100.0, 100.0), fuzzy.GOOD),          # rule 27
        ((50.0, 50.0, 50.0), fuzzy.AVG),            # rule 14
    ]
    for (cq, dq, ms), want in cases:
        s = np.asarray(fuzzy.rule_strengths(jnp.asarray(cq), jnp.asarray(dq),
                                            jnp.asarray(ms)))
        assert s.argmax() == want and s.max() == pytest.approx(1.0)


def test_paper_worked_example():
    """Paper Fig. 7: input (0.2, 0.5, 0.8) normalised = (20, 50, 80) —
    weak/average/stale dominates -> rule 24 -> 'average' output."""
    s = np.asarray(fuzzy.rule_strengths(jnp.asarray(20.0), jnp.asarray(50.0),
                                        jnp.asarray(80.0)))
    assert s.argmax() == fuzzy.AVG
    out = float(fuzzy.fuzzy_score(jnp.asarray(20.0), jnp.asarray(50.0),
                                  jnp.asarray(80.0)))
    # COG of an 'average'-dominated aggregate sits near the centre (50)
    assert 35.0 <= out <= 65.0


def test_extremes_order():
    best = float(fuzzy.fuzzy_score(jnp.asarray(100.0), jnp.asarray(100.0),
                                   jnp.asarray(100.0)))
    worst = float(fuzzy.fuzzy_score(jnp.asarray(0.0), jnp.asarray(0.0),
                                    jnp.asarray(0.0)))
    assert best > 80.0 and worst < 20.0


@settings(max_examples=60, deadline=None)
@given(st.floats(0, 100), st.floats(0, 100), st.floats(0, 100))
def test_output_bounded(cq, dq, ms):
    out = float(fuzzy.fuzzy_score(jnp.asarray(cq), jnp.asarray(dq),
                                  jnp.asarray(ms)))
    assert 0.0 <= out <= 100.0


@settings(max_examples=30, deadline=None)
@given(st.floats(0, 100), st.floats(0, 100),
       st.floats(0, 90), st.floats(1, 10))
def test_monotone_in_staleness(cq, dq, ms, delta):
    """Table I is monotone non-decreasing in every criterion.  Mamdani
    clip + COG introduces sub-unit ripples at membership crossovers (a
    known fuzzy-control artifact, not a rule-table bug) — bound them."""
    lo = float(fuzzy.fuzzy_score(jnp.asarray(cq), jnp.asarray(dq),
                                 jnp.asarray(ms)))
    hi = float(fuzzy.fuzzy_score(jnp.asarray(cq), jnp.asarray(dq),
                                 jnp.asarray(min(ms + delta, 100.0))))
    assert hi >= lo - 1.5      # observed ripple ≈0.51 near crossovers


def test_monotone_on_membership_grid():
    """Exact monotonicity holds on the membership-aligned grid where at
    most the rule weights, not the clip geometry, change."""
    grid = [0.0, 50.0, 100.0]
    for cq in grid:
        for dq in grid:
            vals = [float(fuzzy.fuzzy_score(jnp.asarray(cq), jnp.asarray(dq),
                                            jnp.asarray(ms))) for ms in grid]
            assert vals == sorted(vals)


def test_vectorised_matches_scalar():
    cq = jnp.asarray([10.0, 60.0, 90.0])
    dq = jnp.asarray([40.0, 70.0, 20.0])
    ms = jnp.asarray([80.0, 10.0, 55.0])
    vec = np.asarray(fuzzy.fuzzy_scores(cq, dq, ms))
    for i in range(3):
        s = float(fuzzy.fuzzy_score(cq[i], dq[i], ms[i]))
        assert vec[i] == pytest.approx(s, abs=1e-5)


def test_score_clients_end_to_end():
    g = jnp.asarray([1e-9, 5e-9, 1e-8])
    d = jnp.asarray([200.0, 600.0, 1200.0])
    s = jnp.asarray([1.0, 3.0, 9.0])
    out = np.asarray(fuzzy.score_clients(g, d, s, gain_max=1e-8,
                                         data_max=1200.0, staleness_max=9.0))
    assert out.shape == (3,)
    assert (out >= 0).all() and (out <= 100).all()
    assert out[2] == out.max()  # best on all three criteria
