"""CLI launcher + example smoke tests (subprocess, tiny configs)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.join(os.path.dirname(__file__), "..")
SRC = os.path.join(ROOT, "src")


def _run(args, timeout=420):
    env = dict(os.environ, PYTHONPATH=SRC)
    return subprocess.run([sys.executable] + args, env=env, cwd=ROOT,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_train_cli(tmp_path):
    out = _run(["-m", "repro.launch.train", "--arch", "xlstm-125m",
                "--steps", "3", "--batch", "2", "--seq", "32",
                "--ckpt-dir", str(tmp_path), "--ckpt-every", "3"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "step    2" in out.stdout
    assert any(f.startswith("step_") for f in os.listdir(tmp_path))


@pytest.mark.slow
def test_serve_cli():
    out = _run(["-m", "repro.launch.serve", "--arch", "paligemma-3b",
                "--tokens", "4", "--batch", "2"])
    assert out.returncode == 0, out.stderr[-2000:]
    assert "generated 4 tokens" in out.stdout


@pytest.mark.slow
def test_quickstart_example():
    out = _run([os.path.join(ROOT, "examples", "quickstart.py")])
    assert out.returncode == 0, out.stderr[-2000:]
    assert out.stdout.strip().endswith("OK")


def test_benchmark_modules_import():
    sys.path.insert(0, ROOT)
    import benchmarks.run  # noqa: F401
    from benchmarks import (bench_kernels, bench_roofline, fig_avg_ms,
                            fig_cost_vs_dn, fig_cost_vs_nm, fig_ddpg_cost,
                            fig_hfl_convergence)  # noqa: F401


def test_dryrun_help():
    out = _run(["-m", "repro.launch.dryrun", "--help"], timeout=120)
    assert out.returncode == 0
    assert "--multi-pod" in out.stdout
