"""Cost-model closed-form tests (paper Eqs. 3-5, 9-19, 23a)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.hfl_mnist import CONFIG as HFL
from repro.core import cost, noma


def test_tau_formulas():
    """Eq. 3 and Eq. 12 with θ=ξ=0.5, μ=δ=2."""
    import math
    assert HFL.tau1 == max(1, round(2.0 * math.log(2.0)))
    assert HFL.tau2 == max(1, round(2.0 * math.log(2.0) / 0.5))


def test_local_compute_eq_4_5():
    f = jnp.asarray([2e9])
    d = jnp.asarray([500.0])
    t, e = cost.local_compute(HFL, f, d)
    tau1 = HFL.tau1
    assert float(t[0]) == pytest.approx(tau1 * 1e7 * 500 / 2e9)
    assert float(e[0]) == pytest.approx(tau1 * 0.5e-28 * (2e9) ** 2 * 1e7 * 500)


def test_uplink_eq_9_10_single_client():
    """One client, one edge: no interference -> Shannon SNR rate."""
    p = jnp.asarray([0.1])
    gains = jnp.asarray([[1e-9]])
    assoc = jnp.asarray([[1.0]])
    t_com, e_com, rates = cost.uplink(HFL, p, gains, assoc)
    noise = noma.noise_power_w(HFL.noise_dbm_per_hz, HFL.bandwidth_hz)
    want_rate = HFL.bandwidth_hz * np.log2(1 + 0.1 * 1e-9 / noise)
    assert float(rates[0]) == pytest.approx(want_rate, rel=1e-6)
    assert float(t_com[0]) == pytest.approx(HFL.model_size_bits / want_rate,
                                            rel=1e-6)
    assert float(e_com[0]) == pytest.approx(0.1 * float(t_com[0]), rel=1e-6)


def test_unassociated_clients_cost_nothing():
    p = jnp.asarray([0.1, 0.1])
    gains = jnp.asarray([[1e-9], [1e-9]])
    assoc = jnp.asarray([[1.0], [0.0]])
    t_com, e_com, _ = cost.uplink(HFL, p, gains, assoc)
    assert float(t_com[1]) == 0.0 and float(e_com[1]) == 0.0


def test_round_cost_max_and_sum_semantics():
    """Eq. 13 (max over clients), Eq. 14 (sum), Eqs. 18-19 (masked max/sum)."""
    n, m = 4, 2
    p = jnp.full((n,), 0.05)
    f = jnp.full((n,), 5e9)
    gains = jnp.full((n, m), 1e-9)
    assoc = jnp.asarray([[1., 0.], [1., 0.], [0., 1.], [0., 1.]])
    d = jnp.asarray([400., 800., 400., 800.])
    z = jnp.asarray([1.0, 1.0])
    rc = cost.round_cost(HFL, power_w=p, f_hz=f, gains=gains, assoc=assoc,
                         z=z, n_samples=d)
    # per-edge time is τ₂ × slowest client + cloud upload (Eq. 13);
    # SIC decode order makes "slowest" a NOMA matter, so take the max.
    t_cloud = HFL.edge_model_size_bits / HFL.edge_rate_bps
    slowest = float(jnp.max(rc.client_time_s[:2]))  # edge 0's clients
    assert float(rc.per_edge_time_s[0]) == pytest.approx(
        HFL.tau2 * slowest + t_cloud, rel=1e-5)
    assert float(rc.total_time_s) == pytest.approx(
        float(jnp.max(rc.per_edge_time_s)), rel=1e-6)
    assert float(rc.total_energy_j) == pytest.approx(
        float(jnp.sum(rc.per_edge_energy_j)), rel=1e-6)
    want = HFL.lambda_t * rc.total_time_s + HFL.lambda_e * rc.total_energy_j
    assert float(rc.cost) == pytest.approx(float(want), rel=1e-6)


def test_semi_sync_mask_drops_edges():
    n, m = 2, 2
    p = jnp.full((n,), 0.05)
    f = jnp.full((n,), 5e9)
    gains = jnp.full((n, m), 1e-9)
    assoc = jnp.asarray([[1., 0.], [0., 1.]])
    d = jnp.full((n,), 500.0)
    rc_all = cost.round_cost(HFL, power_w=p, f_hz=f, gains=gains, assoc=assoc,
                             z=jnp.asarray([1., 1.]), n_samples=d)
    rc_one = cost.round_cost(HFL, power_w=p, f_hz=f, gains=gains, assoc=assoc,
                             z=jnp.asarray([1., 0.]), n_samples=d)
    assert float(rc_one.total_energy_j) < float(rc_all.total_energy_j)


def test_oma_slower_than_noma_per_round():
    """With K clients sharing the band, OMA rates are lower (1/K bandwidth)
    at moderate SNR -> longer upload time (the paper's Fig. 8-11 driver)."""
    n, m = 4, 1
    p = jnp.full((n,), 0.05)
    f = jnp.full((n,), 5e9)
    gains = jnp.asarray([[4e-9], [3e-9], [2e-9], [1e-9]])
    assoc = jnp.ones((n, m))
    d = jnp.full((n,), 500.0)
    z = jnp.ones((m,))
    rc_noma = cost.round_cost(HFL, power_w=p, f_hz=f, gains=gains,
                              assoc=assoc, z=z, n_samples=d,
                              noma_enabled=True)
    rc_oma = cost.round_cost(HFL, power_w=p, f_hz=f, gains=gains,
                             assoc=assoc, z=z, n_samples=d,
                             noma_enabled=False)
    assert float(jnp.sum(rc_noma.rates_bps)) > float(jnp.sum(rc_oma.rates_bps))


def test_cost_differentiable_in_p_f():
    """DDPG relies on a smooth cost surface."""
    import jax
    n, m = 3, 1
    gains = jnp.asarray([[1e-9], [2e-9], [3e-9]])
    assoc = jnp.ones((n, m))
    d = jnp.full((n,), 500.0)
    z = jnp.ones((m,))

    def total(pf):
        p, f = pf[:n], pf[n:]
        rc = cost.round_cost(HFL, power_w=p, f_hz=f, gains=gains,
                             assoc=assoc, z=z, n_samples=d)
        return rc.cost

    g = jax.grad(total)(jnp.concatenate([jnp.full((n,), 0.05),
                                         jnp.full((n,), 5e9)]))
    assert np.isfinite(np.asarray(g)).all()
    assert float(jnp.abs(g).max()) > 0
