"""Client-axis sharding (DESIGN.md §9.3): ``shard_clients`` /
``run_scanned_client_sharded`` must reproduce the unsharded round engine
with the N axis genuinely split across devices, and ``pad_clients`` must
add only INERT clients (never associated, never billed).

Unlike the fleet axis (tests/test_fleet_sharding.py), the client axis has
cross-device reductions (aggregation, per-edge cost, fuzzy normalisation),
so multi-device float parity is pinned at tight tolerances rather than
bit-exactness; integer observables (association counts, z) stay exact.

The multi-device cases run in a SUBPROCESS: the placeholder-device
``XLA_FLAGS`` must be set before jax imports and must not leak into this
test process (same pattern as test_fleet_sharding.py).
"""
import dataclasses
import os
import subprocess
import sys

import numpy as np

from repro.configs.hfl_mnist import CONFIG
from repro.core import engine

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SMALL = dataclasses.replace(CONFIG, n_clients=16, n_edges=2,
                            clients_per_edge=3, min_samples=60,
                            max_samples=120, hidden=32, input_dim=64)


def test_single_device_sharded_matches_plain():
    """On the 1-device default mesh the sharded driver is a pass-through
    (N divisible by 1, no padding, placement-only device_put)."""
    spec = engine.EngineSpec(policy="fcea", scheduler="fastest",
                             candidates_k=2)
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    _, plain = engine.run_scanned(SMALL, spec, state, bundle, 2)
    _, sharded = engine.run_scanned_client_sharded(SMALL, spec, state,
                                                   bundle, 2)
    for field in ("loss", "cost", "accuracy", "n_associated"):
        np.testing.assert_array_equal(
            np.asarray(getattr(plain, field)),
            np.asarray(getattr(sharded, field)), err_msg=field)


def test_client_mesh_shape():
    import jax
    mesh = engine.client_mesh()
    assert mesh.axis_names == ("clients",)
    assert int(mesh.devices.size) == len(jax.devices())


def test_pad_clients_inert():
    """Padded clients can never associate and the real clients' admitted
    set stays feasible; a multiple that divides N is a no-op."""
    state, bundle, _ = engine.init_simulation(SMALL, seed=0)
    same = engine.pad_clients(SMALL, state, bundle, 4)
    assert same[0].n_clients == SMALL.n_clients          # 16 % 4 == 0
    cfg2, st2, bu2 = engine.pad_clients(SMALL, state, bundle, 5)
    assert cfg2.n_clients == 20
    spec = engine.EngineSpec(policy="fcea", scheduler="fastest",
                             candidates_k=2)
    assoc = np.asarray(engine.associate_snapshot(cfg2, spec, st2, bu2))
    assert assoc[SMALL.n_clients:].sum() == 0            # pads never admitted
    assert (assoc.sum(axis=1) <= 1).all()
    assert (assoc.sum(axis=0) <= SMALL.clients_per_edge).all()
    # the padded world still runs end to end (dense and candidate paths)
    for s in (spec, dataclasses.replace(spec, candidates_k=None)):
        _, ms = engine.run_scanned(cfg2, s, st2, bu2, 2)
        assert np.isfinite(np.asarray(ms.cost)).all()


_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import dataclasses
import numpy as np
import jax
from repro.configs.hfl_mnist import CONFIG
from repro.core import engine

assert len(jax.devices()) == 4
SMALL = dataclasses.replace(CONFIG, n_clients=16, n_edges=2,
                            clients_per_edge=3, min_samples=60,
                            max_samples=120, hidden=32, input_dim=64)

def check(cfg, spec, state, bundle, label):
    _, plain = engine.run_scanned(cfg, spec, state, bundle, 2)
    _, sharded = engine.run_scanned_client_sharded(cfg, spec, state,
                                                   bundle, 2)
    for f in ("loss", "cost", "accuracy", "total_energy_j"):
        np.testing.assert_allclose(np.asarray(getattr(plain, f)),
                                   np.asarray(getattr(sharded, f)),
                                   rtol=2e-5, atol=1e-7,
                                   err_msg=f"{label}:{f}")
    for f in ("n_associated", "n_available", "z"):
        np.testing.assert_array_equal(np.asarray(getattr(plain, f)),
                                      np.asarray(getattr(sharded, f)),
                                      err_msg=f"{label}:{f}")

# 16 clients over 4 devices, candidate and dense paths, static + dynamic
state, bundle, _ = engine.init_simulation(SMALL, seed=0)
for spec in (engine.EngineSpec(policy="fcea", scheduler="fastest",
                               candidates_k=2),
             engine.EngineSpec(policy="gcea", scheduler="fastest")):
    check(SMALL, spec, state, bundle, f"even:{spec.policy}")
print("EVEN_OK")

dyn = engine.EngineSpec(policy="fcea", scheduler="fastest",
                        scenario="dynamic", candidates_k=2)
st, bu, _ = engine.init_simulation(SMALL, seed=1, scenario="full_dynamic")
check(SMALL, dyn, st, bu, "dynamic")
print("DYN_OK")

# ragged N: 18 clients pad to 20 over 4 devices; the padded world's
# sharded and unsharded runs must agree, and the pads stay inert
RAG = dataclasses.replace(SMALL, n_clients=18)
state, bundle, _ = engine.init_simulation(RAG, seed=0)
spec = engine.EngineSpec(policy="fcea", scheduler="fastest",
                         candidates_k=2)
cfgp, stp, bup = engine.pad_clients(RAG, state, bundle, 4)
assert cfgp.n_clients == 20
check(cfgp, spec, stp, bup, "ragged")
assoc = np.asarray(engine.associate_snapshot(cfgp, spec, stp, bup))
assert assoc[RAG.n_clients:].sum() == 0
print("RAGGED_OK")
"""


def test_multi_device_client_sharding_parity():
    env = dict(os.environ, PYTHONPATH=SRC)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run([sys.executable, "-c", _SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stderr[-3000:]
    for tag in ("EVEN_OK", "DYN_OK", "RAGGED_OK"):
        assert tag in out.stdout
