"""Sweep-grid runner tests (DESIGN.md §6.3).

The acceptance shape: a run_fleet sweep over ≥3 scenarios × 2 association
policies completes in a single vmapped compile PER static-spec group (all
dynamic scenarios share one group per policy) and writes per-cell JSON
trajectories under the results directory.
"""
import dataclasses
import json
import os

import numpy as np
import pytest

from repro import scenarios, sweeps
from repro.configs.hfl_mnist import CONFIG
from repro.core import engine

SMALL = dataclasses.replace(CONFIG, n_clients=16, n_edges=2,
                            clients_per_edge=3, min_samples=60,
                            max_samples=120, hidden=32, input_dim=64)


def _grid(**over):
    base = dict(name="t",
                scenarios=("random_waypoint", "markov_dropout",
                           "hetero_devices"),
                policies=("fcea", "gcea"), seeds=(0,), n_rounds=2)
    base.update(over)
    return sweeps.SweepGrid(**base)


def test_expand_grid_cross_product():
    grid = _grid(seeds=(0, 1))
    cells = sweeps.expand_grid(grid)
    assert len(cells) == 3 * 2 * 2
    assert len({c.cell_id for c in cells}) == len(cells)


def test_dynamic_scenarios_share_one_compile_per_policy(tmp_path):
    """3 dynamic scenarios × 2 policies -> exactly 2 vmapped compiles."""
    grid = _grid()
    before = engine.run_fleet._cache_size()
    summary = sweeps.run_sweep(SMALL, grid, out_dir=str(tmp_path))
    after = engine.run_fleet._cache_size()
    assert summary["n_cells"] == 6
    assert summary["n_compiles"] == 2              # one per policy
    # the jit cache grew by at most one entry per policy group — the three
    # scenarios of a group really do share a single vmapped program
    assert after - before <= 2
    for g in summary["groups"]:
        assert g["n_cells"] == 3                   # scenarios ride the vmap
        assert g["spec"]["scenario"] == "dynamic"


def test_sweep_writes_per_cell_json(tmp_path):
    grid = _grid(scenarios=("static", "full_dynamic"), policies=("gcea",),
                 schedulers=("fastest",))
    summary = sweeps.run_sweep(SMALL, grid, out_dir=str(tmp_path))
    sweep_dir = os.path.join(str(tmp_path), "sweep_t")
    files = sorted(os.listdir(sweep_dir))
    assert "summary.json" in files
    cell_files = [f for f in files if f != "summary.json"]
    assert len(cell_files) == summary["n_cells"] == 2
    for f in cell_files:
        with open(os.path.join(sweep_dir, f)) as fh:
            payload = json.load(fh)
        assert payload["n_rounds"] == 2
        for field in ("accuracy", "loss", "cost", "n_available", "z"):
            assert len(payload["metrics"][field]) == 2
        assert np.isfinite(payload["metrics"]["cost"]).all()


def test_sweep_cell_matches_direct_run(tmp_path):
    """A sweep cell's trajectory equals a standalone run_scanned with the
    same scenario + seed (the grid machinery adds nothing but batching)."""
    grid = _grid(scenarios=("mobile_flaky",), policies=("fcea",),
                 n_rounds=3)
    summary = sweeps.run_sweep(SMALL, grid, out_dir=str(tmp_path),
                               write_json=False)
    (cid, rows), = summary["cells"].items()
    spec = engine.EngineSpec(policy="fcea", scenario="dynamic")
    state, bundle, _ = engine.init_simulation(SMALL, seed=0,
                                              scenario="mobile_flaky")
    _, ms = engine.run_scanned(SMALL, spec, state, bundle, 3)
    np.testing.assert_allclose(rows["cost"], np.asarray(ms.cost), rtol=1e-5)
    np.testing.assert_array_equal(rows["n_available"],
                                  np.asarray(ms.n_available))


def test_custom_scenario_spec_parameters_survive(tmp_path):
    """Regression: a ScenarioSpec passed into the grid must run with ITS
    parameters, not a preset rebuilt from its kind label."""
    blackout = scenarios.ScenarioSpec(kind="markov_dropout", p_drop=1.0,
                                      p_return=0.0)
    grid = _grid(scenarios=(("blackout", blackout),), policies=("gcea",),
                 schedulers=("fastest",), n_rounds=2)
    summary = sweeps.run_sweep(SMALL, grid, out_dir=str(tmp_path),
                               write_json=False)
    (cid, rows), = summary["cells"].items()
    assert cid.startswith("blackout__")
    # p_drop=1, p_return=0: everyone is gone from round 1 onward — the
    # default markov_dropout preset would keep most clients available
    assert rows["n_available"] == [0, 0]


def test_ddpg_group_trains_its_own_actor(tmp_path):
    """The per-cell DDPG path: with no pre-trained actor, every ddpg cell
    trains its own actor on its own world (one vmapped
    ``train_allocator_fleet`` program per group) and the stacked actors
    ride the fleet vmap — no silent fallback to the midpoint allocator,
    no error."""
    grid = _grid(scenarios=("full_dynamic",), policies=("gcea",),
                 schedulers=("fastest",), allocators=("ddpg", "mid"),
                 seeds=(0, 1), ddpg_episodes=1, ddpg_steps=4,
                 ddpg_warmup=2, ddpg_hidden=16)
    summary = sweeps.run_sweep(SMALL, grid, out_dir=str(tmp_path))
    assert summary["n_cells"] == 4
    trained = [g for g in summary["groups"]
               if g["spec"]["allocator"] == "ddpg"]
    assert len(trained) == 1
    assert trained[0]["ddpg_trained"] is True
    assert trained[0]["ddpg_train_s"] > 0
    for cid, row in summary["final"].items():
        assert np.isfinite(row["mean_cost"])
    # both allocators really ran: the ddpg and mid trajectories differ
    costs = {cid: summary["cells"][cid]["cost"]
             for cid in summary["cells"]}
    ddpg_cells = [v for c, v in sorted(costs.items()) if "__ddpg__" in c]
    mid_cells = [v for c, v in sorted(costs.items()) if "__mid__" in c]
    assert len(ddpg_cells) == len(mid_cells) == 2
    assert ddpg_cells[0] != mid_cells[0]


def test_ddpg_cells_train_on_their_own_world(tmp_path):
    """Honest columns: every ddpg cell's actor is trained on that cell's
    own scenario × seed — two seeds must yield DIFFERENT ddpg
    trajectories than a single shared actor would explain, and the group
    timing records one actor per cell."""
    grid = _grid(scenarios=("full_dynamic",), policies=("gcea",),
                 schedulers=("fastest",), allocators=("ddpg",),
                 seeds=(0, 1), ddpg_episodes=1, ddpg_steps=4,
                 ddpg_warmup=2, ddpg_hidden=16)
    summary = sweeps.run_sweep(SMALL, grid, write_json=False)
    (g,) = summary["groups"]
    assert g["ddpg_actors"] == 2
    costs = [summary["cells"][c]["cost"] for c in sorted(summary["cells"])]
    assert costs[0] != costs[1]


def test_ddpg_static_and_dynamic_groups_each_train(tmp_path):
    """Mixed observation shapes are fine WITHOUT a shared actor: the
    static group trains a (2N,) actor, the dynamic group a (3N,) one."""
    grid = _grid(scenarios=("static", "full_dynamic"), policies=("gcea",),
                 schedulers=("fastest",), allocators=("ddpg",),
                 ddpg_episodes=1, ddpg_steps=4, ddpg_warmup=2,
                 ddpg_hidden=16)
    summary = sweeps.run_sweep(SMALL, grid, write_json=False)
    assert summary["n_cells"] == 2
    assert all(g["ddpg_trained"] for g in summary["groups"])
    assert len(summary["groups"]) == 2      # one compile+actor per kind


def test_ddpg_cells_reject_mixed_observation_shapes_with_shared_actor():
    """One PRE-TRAINED actor cannot serve both static (2N,) and dynamic
    (3N,) observations — that path must still refuse."""
    grid = _grid(scenarios=("static", "full_dynamic"), allocators=("ddpg",))
    with pytest.raises(ValueError, match="observation"):
        sweeps.run_sweep(SMALL, grid, write_json=False,
                         actor_params={"w": np.zeros((1,))})


def test_duplicate_scenario_labels_rejected():
    spec_a = scenarios.ScenarioSpec(kind="markov_dropout", p_drop=0.1)
    spec_b = scenarios.ScenarioSpec(kind="markov_dropout", p_drop=0.9)
    with pytest.raises(ValueError, match="ambiguous"):
        sweeps.expand_grid(_grid(scenarios=(spec_a, spec_b)))


def test_render_tables_sweep_mode(tmp_path):
    """results/render_tables.py renders a run_sweep summary.json into the
    Figs. 8-12 cost/accuracy markdown tables."""
    import importlib.util
    grid = _grid(scenarios=("static", "markov_dropout"), policies=("gcea",),
                 schedulers=("fastest",), seeds=(0, 1))
    sweeps.run_sweep(SMALL, grid, out_dir=str(tmp_path))
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "render_tables.py")
    spec = importlib.util.spec_from_file_location("render_tables", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    report = mod.sweep_report(os.path.join(str(tmp_path), "sweep_t"))
    assert "Final accuracy" in report
    assert "Mean round cost" in report
    assert "gcea/mid/fastest/noma" in report
    # one row per scenario, mean ± std over the two seeds
    assert "| static |" in report and "| markov_dropout |" in report
    assert "±" in report


def test_sweep_candidates_k_matches_dense():
    """A sweep on the (N, K ≥ degree) frontier bills identical metrics —
    flipping ``candidates_k`` changes speed, not results (DESIGN.md §9)."""
    import dataclasses as dc
    grid = _grid(scenarios=("static", "markov_dropout"), policies=("fcea",),
                 schedulers=("fastest",), seeds=(0,))
    # the compact SIC is the sorted formulation — pin the dense cells to
    # it so the bills compare bit-for-bit at this (tiny) N too
    grid = dc.replace(grid, sic_impl="sorted")
    dense = sweeps.run_sweep(SMALL, grid, write_json=False)
    kgrid = dc.replace(grid, candidates_k=SMALL.n_edges)
    cand = sweeps.run_sweep(SMALL, kgrid, write_json=False)
    assert dense["cells"].keys() == cand["cells"].keys()
    for cid in dense["cells"]:
        for metric in ("accuracy", "cost", "n_associated"):
            np.testing.assert_array_equal(
                np.asarray(dense["cells"][cid][metric]),
                np.asarray(cand["cells"][cid][metric]),
                err_msg=f"{cid}:{metric}")


def test_render_tables_plot_mode(tmp_path):
    """``plot`` mode writes one PNG per metric from the per-cell
    trajectory files next to summary.json (the Figs. 8-12 figure view)."""
    import importlib.util
    pytest.importorskip("matplotlib")
    grid = _grid(scenarios=("static", "markov_dropout"), policies=("gcea",),
                 schedulers=("fastest",), seeds=(0, 1))
    sweeps.run_sweep(SMALL, grid, out_dir=str(tmp_path))
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "render_tables.py")
    spec = importlib.util.spec_from_file_location("render_tables", path)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    out = mod.plot_report(os.path.join(str(tmp_path), "sweep_t"),
                          str(tmp_path / "figs"))
    assert len(out) == 2                      # accuracy + cost panels
    for p in out:
        assert os.path.exists(p) and os.path.getsize(p) > 0
        assert p.endswith(".png")


def test_same_seed_same_data_across_scenarios():
    """Scenario draws happen after topology+data: the federation is
    identical under every scenario, so sweep columns are comparable."""
    _, b_static, _ = engine.init_simulation(SMALL, seed=3)
    _, b_dyn, _ = engine.init_simulation(SMALL, seed=3,
                                         scenario="full_dynamic")
    np.testing.assert_array_equal(np.asarray(b_static.counts),
                                  np.asarray(b_dyn.counts))
    np.testing.assert_array_equal(np.asarray(b_static.x),
                                  np.asarray(b_dyn.x))
    np.testing.assert_array_equal(np.asarray(b_static.dist),
                                  np.asarray(b_dyn.dist))
