"""Sharding-rule and roofline-parser unit tests (no big meshes here;
multi-device lowering is exercised by the dry-run subprocess test)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.configs import INPUT_SHAPES, get_config, input_specs
from repro.launch import roofline as rl
from repro.models import build_model
from repro.sharding import batch_axes, cache_spec, spec_for_param, tree_specs


class FakeMesh:
    """Duck-typed mesh: only .axis_names and .shape are consulted."""
    def __init__(self, shape_map):
        self.axis_names = tuple(shape_map)
        self.shape = dict(shape_map)


MESH = FakeMesh({"data": 16, "model": 16})
POD = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_attention_rules():
    # (d, H, dh) with H=32 divisible -> heads shard over model, d over data
    assert spec_for_param("wq", (4096, 32, 128), MESH) \
        == P("data", "model", None)
    # GQA kv=8 not divisible by 16 -> REPLICATE over model (dh-sharding
    # would all-reduce full score matrices, §Perf it. 2); FSDP moves to dh
    # to keep the contraction dim d whole (§Perf it. 4)
    assert spec_for_param("wk", (4096, 8, 128), MESH) \
        == P(None, None, "data")
    # MQA kv=1, dh=256
    assert spec_for_param("wk", (2048, 1, 256), MESH) \
        == P(None, None, "data")
    assert spec_for_param("wo", (32, 128, 4096), MESH) \
        == P("model", None, "data")
    # indivisible heads (yi-34b 56H): Q replicated too, FSDP on dh
    assert spec_for_param("wq", (7168, 56, 128), MESH) \
        == P(None, None, "data")


def test_stacked_leading_axis_untouched():
    # stacked-scan leaf: (reps, d, H, dh) — rules count from the END
    assert spec_for_param("wq", (12, 4096, 32, 128), MESH) \
        == P(None, "data", "model", None)


def test_mlp_and_moe_rules():
    assert spec_for_param("w_in", (4096, 12288), MESH) == P("data", "model")
    assert spec_for_param("w_out", (12288, 4096), MESH) == P("model", "data")
    # MoE 128 experts: expert dim shards (expert parallelism)
    assert spec_for_param("w_in", (128, 5120, 8192), MESH) \
        == P("model", "data", None)
    # grok 8 experts: replicated experts, d_ff shards (expert-tensor hybrid)
    assert spec_for_param("w_in", (8, 6144, 32768), MESH) \
        == P(None, "data", "model")


def test_embedding_fallback():
    # whisper vocab 51866 % 16 != 0 -> falls back to sharding d_model
    assert spec_for_param("embedding", (51866, 1280), MESH) \
        == P(None, "model")
    # no FSDP on embeddings (data-sharded d materialises full logits,
    # §Perf it. 4)
    assert spec_for_param("embedding", (151936, 4096), MESH) \
        == P("model", None)


def test_vectors_replicated():
    assert spec_for_param("scale", (4096,), MESH) == P(None)
    assert spec_for_param("b_gates", (3072,), MESH) == P(None)


def test_batch_axes():
    assert batch_axes(MESH, 256) == ("data",)
    assert batch_axes(MESH, 1) is None
    assert batch_axes(POD, 256) == ("pod", "data")
    assert batch_axes(POD, 2) == ("pod",)


def test_cache_spec():
    # (reps, B, S, kv, dh): batch over data, SEQUENCE over model
    # (flash-decoding-style; dh-sharding all-gathers the cache every
    # layer, §Perf it. 3)
    s = cache_spec((36, 128, 32768, 8, 128), MESH, ("data",))
    assert s == P(None, "data", "model", None, None)
    # batch=1 -> replicated batch, seq still sharded
    s = cache_spec((36, 1, 524288, 8, 128), MESH, None)
    assert s == P(None, None, "model", None, None)
    # recurrent state (reps, B, dr): channel shards
    s = cache_spec((12, 32, 4096), MESH, ("data",))
    assert s == P(None, "data", "model")


def test_tree_specs_cover_every_leaf(key):
    cfg = get_config("qwen3-8b").reduced()
    model = build_model(cfg)
    shapes = jax.eval_shape(model.init, key)
    specs = tree_specs(shapes, MESH)
    n_leaves = len(jax.tree.leaves(shapes))
    n_specs = len(jax.tree.leaves(specs, is_leaf=lambda x: isinstance(x, P)))
    assert n_leaves == n_specs


# -- roofline parser -----------------------------------------------------------

HLO = """
  %ag = f32[256,128]{1,0} all-gather(%p), channel_id=1, replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %ar = bf16[64,64]{1,0} all-reduce(%x), channel_id=2, replica_groups=[32,8]<=[256] use_global_device_ids=true
  %rs = f32[32]{0} reduce-scatter(%y), channel_id=3, replica_groups={{0,1}}, dimensions={0}
  %cp = f32[16,16]{1,0} collective-permute(%z), channel_id=4, source_target_pairs={{0,1}}
  %dot = f32[8,8]{1,0} dot(%a, %b), lhs_contracting_dims={1}
"""


def test_collective_bytes_parser():
    out = rl.collective_bytes(HLO)
    assert out["all-gather"] == int(256 * 128 * 4 * 3 / 4)
    assert out["all-reduce"] == int(2 * 64 * 64 * 2 * 7 / 8)
    assert out["reduce-scatter"] == 32 * 4 * 1
    assert out["collective-permute"] == 16 * 16 * 4
    assert out["all-to-all"] == 0


def test_model_flops_estimate():
    cfg = get_config("qwen3-8b")
    train = rl.model_flops_estimate(cfg, INPUT_SHAPES["train_4k"])
    dec = rl.model_flops_estimate(cfg, INPUT_SHAPES["decode_32k"])
    n = cfg.active_param_count()
    assert train == pytest.approx(6.0 * n * 256 * 4096)
    assert dec == pytest.approx(2.0 * n * 128)


def test_moe_active_params_smaller():
    cfg = get_config("llama4-maverick-400b-a17b")
    assert cfg.active_param_count() < 0.25 * cfg.param_count()


def test_format_table_runs():
    r = rl.Roofline("a", "s", "m", 256, 1e12, 1e12, 1e9, {}, 0.0, 1e15,
                    0.1, 0.2, 0.05)
    assert r.dominant == "memory"
    assert "memory" in rl.format_table([r])


def test_input_specs_all_pairs_build():
    """ShapeDtypeStruct specs build for every applicable (arch × shape)."""
    from repro.configs import ASSIGNED
    from repro.configs.base import shape_applicable
    for arch in ASSIGNED:
        cfg = get_config(arch)
        for sname, shape in INPUT_SHAPES.items():
            ok, _ = shape_applicable(cfg, shape)
            if not ok:
                continue
            specs = input_specs(cfg, shape)
            assert "tokens" in specs or "token" in specs
            for leaf in jax.tree.leaves(specs):
                assert isinstance(leaf, jax.ShapeDtypeStruct)
