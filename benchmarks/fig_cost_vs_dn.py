"""Paper Fig. 15: total cost vs local model size d_n (1-4 Mbit)."""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import SMALL, emit
from repro.core.hfl import HFLSimulation


def main() -> None:
    for mbit in (1, 2, 3, 4):
        cfg = dataclasses.replace(SMALL, model_size_bits=mbit * 1e6)
        sim = HFLSimulation(cfg, seed=4, iid=True)
        t0 = time.time()
        m = sim.run_round()
        emit(f"cost_vs_dn_{mbit}mbit", (time.time() - t0) * 1e6,
             {"cost": round(m.cost, 3), "time_s": round(m.total_time_s, 3)})


if __name__ == "__main__":
    main()
