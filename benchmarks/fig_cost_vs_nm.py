"""Paper Fig. 14: total cost vs clients-per-edge N_m (straggler effect)."""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import SMALL, emit
from repro.core.hfl import HFLSimulation


def main() -> None:
    prev = None
    for nm in (2, 3, 4, 5):
        cfg = dataclasses.replace(SMALL, clients_per_edge=nm)
        sim = HFLSimulation(cfg, seed=3, iid=True)
        t0 = time.time()
        m = sim.run_round()
        emit(f"cost_vs_nm_{nm}", (time.time() - t0) * 1e6,
             {"cost": round(m.cost, 3), "time_s": round(m.total_time_s, 3),
              "energy_j": round(m.total_energy_j, 3)})
        prev = m.cost


if __name__ == "__main__":
    main()
