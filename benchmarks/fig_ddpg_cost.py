"""Paper Fig. 13: total cost vs DDPG training episode, DDPG-RA vs
RRA / FPA / FCA (all under FCEA association)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import SMALL, emit
from repro.core import ddpg, env
from repro.core.hfl import HFLSimulation


def _mean_cost(e, allocator, agent, key, steps=20):
    state, obs = e.reset(key)
    costs = []
    for t in range(steps):
        key, k = jax.random.split(key)
        if allocator == "ddpg":
            act = ddpg.actor_apply(agent.actor, obs)
        elif allocator == "rra":
            act = env.rra_action(k, e.n_clients)
        elif allocator == "fpa":   # fixed power, grid-optimised frequency
            act = env.fpa_best_action(e, state.gains)
        else:  # fca: fixed frequency, grid-optimised power
            act = env.fca_best_action(e, state.gains)
        state, obs, reward, rc = e.step(state, act)
        costs.append(float(rc.cost))
    return float(np.mean(costs))


def main(episodes: int = 15) -> None:
    sim = HFLSimulation(SMALL, seed=2, iid=True, allocator="ddpg")
    t0 = time.time()
    hist = sim.train_ddpg(episodes=episodes, steps_per_episode=30,
                          warmup=64, hidden=64)
    train_us = (time.time() - t0) * 1e6 / episodes
    emit("ddpg_training", train_us,
         {"first_ep_reward": round(hist["episode_reward"][0], 3),
          "last_ep_reward": round(hist["episode_reward"][-1], 3),
          "improved": hist["episode_reward"][-1]
          >= hist["episode_reward"][0]})

    assoc = jnp.asarray(sim._associate(), jnp.float32)
    e = env.NomaHflEnv(SMALL, assoc, jnp.ones((SMALL.n_edges,)),
                       jnp.asarray(sim.topo["dist"]),
                       jnp.asarray(sim.data.counts, jnp.float32))
    key = jax.random.key(7)
    costs = {}
    for allocator in ("ddpg", "rra", "fpa", "fca"):
        costs[allocator] = _mean_cost(e, allocator, sim.agent, key)
        emit(f"cost_{allocator}", 0.0, {"mean_cost": round(costs[allocator], 3)})
    gain = {k: round(100 * (1 - costs["ddpg"] / v), 1)
            for k, v in costs.items() if k != "ddpg"}
    emit("ddpg_gain_pct", 0.0, gain)


if __name__ == "__main__":
    main()
