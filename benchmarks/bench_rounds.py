"""Rounds/sec for the HFL round drivers, as a scaling curve up to
(4096, 32) clients × edges:

* ``eager``   — a faithful replica of the pre-engine ``run_round``: per-edge
  fuzzy scoring through host numpy, numpy association, TWO ``round_cost``
  evaluations (pairwise SIC), a per-iteration-dispatched python τ₂ loop and
  per-round host syncs.  This is the baseline the round-engine refactor
  retired; it is only run up to (1024, 16) — beyond that its O(N²M)
  pairwise SIC materialises GB-scale temporaries.
* ``stepped`` — one jitted ``round_step`` dispatch per round (the wrapper's
  ``run``): same math, one program, still a host sync per round.
* ``scanned`` — ``engine.run_scanned``: the experiment as ONE ``lax.scan``.
* ``fleet``   — ``engine.run_fleet``: vmap of the scanned program over seeds.

Each size also records ``serial_rps`` — the scanned driver with the legacy
serial association resolver + pairwise SIC (``EngineSpec(resolver="serial",
sic_impl="pairwise")``) — the A/B for the PR-4 hot-path work — and a
per-stage breakdown (associate / allocate / schedule / train / eval, each
jitted separately, median-of-k like the driver timings) so a regression is
attributable to a stage.  The 1024×16 rung additionally records the
telemetry-enabled scanned driver (``EngineSpec(telemetry=True)``) and its
overhead percentage — the acceptance number for the in-scan trace.

At the scaling-tail sizes a K-SWEEP column compares the dense (N, M)
round against the (N, K) candidate frontier (``EngineSpec.candidates_k``,
DESIGN.md §9) for K ∈ {4, 8}, per-stage breakdowns included — the A/B for
the PR-5 candidate-set refactor.  8192×32 exists only because of that
refactor: the dense resolver still runs there but materially slower (its
sweeps drag (N, M) tensors and an (M, N) rank scatter through every
while_loop step).

Every size also records a TRAIN_IMPL A/B (``train_impl_ab``): the
batched-GEMM cohort step vs the per-client vmap reference, train stage
alone — the PR-10 fused-training acceptance column — and the 1024×16
rung adds a WARM_SWEEPS block (cold vs warm-started deferred-acceptance
sweep medians under ``random_waypoint`` — an honest negative at this
scale, see ``warm_sweeps_ab`` and DESIGN.md §13.4).

The model/data are kept small so the numbers measure the ROUND pipeline,
not the MLP.  Writes BENCH_rounds.json at the repo root so the perf
trajectory is tracked across PRs.

  PYTHONPATH=src python -m benchmarks.bench_rounds [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, median_ms, median_rps, provenance
from repro import scenarios
from repro.configs.hfl_mnist import CONFIG
from repro.core import (aggregation, association, cost, engine, fuzzy, noma,
                        pdd)
from repro.core.hfl import HFLSimulation
from repro.faults import FaultSpec
from repro.models.mlp import MLPClassifier

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_rounds.json")

SIZES = ((64, 4), (256, 8), (1024, 16))
# scanned/fleet-only scaling tail: the eager baseline cannot run here
SCALE_SIZES = ((2048, 32), (4096, 32))
# candidate-frontier K-sweep sizes (dense column = the regular entry);
# 8192×32 runs candidate-only drivers next to a dense A/B that the PR-4
# resolver handles materially slower
K_SWEEP = {(4096, 32): (4, 8), (8192, 32): (4, 8)}
# gcea + fastest is the fully host-callback-free acceptance path.
SPEC = engine.EngineSpec(policy="gcea", scheduler="fastest")
# the legacy hot path (PR-1..3): serial while-loop resolver, pairwise SIC
SPEC_SERIAL = dataclasses.replace(SPEC, resolver="serial",
                                  sic_impl="pairwise")
# the semi-async buffered engine (DESIGN.md §11): same spec, micro-steps
SPEC_BUFFERED = dataclasses.replace(SPEC, engine_mode="buffered")
# the fault layer on the buffered engine (DESIGN.md §12): edge churn +
# SINR-tied uplink loss + retry/backoff + quarantine, all in-scan; its
# delta vs buffered_rps prices the chaos epilogue
SPEC_FAULTS = dataclasses.replace(
    SPEC_BUFFERED,
    faults=FaultSpec(edge_p_kill=0.1, edge_p_respawn=0.5,
                     uplink_p_loss=0.1, uplink_loss_slope=0.2))
# async A/B scenarios: churny worlds where the sync barrier pays its
# straggler tail every round (the buffered engine's home turf)
AB_SCENARIOS = ("flash_crowd", "markov_dropout")
AB_SIZE = (1024, 16)


def _cfg(n: int, m: int):
    return dataclasses.replace(CONFIG, n_clients=n, n_edges=m,
                               clients_per_edge=4, min_samples=60,
                               max_samples=120, hidden=16, input_dim=32,
                               local_batch=16)


class LegacyEagerSim:
    """The seed implementation's ``run_round``, preserved for the baseline:
    host numpy association + double cost eval + eager τ₂ python loop."""

    def __init__(self, cfg, state: engine.RoundState,
                 bundle: engine.RoundBundle, topo, rng):
        self.cfg = cfg
        self.bundle = bundle
        self.topo = topo
        self.rng = rng
        self.key = state.key
        self.gains = state.gains
        self.staleness = state.staleness
        self.global_params = state.global_params
        self.client_params = state.client_params
        self.model = MLPClassifier(cfg.input_dim, cfg.hidden, cfg.n_classes)
        self._local_fit = jax.jit(engine._local_sgd(
            self.model, cfg.lr, cfg.tau1, cfg.local_batch))

    def _scores(self) -> np.ndarray:
        """The seed's per-edge host loop (computed for EVERY policy)."""
        gains = np.asarray(self.gains)
        n, m = gains.shape
        db = 10.0 * np.log10(np.maximum(gains, 1e-30))
        lo, hi = db.min(), db.max()
        cq = np.asarray(fuzzy.normalize(jnp.asarray(db - lo),
                                        float(max(hi - lo, 1e-9))))
        dq = np.asarray(fuzzy.normalize(
            jnp.asarray(np.asarray(self.bundle.counts)),
            float(self.cfg.max_samples)))
        ms = np.asarray(fuzzy.normalize(
            self.staleness.astype(jnp.float32),
            float(max(int(jnp.max(self.staleness)), 1))))
        scores = np.zeros((n, m), np.float32)
        for j in range(m):
            scores[:, j] = np.asarray(fuzzy.fuzzy_scores(
                jnp.asarray(np.ascontiguousarray(cq[:, j])),
                jnp.asarray(dq), jnp.asarray(ms)))
        return scores

    def run_round(self) -> float:
        cfg, bundle = self.cfg, self.bundle
        self.key, k = jax.random.split(self.key)
        self.gains = noma.evolve_gains(
            k, self.gains, bundle.dist,
            path_loss_exponent=cfg.path_loss_exponent, rho=SPEC.fading_rho)
        assoc_np = association.associate(
            SPEC.policy, scores=self._scores(),
            gains_to_edges=np.asarray(self.gains), dist=self.topo["dist"],
            quota=cfg.clients_per_edge,
            coverage_radius_m=engine.coverage_radius(cfg), rng=self.rng)
        assoc = jnp.asarray(assoc_np, jnp.float32)
        n = cfg.n_clients
        p = jnp.full((n,), 0.5 * (cfg.p_min_w + cfg.p_max_w))
        f = jnp.full((n,), 0.5 * (cfg.f_min_hz + cfg.f_max_hz))
        quota = max(1, int(round(cfg.semi_sync_fraction * cfg.n_edges)))
        rc_all = cost.round_cost(cfg, power_w=p, f_hz=f, gains=self.gains,
                                 assoc=assoc, z=jnp.ones((cfg.n_edges,)),
                                 n_samples=bundle.counts,
                                 sic_impl="pairwise")
        z = pdd.semi_sync_fastest(rc_all.per_edge_time_s, quota)
        rc = cost.round_cost(cfg, power_w=p, f_hz=f, gains=self.gains,
                             assoc=assoc, z=z, n_samples=bundle.counts,
                             sic_impl="pairwise")
        selected = jnp.sum(assoc, axis=1) > 0
        edge_params = aggregation.replicate(self.global_params, cfg.n_edges)
        client_params = aggregation.broadcast_to_clients(
            None, assoc, edge_params, self.client_params)
        for _ in range(cfg.tau2):
            self.key, k = jax.random.split(self.key)
            ks = jax.random.split(k, n)
            trained = self._local_fit(client_params, bundle.x, bundle.y,
                                      bundle.counts, ks)
            client_params = jax.tree.map(
                lambda new, old: jnp.where(
                    selected.reshape((-1,) + (1,) * (new.ndim - 1)),
                    new, old), trained, client_params)
            edge_params = aggregation.edge_aggregate(client_params, assoc,
                                                     bundle.counts)
            client_params = aggregation.broadcast_to_clients(
                None, assoc, edge_params, client_params)
        edge_data = jnp.sum(assoc * bundle.counts[:, None], axis=0)
        z_eff = z * (edge_data > 0).astype(z.dtype)
        if float(jnp.sum(z_eff * edge_data)) > 0:
            self.global_params = aggregation.cloud_aggregate(
                edge_params, z_eff, edge_data)
        self.client_params = client_params
        acc = float(self.model.accuracy(self.global_params, bundle.test_x,
                                        bundle.test_y))
        return acc


def stage_breakdown(cfg, state, bundle, spec=SPEC) -> Dict[str, float]:
    """Per-stage ms for one round's pieces, each jitted separately on the
    init state — the attribution view behind the scanned rounds/sec.

    With ``spec.candidates_k`` set, the associate stage includes the
    per-round candidate build and the schedule stage bills through the
    compact ``assigned`` path, mirroring ``round_step`` exactly."""
    model = MLPClassifier(cfg.input_dim, cfg.hidden, cfg.n_classes)
    _, _, _, k_assoc, k_alloc, k_train = engine.round_keys(spec, state.key)
    compact = spec.candidates_k is not None

    def _assoc(g, s):
        cand = engine._build_candidates(cfg, spec, bundle.dist, None)
        out = engine._associate(cfg, spec, k_assoc, g, bundle.dist,
                                bundle.counts, s, None, cand)
        if compact:     # assigned (N,) + the one-hot view round_step builds
            from repro.core import candidates
            return out, candidates.assigned_one_hot(
                out, cfg.n_edges).astype(jnp.float32)
        return None, out.astype(jnp.float32)

    f_assoc = jax.jit(_assoc)
    assigned, assoc = f_assoc(state.gains, state.staleness)
    f_alloc = jax.jit(lambda a, g: engine._allocate(
        cfg, spec, k_alloc, a, g, bundle.counts, None, None, bundle.dist))
    p, f = f_alloc(assoc, state.gains)

    def _sched(p_, f_, g_, a_, asg_):
        rc_all = cost.round_cost(
            cfg, power_w=p_, f_hz=f_, gains=g_, assoc=a_,
            z=jnp.ones((cfg.n_edges,)), n_samples=bundle.counts,
            noma_enabled=spec.noma_enabled, sic_impl=spec.sic_impl,
            sic_max_per_edge=engine.quota_for(cfg, spec), assigned=asg_)
        z = engine._schedule(cfg, spec, rc_all)
        return cost.apply_schedule(cfg, rc_all, z)

    f_sched = jax.jit(_sched)
    z1 = jnp.ones((cfg.n_edges,))
    f_train = jax.jit(lambda st, a: engine._train(cfg, spec, model, k_train,
                                                  st, bundle, a, z1))
    f_eval = jax.jit(lambda gp: (model.accuracy(gp, bundle.test_x,
                                                bundle.test_y),
                                 model.loss(gp, (bundle.test_x,
                                                 bundle.test_y))))
    return {
        "associate_ms": round(median_ms(f_assoc, state.gains,
                                        state.staleness), 3),
        "allocate_ms": round(median_ms(f_alloc, assoc, state.gains), 3),
        "schedule_ms": round(median_ms(f_sched, p, f, state.gains, assoc,
                                       assigned), 3),
        "train_ms": round(median_ms(f_train, state, assoc), 3),
        "eval_ms": round(median_ms(f_eval, state.global_params), 3),
    }


def train_stage_ms(cfg, state, bundle, spec=SPEC) -> float:
    """Median ms of the jitted train stage alone — the hot stage once
    association went candidate-compact (DESIGN.md §13), and the number
    ``check_regress`` gates per-stage so association noise can't hide a
    training regression in the aggregate rps."""
    model = MLPClassifier(cfg.input_dim, cfg.hidden, cfg.n_classes)
    _, _, _, k_assoc, _, k_train = engine.round_keys(spec, state.key)
    assoc = jax.jit(lambda g, s: engine._associate(
        cfg, spec, k_assoc, g, bundle.dist, bundle.counts, s, None,
        None).astype(jnp.float32))(state.gains, state.staleness)
    z1 = jnp.ones((cfg.n_edges,))
    f_train = jax.jit(lambda st, a: engine._train(cfg, spec, model, k_train,
                                                  st, bundle, a, z1))
    return median_ms(f_train, state, assoc)


def warm_sweeps_ab(n: int, m: int, *, rounds: int) -> Dict[str, float]:
    """Cold vs warm-started deferred-acceptance sweep counts under
    ``random_waypoint`` mobility (DESIGN.md §13.4), read off the in-scan
    ``RoundTrace.assoc_sweeps`` leaf.  Round 0 has no seed either way, so
    the medians are over rounds 1..R-1.

    NB this records an honest NEGATIVE result at bench scale: the market
    is oversubscribed enough that fading + motion leave a blocking pair
    in yesterday's matching almost every round, so the exactness guard
    bills seeded-fixpoint + cold-rerun and ``median_reduction`` comes
    out negative (see DESIGN.md §13.4 for the analysis; the warm win is
    pinned at the 16×2 test scale in tests/test_train_impl.py)."""
    cfg = _cfg(n, m)
    sspec = scenarios.preset("random_waypoint")
    state, bundle, _ = engine.init_simulation(cfg, seed=0, scenario=sspec)
    out: Dict[str, float] = {"rounds": rounds}
    for name, warm in (("cold", False), ("warm", True)):
        sp = dataclasses.replace(SPEC, scenario=sspec.engine_kind(),
                                 telemetry=True, warm_start=warm)
        _, (_, tr) = jax.block_until_ready(
            engine.run_scanned(cfg, sp, state, bundle, rounds))
        sw = np.asarray(tr.assoc_sweeps)[1:]
        out[f"{name}_median_sweeps"] = float(np.median(sw))
        out[f"{name}_mean_sweeps"] = round(float(sw.mean()), 2)
    out["median_reduction"] = round(
        out["cold_median_sweeps"] - out["warm_median_sweeps"], 1)
    return out


def bench_size(n: int, m: int, *, eager_rounds: int, scan_rounds: int,
               fleet_seeds: int, with_eager: bool = True,
               with_fleet: bool = True) -> Dict[str, float]:
    cfg = _cfg(n, m)
    state, bundle, aux = engine.init_simulation(cfg, seed=0)
    out: Dict[str, float] = {}

    if with_eager:
        # -- legacy eager (the retired execution model) ----------------------
        legacy = LegacyEagerSim(cfg, state, bundle, aux["topo"], aux["rng"])
        legacy.run_round()                            # compile
        t0 = time.perf_counter()
        for _ in range(eager_rounds):
            legacy.run_round()
        out["eager_rps"] = round(eager_rounds / (time.perf_counter() - t0),
                                 3)

        # -- stepped: one jitted round_step per round ------------------------
        sim = HFLSimulation(cfg, seed=0, policy=SPEC.policy,
                            scheduler=SPEC.scheduler)
        sim.run_round()                               # compile
        t0 = time.perf_counter()
        sim.run(eager_rounds)
        out["stepped_rps"] = round(eager_rounds / (time.perf_counter() - t0),
                                   3)

    # -- scanned: the whole experiment is one XLA program --------------------
    scanned_rps = median_rps(
        lambda: engine.run_scanned(cfg, SPEC, state, bundle, scan_rounds),
        scan_rounds)
    out["scanned_rps"] = round(scanned_rps, 3)

    # -- buffered: the semi-async micro-step engine, same scanned driver ----
    #    (micro-steps/sec — a compile-structure gate like scanned_rps, not a
    #    round-for-round comparison; the virtual A/B lives in async_ab)
    out["buffered_rps"] = round(median_rps(
        lambda: engine.run_scanned(cfg, SPEC_BUFFERED, state, bundle,
                                   scan_rounds), scan_rounds), 3)

    # -- faulted: the chaos layer riding the buffered micro-step driver ------
    out["faults_rps"] = round(median_rps(
        lambda: engine.run_scanned(cfg, SPEC_FAULTS, state, bundle,
                                   scan_rounds), scan_rounds), 3)

    # -- telemetry-enabled scanned driver: the in-scan RoundTrace rides the
    #    scan outputs; its overhead at 1024×16 is the acceptance number
    if (n, m) == (1024, 16):
        spec_t = dataclasses.replace(SPEC, telemetry=True)
        t_rps = median_rps(
            lambda: engine.run_scanned(cfg, spec_t, state, bundle,
                                       scan_rounds), scan_rounds)
        out["telemetry_rps"] = round(t_rps, 3)
        out["telemetry_overhead_pct"] = round(
            (scanned_rps / t_rps - 1.0) * 100.0, 2)

    # -- A/B: the legacy serial resolver + pairwise SIC, same driver ---------
    if with_eager:     # the pairwise SIC shares eager's memory wall
        out["serial_rps"] = round(median_rps(
            lambda: engine.run_scanned(cfg, SPEC_SERIAL, state, bundle,
                                       scan_rounds), scan_rounds), 3)

    # -- fleet: vmap the scanned program over independent seeds --------------
    if with_fleet:
        pairs = [engine.init_simulation(cfg, seed=s)[:2]
                 for s in range(fleet_seeds)]
        states, bundles = engine.stack_fleet(pairs)
        fleet_rps = median_rps(
            lambda: engine.run_fleet(cfg, SPEC, states, bundles,
                                     scan_rounds),
            fleet_seeds * scan_rounds)
        out["fleet_rps"] = round(fleet_rps, 3)

    if with_eager:
        out["scan_speedup"] = round(scanned_rps / out["eager_rps"], 2)
        if with_fleet:
            out["fleet_speedup"] = round(out["fleet_rps"]
                                         / out["eager_rps"], 2)
    out.update(eager_rounds=eager_rounds if with_eager else 0,
               scan_rounds=scan_rounds,
               fleet_seeds=fleet_seeds if with_fleet else 0,
               stages=stage_breakdown(cfg, state, bundle))

    # -- train_impl A/B (DESIGN.md §13): the batched-GEMM cohort step vs
    #    the per-client vmap reference, train stage alone
    out["train_impl_ab"] = {
        impl: round(train_stage_ms(
            cfg, state, bundle,
            dataclasses.replace(SPEC, train_impl=impl)), 3)
        for impl in ("batched", "vmap")}

    # -- candidate-frontier K-sweep vs the dense column above ----------------
    for k in K_SWEEP.get((n, m), ()):
        spec_k = dataclasses.replace(SPEC, candidates_k=k)
        out.setdefault("candidates", {})[f"k{k}"] = {
            "scanned_rps": round(median_rps(
                lambda: engine.run_scanned(cfg, spec_k, state, bundle,
                                           scan_rounds), scan_rounds), 3),
            "stages": stage_breakdown(cfg, state, bundle, spec_k),
        }
    return out


def async_ab(n: int, m: int, *, scenario: str, sync_rounds: int,
             micro_steps: int) -> Dict[str, float]:
    """Sync-vs-buffered A/B under a churny scenario (DESIGN.md §11).

    The acceptance number is VIRTUAL round throughput — aggregations per
    simulated second, the quantity the semi-async refactor exists to move:

    * sync     — global rounds / Σ per-round barrier time (Eq. 18), i.e.
      every round pays max-over-selected-clients + the cloud hop;
    * buffered — cloud merges / final virtual clock: a merge fires when
      ``buffer_fill`` staleness-weighted updates land, so its period
      tracks the cohort's MEDIAN duration, not its straggler tail.

    Wall-clock micro-steps/sec ride along for the compile-cost view.
    """
    cfg = _cfg(n, m)
    sspec = scenarios.preset(scenario)
    state, bundle, _ = engine.init_simulation(cfg, seed=0, scenario=sspec)
    spec_s = dataclasses.replace(SPEC, scenario=sspec.engine_kind())
    spec_b = dataclasses.replace(spec_s, engine_mode="buffered")

    _, ms = jax.block_until_ready(
        engine.run_scanned(cfg, spec_s, state, bundle, sync_rounds))
    sync_virtual_s = float(np.sum(np.asarray(ms.total_time_s)))
    sync_vrps = sync_rounds / max(sync_virtual_s, 1e-9)
    sync_wall = median_rps(
        lambda: engine.run_scanned(cfg, spec_s, state, bundle, sync_rounds),
        sync_rounds)

    fs, _ = jax.block_until_ready(
        engine.run_scanned(cfg, spec_b, state, bundle, micro_steps))
    merges = int(fs.buffer.version)
    virtual_s = float(fs.buffer.clock_s)
    buf_vrps = merges / max(virtual_s, 1e-9)
    buf_wall = median_rps(
        lambda: engine.run_scanned(cfg, spec_b, state, bundle, micro_steps),
        micro_steps)
    return {
        "sync_rounds": sync_rounds,
        "micro_steps": micro_steps,
        "sync_virtual_rps": round(sync_vrps, 4),
        "buffered_merges": merges,
        "buffered_virtual_s": round(virtual_s, 3),
        "buffered_virtual_rps": round(buf_vrps, 4),
        "virtual_speedup": round(buf_vrps / max(sync_vrps, 1e-9), 3),
        "sync_wall_rps": round(sync_wall, 3),
        "buffered_wall_rps": round(buf_wall, 3),
    }


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer rounds/seeds (CI-speed)")
    args = ap.parse_args(argv)

    results: Dict[str, Dict[str, float]] = {}
    sizes = [(n, m, True) for n, m in SIZES]
    sizes += [(n, m, False) for n, m in SCALE_SIZES]
    if not args.quick:
        # the 8192×32 rung exists on the candidate frontier; the dense
        # column rides along as the (much slower) A/B
        sizes += [(8192, 32, False)]
    for n, m, with_eager in sizes:
        big = n >= 1024
        r = bench_size(
            n, m,
            eager_rounds=3 if (args.quick or big) else 6,
            scan_rounds=3 if n >= 8192 else (5 if (args.quick or big)
                                             else 15),
            fleet_seeds=2 if (args.quick or big) else 4,
            with_eager=with_eager,
            with_fleet=n < 8192)
        results[f"{n}x{m}"] = r
        emit(f"rounds_n{n}_m{m}", 1e6 / r["scanned_rps"],
             {k: v for k, v in r.items()
              if k not in ("stages", "candidates")})

    # -- semi-async A/B at the acceptance size (DESIGN.md §11) --------------
    n, m = AB_SIZE
    ab: Dict[str, Dict[str, float]] = {}
    for scen in AB_SCENARIOS:
        ab[scen] = async_ab(n, m, scenario=scen,
                            sync_rounds=4 if args.quick else 8,
                            micro_steps=24 if args.quick else 64)
        emit(f"async_ab_{scen}_n{n}_m{m}",
             1e6 / max(ab[scen]["buffered_virtual_rps"], 1e-9), ab[scen])
    results["async_ab"] = {"size": f"{n}x{m}", **ab}

    # -- warm-started association A/B (DESIGN.md §13.4) ---------------------
    ws = warm_sweeps_ab(n, m, rounds=8 if args.quick else 16)
    emit(f"warm_sweeps_n{n}_m{m}",
         ws["warm_median_sweeps"] * 1e3, ws)
    results["warm_sweeps"] = {"size": f"{n}x{m}", **ws}

    with open(OUT, "w") as fh:
        json.dump({"spec": dataclasses.asdict(SPEC),
                   "provenance": provenance(),
                   "timing_stat": "median_of_k",
                   "results": results},
                  fh, indent=2)
    print(f"wrote {os.path.normpath(OUT)}")


if __name__ == "__main__":
    main()
