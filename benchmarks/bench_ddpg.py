"""Scanned vs eager DDPG trainer (DESIGN.md §7).

The tentpole question of PR 3: what does folding the whole of paper
Algorithm 2 (env rollout + replay store + actor/critic update) into ONE
``lax.scan``-of-scans program buy over the legacy per-step Python loop?

* trains the allocator twice — ``ddpg.train_allocator`` (one compiled XLA
  program) and ``ddpg.train_allocator_eager`` (the per-step oracle) — on
  the SAME (cfg, spec, state, bundle, key), under the ``full_dynamic``
  scenario so the actor sees the (3N,) scenario-sliced observation;
* asserts the two histories agree (the parity the tests pin, re-checked
  here at benchmark scale);
* writes BENCH_ddpg.json at the repo root so the perf trajectory is
  tracked across PRs.

  PYTHONPATH=src python -m benchmarks.bench_ddpg [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time

import jax
import numpy as np

from benchmarks.common import emit, provenance
from repro.configs.hfl_mnist import CONFIG
from repro.core import ddpg, engine

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_ddpg.json")


def _setup(n_clients: int, n_edges: int):
    cfg = dataclasses.replace(CONFIG, n_clients=n_clients, n_edges=n_edges,
                              clients_per_edge=4, min_samples=60,
                              max_samples=120, hidden=16, input_dim=32)
    spec = engine.EngineSpec(policy="gcea", scheduler="fastest",
                             scenario="dynamic")
    state, bundle, _ = engine.init_simulation(cfg, seed=0,
                                              scenario="full_dynamic")
    return cfg, spec, state, bundle


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="tiny shapes/episodes (CI smoke)")
    args = ap.parse_args(argv)

    n, m = (16, 2) if args.quick else (64, 4)
    episodes = 2 if args.quick else 10
    steps = 8 if args.quick else 40
    hidden = 16 if args.quick else 64
    warmup = 4 if args.quick else 64

    cfg, spec, state, bundle = _setup(n, m)
    dcfg = ddpg.allocator_config(cfg, spec, hidden=hidden, buffer_size=1024)
    key = jax.random.key(0)
    kw = dict(episodes=episodes, steps_per_episode=steps, warmup=warmup)

    # scanned: first call compiles, second measures steady-state
    t0 = time.perf_counter()
    agent_s, hist_s = ddpg.train_allocator(cfg, spec, state, bundle, dcfg,
                                           key, **kw)
    jax.block_until_ready(agent_s.actor)
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    agent_s, hist_s = ddpg.train_allocator(cfg, spec, state, bundle, dcfg,
                                           key, **kw)
    jax.block_until_ready(agent_s.actor)
    scanned_s = time.perf_counter() - t0

    # warm the eager path's jitted pieces (train_step etc.) so both
    # timers measure steady-state work, not one-off compiles
    ddpg.train_allocator_eager(cfg, spec, state, bundle, dcfg, key,
                               episodes=1, steps_per_episode=2, warmup=1)
    t0 = time.perf_counter()
    agent_e, hist_e = ddpg.train_allocator_eager(cfg, spec, state, bundle,
                                                 dcfg, key, **kw)
    jax.block_until_ready(agent_e.actor)
    eager_s = time.perf_counter() - t0

    # the speedup only counts if both trainers walked the same trajectory
    np.testing.assert_allclose(np.asarray(hist_s["episode_reward"]),
                               np.asarray(hist_e["episode_reward"]),
                               rtol=1e-4, atol=1e-5)

    total_steps = episodes * steps
    record = {
        "size": [n, m],
        "episodes": episodes,
        "steps_per_episode": steps,
        "state_dim": dcfg.state_dim,
        "eager_s": round(eager_s, 4),
        "scanned_s": round(scanned_s, 4),
        "scanned_compile_s": round(compile_s, 4),
        "speedup": round(eager_s / max(scanned_s, 1e-9), 2),
        "scanned_steps_per_s": round(total_steps / max(scanned_s, 1e-9), 1),
        "eager_steps_per_s": round(total_steps / max(eager_s, 1e-9), 1),
        "parity_max_abs_diff": float(np.max(np.abs(
            np.asarray(hist_s["episode_reward"])
            - np.asarray(hist_e["episode_reward"])))),
        "last_ep_reward": round(float(
            np.asarray(hist_s["episode_reward"])[-1]), 4),
    }
    emit(f"ddpg_trainer_n{n}_m{m}", 1e6 * scanned_s / total_steps, record)

    record["provenance"] = provenance()
    with open(OUT, "w") as fh:
        json.dump(record, fh, indent=2)
    print(f"wrote {os.path.normpath(OUT)}")


if __name__ == "__main__":
    main()
