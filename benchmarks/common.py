"""Shared benchmark plumbing: CSV emission, timing statistics, provenance
stamping and the paper's simulation configs."""
from __future__ import annotations

import dataclasses
import datetime
import os
import platform
import subprocess
import time
from typing import Callable, Dict, List

from repro.configs.hfl_mnist import CONFIG

# A budget-friendly variant of the paper's 64-client setup for CI-speed runs;
# pass full=True for the paper-faithful topology.  mu/delta raised so τ₁=3,
# τ₂=6 give the classifier a real training signal per global round.
# 12/48 = 25% participation per round, the paper's 16/64 scarcity ratio.
SMALL = dataclasses.replace(CONFIG, n_clients=48, n_edges=4,
                            clients_per_edge=3, min_samples=100,
                            max_samples=400, hidden=64, input_dim=196,
                            mu_const=4.0, delta_const=2.0)


def emit(name: str, us_per_call: float, derived: Dict) -> str:
    kv = ";".join(f"{k}={v}" for k, v in derived.items())
    line = f"{name},{us_per_call:.1f},{kv}"
    print(line, flush=True)
    return line


def timed(fn: Callable, *args, repeat: int = 1) -> float:
    t0 = time.time()
    for _ in range(repeat):
        fn(*args)
    return (time.time() - t0) / repeat * 1e6


def median_rps(fn: Callable, rounds: int, repeats: int = 3,
               warm: bool = True) -> float:
    """Median-of-k rounds/sec of a jax driver call.

    Single-shot driver timings are scheduler-noise limited on this host
    (BENCH_sweeps.json once recorded a *negative* dynamic-scenario
    overhead from exactly that); the median over k runs is what every
    BENCH_*.json records.  ``warm`` runs the callable once first so the
    compile never lands in a timed sample.
    """
    import jax
    if warm:
        jax.block_until_ready(fn())
    samples = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        samples.append(rounds / (time.perf_counter() - t0))
    samples.sort()
    return samples[len(samples) // 2]


def median_ms(fn: Callable, *args, repeats: int = 5) -> float:
    """Median-of-k wall time of a compiled callable, in ms (warm first).

    THE stage/driver timing statistic for every BENCH_*.json — stage
    breakdowns used to record best-of-k while driver timings recorded
    median-of-k, which made stage sums incomparable to driver totals.
    """
    import jax
    jax.block_until_ready(fn(*args))                  # compile + warm
    samples = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append(time.perf_counter() - t0)
    samples.sort()
    return samples[len(samples) // 2] * 1e3


def provenance() -> Dict[str, object]:
    """Recording-host identity stamped into every BENCH_*.json so the
    perf trajectory stays interpretable across machines: git sha, jax
    version, backend, device count, platform, ISO timestamp."""
    import jax
    try:
        sha = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=os.path.dirname(os.path.abspath(__file__))).stdout.strip()
    except (OSError, subprocess.SubprocessError):
        sha = ""
    return {
        "git_sha": sha or None,
        "jax_version": jax.__version__,
        "backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "platform": platform.platform(),
        "python": platform.python_version(),
        "timestamp": datetime.datetime.now(
            datetime.timezone.utc).isoformat(timespec="seconds"),
    }
