"""Shared benchmark plumbing: CSV emission + the paper's simulation configs."""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Dict, List

from repro.configs.hfl_mnist import CONFIG

# A budget-friendly variant of the paper's 64-client setup for CI-speed runs;
# pass full=True for the paper-faithful topology.  mu/delta raised so τ₁=3,
# τ₂=6 give the classifier a real training signal per global round.
# 12/48 = 25% participation per round, the paper's 16/64 scarcity ratio.
SMALL = dataclasses.replace(CONFIG, n_clients=48, n_edges=4,
                            clients_per_edge=3, min_samples=100,
                            max_samples=400, hidden=64, input_dim=196,
                            mu_const=4.0, delta_const=2.0)


def emit(name: str, us_per_call: float, derived: Dict) -> str:
    kv = ";".join(f"{k}={v}" for k, v in derived.items())
    line = f"{name},{us_per_call:.1f},{kv}"
    print(line, flush=True)
    return line


def timed(fn: Callable, *args, repeat: int = 1) -> float:
    t0 = time.time()
    for _ in range(repeat):
        fn(*args)
    return (time.time() - t0) / repeat * 1e6


def median_rps(fn: Callable, rounds: int, repeats: int = 3,
               warm: bool = True) -> float:
    """Median-of-k rounds/sec of a jax driver call.

    Single-shot driver timings are scheduler-noise limited on this host
    (BENCH_sweeps.json once recorded a *negative* dynamic-scenario
    overhead from exactly that); the median over k runs is what every
    BENCH_*.json records.  ``warm`` runs the callable once first so the
    compile never lands in a timed sample.
    """
    import jax
    if warm:
        jax.block_until_ready(fn())
    samples = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        samples.append(rounds / (time.perf_counter() - t0))
    samples.sort()
    return samples[len(samples) // 2]
