"""Benchmark entry point: one module per paper figure + kernels + roofline.

  PYTHONPATH=src python -m benchmarks.run [--quick]
  PYTHONPATH=src python -m benchmarks.run --profile results/profile

Each line: ``name,us_per_call,key=value;...`` CSV.  ``--profile DIR``
skips the suites and instead captures a stage-annotated device profile
(``jax.profiler.trace``) of a scanned round-engine workload — the
``hfl/associate`` … ``hfl/eval`` spans from ``repro.telemetry.spans``
segment the scan program by paper stage in TensorBoard/XProf.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def _profile(out_dir: str, quick: bool) -> int:
    import dataclasses

    from repro.configs.hfl_mnist import CONFIG
    from repro.core import engine
    from repro.telemetry import spans

    n, m = (256, 8) if quick else (1024, 16)
    cfg = dataclasses.replace(CONFIG, n_clients=n, n_edges=m,
                              clients_per_edge=4, min_samples=60,
                              max_samples=120, hidden=16, input_dim=32,
                              local_batch=16)
    spec = engine.EngineSpec(policy="gcea", scheduler="fastest")
    state, bundle, _ = engine.init_simulation(cfg, seed=0)
    rounds = 3 if quick else 5
    spans.profile_scanned(cfg, spec, state, bundle, rounds, out_dir)
    print(f"profile ({n}x{m}, {rounds} rounds) written to {out_dir}")
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer rounds/episodes")
    ap.add_argument("--only", default=None)
    ap.add_argument("--profile", default=None, metavar="DIR",
                    help="capture a stage-annotated jax.profiler trace of "
                         "the scanned round engine into DIR, then exit")
    args = ap.parse_args(argv)

    if args.profile:
        return _profile(args.profile, args.quick)

    from benchmarks import (bench_ddpg, bench_kernels, bench_roofline,
                            bench_rounds, bench_sweeps, fig_avg_ms,
                            fig_cost_vs_dn, fig_cost_vs_nm, fig_ddpg_cost,
                            fig_hfl_convergence)
    rounds = 4 if args.quick else 16
    episodes = 6 if args.quick else 15
    suites = [
        ("bench_rounds",
         lambda: bench_rounds.main(["--quick"] if args.quick else [])),
        ("bench_sweeps",
         lambda: bench_sweeps.main(["--quick"] if args.quick else [])),
        ("bench_ddpg",
         lambda: bench_ddpg.main(["--quick"] if args.quick else [])),
        ("fig_hfl_convergence", lambda: fig_hfl_convergence.main(rounds)),
        ("fig_avg_ms", lambda: fig_avg_ms.main(rounds)),
        ("fig_ddpg_cost", lambda: fig_ddpg_cost.main(episodes)),
        ("fig_cost_vs_nm", fig_cost_vs_nm.main),
        ("fig_cost_vs_dn", fig_cost_vs_dn.main),
        ("bench_kernels",
         lambda: bench_kernels.main(["--quick"] if args.quick else [])),
        ("bench_roofline", bench_roofline.main),
    ]
    failed = 0
    for name, fn in suites:
        if args.only and args.only != name:
            continue
        print(f"# === {name} ===", flush=True)
        try:
            fn()
        except Exception:
            traceback.print_exc()
            failed += 1
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
