"""Benchmark entry point: one module per paper figure + kernels + roofline.

  PYTHONPATH=src python -m benchmarks.run [--quick]

Each line: ``name,us_per_call,key=value;...`` CSV.
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer rounds/episodes")
    ap.add_argument("--only", default=None)
    args = ap.parse_args(argv)

    from benchmarks import (bench_ddpg, bench_kernels, bench_roofline,
                            bench_rounds, bench_sweeps, fig_avg_ms,
                            fig_cost_vs_dn, fig_cost_vs_nm, fig_ddpg_cost,
                            fig_hfl_convergence)
    rounds = 4 if args.quick else 16
    episodes = 6 if args.quick else 15
    suites = [
        ("bench_rounds",
         lambda: bench_rounds.main(["--quick"] if args.quick else [])),
        ("bench_sweeps",
         lambda: bench_sweeps.main(["--quick"] if args.quick else [])),
        ("bench_ddpg",
         lambda: bench_ddpg.main(["--quick"] if args.quick else [])),
        ("fig_hfl_convergence", lambda: fig_hfl_convergence.main(rounds)),
        ("fig_avg_ms", lambda: fig_avg_ms.main(rounds)),
        ("fig_ddpg_cost", lambda: fig_ddpg_cost.main(episodes)),
        ("fig_cost_vs_nm", fig_cost_vs_nm.main),
        ("fig_cost_vs_dn", fig_cost_vs_dn.main),
        ("bench_kernels",
         lambda: bench_kernels.main(["--quick"] if args.quick else [])),
        ("bench_roofline", bench_roofline.main),
    ]
    failed = 0
    for name, fn in suites:
        if args.only and args.only != name:
            continue
        print(f"# === {name} ===", flush=True)
        try:
            fn()
        except Exception:
            traceback.print_exc()
            failed += 1
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
