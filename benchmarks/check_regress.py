"""Perf-regression gate: a fresh quick bench vs the committed baseline.

Re-measures the scanned round-engine drivers — the sync barrier engine
(``bench_rounds.SPEC``) and the semi-async buffered engine
(``bench_rounds.SPEC_BUFFERED``, DESIGN.md §11) — at the quick sizes and
compares each size's rounds/sec against the ``scanned_rps`` /
``buffered_rps`` columns recorded in the committed
``BENCH_rounds.json``.  A column REGRESSES when

    fresh_rps < committed_rps * (1 - tol/100)

The train stage is additionally gated per-stage (``stages.train_ms``,
direction flipped since lower ms is better): a training-stage regression
fails CI even when association noise hides it in the aggregate rps.

and any regression exits non-zero — the CI perf-smoke step.  Faster is
never a failure (an improved number just means the baseline should be
re-recorded by ``bench_rounds``).

The committed baseline carries provenance (host, backend, jax version);
a CI runner is a DIFFERENT machine from the recording host, so the CI
invocation uses a deliberately generous ``--tol`` — the gate catches
order-of-magnitude structural regressions (a retrace per round, a host
sync inside the scan), not single-digit drift.  Writes its verdict to
``results/check_regress.json``.

  PYTHONPATH=src python -m benchmarks.check_regress --quick --tol 75
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Dict

from benchmarks import bench_rounds
from benchmarks.common import median_rps, provenance
from repro.core import engine

BENCH = os.path.join(os.path.dirname(__file__), "..", "BENCH_rounds.json")
OUT = os.path.join(os.path.dirname(__file__), "..", "results",
                   "check_regress.json")

QUICK_SIZES = ((64, 4), (256, 8))
FULL_SIZES = bench_rounds.SIZES                 # adds (1024, 16)


# gated (column, spec) pairs: the sync scanned driver, the semi-async
# buffered micro-step driver (DESIGN.md §11) and the fault-injected
# buffered driver (DESIGN.md §12) — all scan-compiled programs whose rps
# collapses on the same structural regressions
COLUMNS = (("scanned_rps", bench_rounds.SPEC),
           ("buffered_rps", bench_rounds.SPEC_BUFFERED),
           ("faults_rps", bench_rounds.SPEC_FAULTS))


def fresh_scanned_rps(n: int, m: int, rounds: int,
                      spec=bench_rounds.SPEC) -> float:
    """The scanned driver's median rounds/sec at (n, m) — the same spec,
    config shape and statistic ``bench_rounds`` records."""
    cfg = bench_rounds._cfg(n, m)
    state, bundle, _ = engine.init_simulation(cfg, seed=0)
    return median_rps(
        lambda: engine.run_scanned(cfg, spec, state, bundle, rounds),
        rounds)


def check(bench_path: str = BENCH, tol_pct: float = 30.0,
          quick: bool = False, rounds: int = 5) -> Dict:
    with open(bench_path) as fh:
        committed = json.load(fh)
    sizes = QUICK_SIZES if quick else FULL_SIZES
    report = {
        "tol_pct": tol_pct,
        "baseline_provenance": committed.get("provenance"),
        "provenance": provenance(),
        "sizes": {},
        "regressed": [],
    }
    for n, m in sizes:
        key = f"{n}x{m}"
        row = committed.get("results", {}).get(key, {})
        report["sizes"][key] = {}
        for col, spec in COLUMNS:
            base = row.get(col)
            if base is None:
                # a baseline recorded before this column existed: warn and
                # skip rather than fail — re-recording bench_rounds is the
                # fix, not a red CI
                print(f"WARNING: {key} {col}: committed baseline has no "
                      f"such column — skipping (re-record with "
                      f"bench_rounds to gate it)", flush=True)
                report["sizes"][key][col] = {"status": "no-baseline"}
                continue
            fresh = fresh_scanned_rps(n, m, rounds, spec)
            floor = base * (1.0 - tol_pct / 100.0)
            ok = fresh >= floor
            report["sizes"][key][col] = {
                "committed_rps": base,
                "fresh_rps": round(fresh, 3),
                "floor_rps": round(floor, 3),
                "ratio": round(fresh / base, 3),
                "status": "ok" if ok else "REGRESSED",
            }
            if not ok:
                report["regressed"].append(f"{key}:{col}")
            print(f"{key} {col}: fresh {fresh:.2f} rps vs committed "
                  f"{base:.2f} (floor {floor:.2f}) -> "
                  f"{report['sizes'][key][col]['status']}", flush=True)

        # per-stage train gate (DESIGN.md §13): training is the hot stage
        # post-candidate-frontier, and association noise can hide a train
        # regression inside the aggregate rps — so its ms is gated
        # directly.  Lower is better here, so the failure direction flips:
        # fresh_ms > committed_ms * (1 + tol/100) regresses.
        base_ms = row.get("stages", {}).get("train_ms")
        if base_ms is None:
            print(f"WARNING: {key} stages.train_ms: committed baseline "
                  f"has no such column — skipping (re-record with "
                  f"bench_rounds to gate it)", flush=True)
            report["sizes"][key]["train_ms"] = {"status": "no-baseline"}
        else:
            cfg = bench_rounds._cfg(n, m)
            state, bundle, _ = engine.init_simulation(cfg, seed=0)
            fresh_ms = bench_rounds.train_stage_ms(cfg, state, bundle)
            ceil = base_ms * (1.0 + tol_pct / 100.0)
            ok = fresh_ms <= ceil
            report["sizes"][key]["train_ms"] = {
                "committed_ms": base_ms,
                "fresh_ms": round(fresh_ms, 3),
                "ceil_ms": round(ceil, 3),
                "ratio": round(fresh_ms / max(base_ms, 1e-9), 3),
                "status": "ok" if ok else "REGRESSED",
            }
            if not ok:
                report["regressed"].append(f"{key}:train_ms")
            print(f"{key} train_ms: fresh {fresh_ms:.2f} ms vs committed "
                  f"{base_ms:.2f} (ceil {ceil:.2f}) -> "
                  f"{report['sizes'][key]['train_ms']['status']}",
                  flush=True)
    report["ok"] = not report["regressed"]
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--bench", default=BENCH,
                    help="committed baseline JSON (default: BENCH_rounds"
                         ".json at the repo root)")
    ap.add_argument("--tol", type=float, default=30.0,
                    help="allowed slowdown in percent before failing")
    ap.add_argument("--quick", action="store_true",
                    help="small sizes only (CI-speed)")
    ap.add_argument("--rounds", type=int, default=5,
                    help="scan length per timed driver call")
    ap.add_argument("--out", default=OUT,
                    help="verdict JSON path")
    args = ap.parse_args(argv)

    report = check(args.bench, args.tol, args.quick, args.rounds)
    os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
    with open(args.out, "w") as fh:
        json.dump(report, fh, indent=2)
    print(f"wrote {os.path.normpath(args.out)}")
    if not report["ok"]:
        print(f"PERF REGRESSION: {', '.join(report['regressed'])} fell "
              f"more than {args.tol}% below the committed baseline")
        return 1
    print("no perf regression")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
