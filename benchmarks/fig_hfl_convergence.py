"""Paper Figs. 8-11: HFL accuracy/loss vs global round, FCEA vs RCEA/GCEA/OMA,
IID and non-IID — driven by the pure round engine: each scheme's seed sweep
is ONE ``engine.run_fleet`` call (vmap over seeds of the scanned round
program) instead of seeds × rounds eager python steps."""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from benchmarks.common import SMALL, emit
from repro.core import engine

SEEDS = (0, 1, 2)


def run(rounds: int = 10, iid: bool = True) -> Dict[str, Dict[str, float]]:
    """Per scheme, over matched SEEDS: mean accuracy-AUC (convergence
    speed, the paper's Figs. 8/10 visual) and mean final accuracy."""
    out: Dict[str, Dict[str, List[float]]] = {}
    schemes = [("fcea", True), ("rcea", True), ("gcea", True),
               ("oma", False)]
    for name, noma in schemes:
        policy = "fcea" if name == "oma" else name
        spec = engine.EngineSpec(policy=policy, noma_enabled=noma)
        t0 = time.time()
        pairs = [engine.init_simulation(SMALL, seed=s, iid=iid)[:2]
                 for s in SEEDS]
        states, bundles = engine.stack_fleet(pairs)
        _, ms = engine.run_fleet(SMALL, spec, states, bundles, rounds)
        acc = np.asarray(ms.accuracy)                     # (seeds, rounds)
        loss = np.asarray(ms.loss)
        rec = out[name] = {"auc": acc.mean(axis=1).tolist(),
                           "final": acc[:, -1].tolist(),
                           "loss": loss[:, -1].tolist()}
        emit(f"hfl_{'iid' if iid else 'noniid'}_{name}",
             (time.time() - t0) / (rounds * len(SEEDS)) * 1e6,
             {"acc_auc": round(float(np.mean(rec["auc"])), 4),
              "final_acc": round(float(np.mean(rec["final"])), 4),
              "final_loss": round(float(np.mean(rec["loss"])), 4),
              "rounds": rounds, "seeds": len(SEEDS)})
    return {k: {kk: float(np.mean(vv)) for kk, vv in v.items()}
            for k, v in out.items()}


def main(rounds: int = 10) -> None:
    for iid in (True, False):
        res = run(rounds=rounds, iid=iid)
        # the paper's claim: FCEA converges fastest (highest accuracy
        # through training) — ranked on accuracy-AUC
        aucs = {k: v["auc"] for k, v in res.items()}
        best = max(aucs, key=aucs.get)
        emit(f"hfl_{'iid' if iid else 'noniid'}_summary", 0.0,
             {"best_scheme_auc": best,
              **{k: round(v, 4) for k, v in aucs.items()}})


if __name__ == "__main__":
    main()
