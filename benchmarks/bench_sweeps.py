"""Scenario-engine overhead + sweep-fleet throughput (DESIGN.md §6).

Two questions:

* what does the dynamic-world transition cost per round?  ``static`` vs
  ``dynamic`` ``run_scanned`` rounds/sec at (N, M) = (256, 8);
* what does the sweep machinery deliver?  a 3-scenario × 2-policy grid
  (seeds vmapped per policy group) through ``sweeps.run_sweep``, reported
  as aggregate simulated rounds/sec and compiles used.

Writes BENCH_sweeps.json at the repo root so the perf trajectory is
tracked across PRs.

  PYTHONPATH=src python -m benchmarks.bench_sweeps [--quick]
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import time
from typing import Dict

from benchmarks.common import emit, median_rps, provenance
from repro import sweeps
from repro.configs.hfl_mnist import CONFIG
from repro.core import engine

OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_sweeps.json")

N, M = 256, 8


def _cfg():
    return dataclasses.replace(CONFIG, n_clients=N, n_edges=M,
                               clients_per_edge=4, min_samples=60,
                               max_samples=120, hidden=16, input_dim=32,
                               local_batch=16)


def bench_engine_overhead(rounds: int, repeats: int) -> Dict[str, float]:
    """static vs dynamic round_step throughput, same compiled-scan driver,
    median of ``repeats`` timed runs per path (``common.median_rps`` —
    single-shot timings once recorded a NEGATIVE −5.4 % dynamic overhead
    from pure scheduler jitter)."""
    cfg = _cfg()
    out: Dict[str, float] = {}
    for label, scenario, kind in (("static", None, "static"),
                                  ("dynamic", "full_dynamic", "dynamic")):
        spec = engine.EngineSpec(policy="gcea", scheduler="fastest",
                                 scenario=kind)
        state, bundle, _ = engine.init_simulation(cfg, seed=0,
                                                  scenario=scenario)
        run = lambda: engine.run_scanned(cfg, spec, state, bundle, rounds)
        out[f"{label}_rps"] = round(
            median_rps(run, rounds, repeats=repeats), 3)
    out["dynamic_overhead_pct"] = round(
        100.0 * (out["static_rps"] / max(out["dynamic_rps"], 1e-9) - 1.0), 2)
    out["rounds"] = rounds
    out["repeats"] = repeats
    return out


def bench_sweep_fleet(rounds: int, seeds: int,
                      repeats: int) -> Dict[str, float]:
    """3 scenarios × 2 policies × seeds as grouped vmapped fleets
    (median of ``repeats`` timed passes)."""
    cfg = _cfg()
    grid = sweeps.SweepGrid(
        name="bench",
        scenarios=("random_waypoint", "markov_dropout", "hetero_devices"),
        policies=("fcea", "gcea"),
        schedulers=("pdd",),
        seeds=tuple(range(seeds)),
        n_rounds=rounds)
    # warm the compile caches so the timed passes measure throughput
    summary = sweeps.run_sweep(cfg, grid, write_json=False)
    total_rounds = summary["n_cells"] * rounds
    walls = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        sweeps.run_sweep(cfg, grid, write_json=False)
        walls.append(time.perf_counter() - t0)
    walls.sort()
    wall = walls[len(walls) // 2]
    return {"cells": summary["n_cells"],
            "compiles": summary["n_compiles"],
            "rounds_per_cell": rounds,
            "repeats": repeats,
            "fleet_rps": round(total_rounds / wall, 3),
            "wall_s": round(wall, 3)}


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer rounds/seeds (CI-speed)")
    args = ap.parse_args(argv)

    rounds = 5 if args.quick else 15
    seeds = 2 if args.quick else 4
    repeats = 3 if args.quick else 5

    overhead = bench_engine_overhead(rounds, repeats)
    emit(f"sweeps_engine_n{N}_m{M}", 1e6 / overhead["dynamic_rps"], overhead)
    fleet = bench_sweep_fleet(rounds, seeds, repeats)
    emit("sweeps_fleet_3x2", 1e6 / fleet["fleet_rps"], fleet)

    with open(OUT, "w") as fh:
        json.dump({"size": [N, M], "provenance": provenance(),
                   "timing_stat": "median_of_k",
                   "engine": overhead, "fleet": fleet},
                  fh, indent=2)
    print(f"wrote {os.path.normpath(OUT)}")


if __name__ == "__main__":
    main()
