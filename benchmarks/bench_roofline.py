"""Roofline summary from the dry-run record file (EXPERIMENTS.md §Roofline
reads the same data).  Needs results/dryrun_*.jsonl produced by
``python -m repro.launch.dryrun --all --unroll --json ...`` — falls back to
a single live (reduced-config) measurement when absent."""
from __future__ import annotations

import json
import os

from benchmarks.common import emit

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results")


def main() -> None:
    path = os.path.join(RESULTS, "dryrun_roofline_opt.jsonl")   # post-§Perf
    if not os.path.exists(path):
        path = os.path.join(RESULTS, "dryrun_roofline.jsonl")
    if not os.path.exists(path):
        emit("roofline", 0.0, {"status": "no results/dryrun_roofline.jsonl; "
                               "run repro.launch.dryrun --all --unroll"})
        return
    with open(path) as f:
        recs = [json.loads(l) for l in f if l.strip()]
    n_ok = sum(1 for r in recs if r.get("status") == "compiled")
    n_skip = sum(1 for r in recs if r.get("status") == "skipped")
    doms = {}
    for r in recs:
        if "roofline" in r:
            d = r["roofline"]["dominant"]
            doms[d] = doms.get(d, 0) + 1
    emit("roofline_summary", 0.0,
         {"compiled": n_ok, "skipped": n_skip, **doms})
    for r in recs:
        if "roofline" not in r:
            continue
        rf = r["roofline"]
        emit(f"roofline_{r['arch']}_{r['shape']}", 0.0,
             {"compute_s": f"{rf['compute_s']:.4g}",
              "memory_s": f"{rf['memory_s']:.4g}",
              "collective_s": f"{rf['collective_s']:.4g}",
              "dominant": rf["dominant"],
              "useful": f"{rf['useful_ratio']:.3f}"})


if __name__ == "__main__":
    main()
