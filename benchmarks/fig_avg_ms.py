"""Paper Fig. 12: average model staleness vs global round per scheme."""
from __future__ import annotations

import time

from benchmarks.common import SMALL, emit
from repro.core.hfl import HFLSimulation


def main(rounds: int = 8) -> None:
    results = {}
    for name, noma in [("fcea", True), ("rcea", True), ("gcea", True),
                       ("oma", False)]:
        policy = "fcea" if name == "oma" else name
        sim = HFLSimulation(SMALL, seed=1, iid=True, policy=policy,
                            noma_enabled=noma)
        t0 = time.time()
        ms = sim.run(rounds)
        results[name] = ms[-1].avg_staleness
        emit(f"avg_ms_{name}", (time.time() - t0) / rounds * 1e6,
             {"avg_staleness": round(ms[-1].avg_staleness, 3),
              "trajectory": "|".join(f"{m.avg_staleness:.2f}" for m in ms)})
    emit("avg_ms_summary", 0.0,
         {"fcea_lowest": results["fcea"] <= min(results["rcea"],
                                                results["gcea"]) + 0.5})


if __name__ == "__main__":
    main()
