"""Kernel micro-benchmarks (interpret-mode correctness + XLA-oracle timing
on CPU; real timings require the TPU target).

Covers the substrate kernels (flash attention, linear recurrence) and the
PR-4 HFL kernels (``hfl_ops.score_matrix`` fused fuzzy scoring,
``hfl_ops.sic_rates`` fused NOMA SIC) — for the latter the jnp oracles are
also raced against each other (pairwise vs sorted SIC), since on CPU the
sorted jnp path is the production one and the kernel is the TPU story.

  PYTHONPATH=src python -m benchmarks.bench_kernels [--quick]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import fuzzy, noma
from repro.kernels import hfl_ops, ops, ref
from repro.models.mlp import MLPClassifier


def _time_us(fn, *args, repeats: int = 5) -> float:
    jax.block_until_ready(fn(*args))
    t0 = time.time()
    for _ in range(repeats):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / repeats * 1e6


def bench_hfl_kernels(quick: bool) -> None:
    """Interpret-mode parity + jnp-oracle timings for the HFL kernels."""
    rng = np.random.default_rng(0)
    n, m = (256, 8) if quick else (1024, 16)
    quota = 4

    gains = jnp.asarray(rng.uniform(1e-12, 1e-8, (n, m)))
    counts = jnp.asarray(rng.integers(60, 120, n), jnp.float32)
    stale = jnp.asarray(rng.integers(1, 9, n), jnp.int32)
    oracle = jax.jit(lambda g, c, s: fuzzy.score_matrix(g, c, s,
                                                        data_max=120.0))
    us = _time_us(oracle, gains, counts, stale)
    got = hfl_ops.score_matrix(gains, counts, stale, data_max=120.0,
                               interpret=True)
    err = float(jnp.max(jnp.abs(got - oracle(gains, counts, stale))))
    emit(f"hfl_score_{n}x{m}", us,
         {"interpret_maxerr": f"{err:.2e}", "rows": n * m,
          "note": "oracle-XLA time on CPU"})

    p = jnp.asarray(rng.uniform(0.01, 0.1, n))
    mask_np = np.zeros((n, m), bool)
    for j in range(m):
        mask_np[rng.choice(n, quota, replace=False), j] = True
    mask = jnp.asarray(mask_np)
    noise = noma.noise_power_w(-174.0, 1e6)

    def pairwise(p_, g_, mk_):
        def per_edge(j):
            return noma.achievable_rates(p_, g_[:, j], bandwidth_hz=1e6,
                                         noise_w=noise, mask=mk_[:, j])
        return jax.vmap(per_edge)(jnp.arange(m)).T

    f_pair = jax.jit(pairwise)
    f_sorted = jax.jit(lambda p_, g_, mk_: noma.sic_rates_matrix(
        p_, g_, mk_, bandwidth_hz=1e6, noise_w=noise))
    f_topk = jax.jit(lambda p_, g_, mk_: noma.sic_rates_matrix(
        p_, g_, mk_, bandwidth_hz=1e6, noise_w=noise, max_per_edge=quota))
    pair_us = _time_us(f_pair, p, gains, mask)
    sorted_us = _time_us(f_sorted, p, gains, mask)
    topk_us = _time_us(f_topk, p, gains, mask)
    got = hfl_ops.sic_rates(p, gains, mask, bandwidth_hz=1e6,
                            noise_w=noise, interpret=True)
    err = float(jnp.max(jnp.abs(got - f_pair(p, gains, mask))))
    emit(f"hfl_sic_{n}x{m}", pair_us,
         {"interpret_maxerr": f"{err:.2e}",
          "sorted_us": round(sorted_us, 1), "topk_us": round(topk_us, 1),
          "sorted_speedup": round(pair_us / max(sorted_us, 1e-9), 1),
          "topk_speedup": round(pair_us / max(topk_us, 1e-9), 1),
          "note": "pairwise-XLA time on CPU"})

    # fused local-SGD (DESIGN.md §13.3): batched-GEMM oracle timing +
    # interpret-mode parity of the Pallas kernel on the same minibatches
    k_lanes, tau1, batch = (8, 2, 16) if quick else (16, 2, 16)
    dim, hid, ncls = (32, 16, 10) if quick else (64, 32, 10)
    model = MLPClassifier(dim, hid, ncls)
    p0 = model.init(jax.random.key(1))
    params = jax.tree.map(
        lambda l: jnp.stack([l] * k_lanes) * (1.0 + 1e-3), p0)
    bx = jnp.asarray(rng.normal(size=(tau1, k_lanes, batch, dim)),
                     jnp.float32)
    by = jnp.asarray(rng.integers(0, ncls, (tau1, k_lanes, batch)),
                     jnp.int32)

    def one(p_, xs, ys):
        def step(p, xy):
            g = jax.grad(model.loss)(p, xy)
            return jax.tree.map(lambda a, b: a - 0.01 * b, p, g), None
        return jax.lax.scan(step, p_, (xs, ys))[0]

    oracle_sgd = jax.jit(jax.vmap(one, in_axes=(0, 1, 1)))
    sgd_us = _time_us(oracle_sgd, params, bx, by)
    got = hfl_ops.local_sgd_step(params, bx, by, lr=0.01, interpret=True)
    want = oracle_sgd(params, bx, by)
    err = max(float(jnp.max(jnp.abs(got[k_] - want[k_]))) for k_ in want)
    emit(f"hfl_local_sgd_{k_lanes}x{tau1}x{batch}", sgd_us,
         {"interpret_maxerr": f"{err:.2e}",
          "flops": 6 * tau1 * k_lanes * batch * (dim * hid + hid * hid
                                                 + hid * ncls),
          "note": "vmap-XLA time on CPU"})


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="smaller shapes (CI smoke)")
    args = ap.parse_args(argv)

    key = jax.random.key(0)
    ks = jax.random.split(key, 3)
    b, s, h, kv, d = (1, 256, 4, 2, 64) if args.quick else (1, 512, 4, 2, 64)
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, d), jnp.float32)

    qt, kt, vt = (x.transpose(0, 2, 1, 3) for x in (q, k, v))
    oracle = jax.jit(lambda q_, k_, v_: ref.attention_ref(q_, k_, v_,
                                                          causal=True))
    oracle(qt, kt, vt).block_until_ready()
    t0 = time.time()
    for _ in range(5):
        oracle(qt, kt, vt).block_until_ready()
    oracle_us = (time.time() - t0) / 5 * 1e6

    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = oracle(qt, kt, vt).transpose(0, 2, 1, 3)
    err = float(jnp.max(jnp.abs(out - want)))
    emit(f"flash_attention_{s}", oracle_us,
         {"interpret_maxerr": f"{err:.2e}",
          "flops": 4 * b * h * s * s * d, "note": "oracle-XLA time on CPU"})

    t = 512 if args.quick else 1024
    la = -jax.random.uniform(ks[0], (1, t, 256), jnp.float32, 0.01, 1.0)
    x = jax.random.normal(ks[1], (1, t, 256), jnp.float32)
    lr_oracle = jax.jit(ref.linear_recurrence_ref)
    lr_oracle(la, x).block_until_ready()
    t0 = time.time()
    for _ in range(5):
        lr_oracle(la, x).block_until_ready()
    lr_us = (time.time() - t0) / 5 * 1e6
    out = ops.linear_recurrence(la, x, interpret=True)
    err = float(jnp.max(jnp.abs(out - lr_oracle(la, x))))
    emit(f"linear_recurrence_{t}", lr_us,
         {"interpret_maxerr": f"{err:.2e}",
          "bytes": 3 * la.size * 4, "note": "oracle-XLA time on CPU"})

    bench_hfl_kernels(args.quick)


if __name__ == "__main__":
    main()
