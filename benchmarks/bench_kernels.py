"""Kernel micro-benchmarks (interpret-mode correctness + XLA-oracle timing
on CPU; real timings require the TPU target)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.kernels import ops, ref


def main() -> None:
    key = jax.random.key(0)
    ks = jax.random.split(key, 3)
    b, s, h, kv, d = 1, 512, 4, 2, 64
    q = jax.random.normal(ks[0], (b, s, h, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, d), jnp.float32)

    qt, kt, vt = (x.transpose(0, 2, 1, 3) for x in (q, k, v))
    oracle = jax.jit(lambda q_, k_, v_: ref.attention_ref(q_, k_, v_,
                                                          causal=True))
    oracle(qt, kt, vt).block_until_ready()
    t0 = time.time()
    for _ in range(5):
        oracle(qt, kt, vt).block_until_ready()
    oracle_us = (time.time() - t0) / 5 * 1e6

    out = ops.flash_attention(q, k, v, causal=True, interpret=True)
    want = oracle(qt, kt, vt).transpose(0, 2, 1, 3)
    err = float(jnp.max(jnp.abs(out - want)))
    emit("flash_attention_512", oracle_us,
         {"interpret_maxerr": f"{err:.2e}",
          "flops": 4 * b * h * s * s * d, "note": "oracle-XLA time on CPU"})

    la = -jax.random.uniform(ks[0], (1, 1024, 256), jnp.float32, 0.01, 1.0)
    x = jax.random.normal(ks[1], (1, 1024, 256), jnp.float32)
    lr_oracle = jax.jit(ref.linear_recurrence_ref)
    lr_oracle(la, x).block_until_ready()
    t0 = time.time()
    for _ in range(5):
        lr_oracle(la, x).block_until_ready()
    lr_us = (time.time() - t0) / 5 * 1e6
    out = ops.linear_recurrence(la, x, interpret=True)
    err = float(jnp.max(jnp.abs(out - lr_oracle(la, x))))
    emit("linear_recurrence_1k", lr_us,
         {"interpret_maxerr": f"{err:.2e}",
          "bytes": 3 * la.size * 4, "note": "oracle-XLA time on CPU"})


if __name__ == "__main__":
    main()
